"""TPU-native use of the paper's allocator: schedule local-SGD quotas
across heterogeneous pod slices (DiLoCo-style multi-pod training).

Each "learner" is a pod slice with an effective throughput (chips x peak x
MFU) and a DCN link to the orchestrator; the allocator decides how many
sequences (d_k) and local steps (tau_k) each slice runs per synchronization
wall-clock window T so no slice idles and gradient staleness across slices
is minimized.

  PYTHONPATH=src python examples/allocate_pods.py --arch llama3-8b
"""

import argparse

from repro.configs import get_config
from repro.core import (
    AllocationProblem,
    TimeModel,
    pod_slice_profile,
    solve_eta,
    solve_kkt_sai,
    transformer_cost,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--slices", type=int, default=6)
    ap.add_argument("--t", type=float, default=300.0, help="sync window (s)")
    ap.add_argument("--seqs", type=int, default=8192, help="sequences per window")
    ap.add_argument("--seq-len", type=int, default=4096)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    total, active = cfg.param_counts()
    cost = transformer_cost(
        params_total=total, params_active=active, seq_len=args.seq_len,
        precision_bits=16,
    )
    print(f"{args.arch}: {total/1e9:.1f}B params ({active/1e9:.1f}B active), "
          f"{cost.flops_per_sample:.2e} FLOPs/seq, model {cost.model_bits/8e9:.1f} GB")

    profiles = pod_slice_profile(args.slices, seed=1)
    tm = TimeModel.build(
        profiles,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
        task_parallelization=False,   # each slice streams its own data shard
    )
    prob = AllocationProblem(
        time_model=tm, T=args.t, total_samples=args.seqs,
        d_lower=args.seqs // (4 * args.slices), d_upper=args.seqs,
    )
    for name, solver in [("optimized", solve_kkt_sai), ("equal-split", solve_eta)]:
        a = solver(prob)
        s = a.summary(prob)
        print(f"\n{name}: local-steps quotas tau={a.tau.tolist()}")
        print(f"  seqs/slice d={a.d.tolist()}")
        print(f"  max staleness {s['max_staleness']}, utilization {s['utilization']:.1%}, "
              f"updates {s['total_updates']}")


if __name__ == "__main__":
    main()
