"""Quickstart: the paper's core in ~40 lines.

Builds a heterogeneous edge fleet, derives the per-learner time model from
the paper's exact MNIST-DNN constants, solves the staleness-minimizing
task allocation (KKT water-filling + suggest-and-improve), and compares it
against the ETA and synchronous baselines.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    AllocationProblem,
    TimeModel,
    indoor_80211_profile,
    mnist_dnn_cost,
    solve_eta,
    solve_kkt_sai,
    solve_synchronous,
)

K, T, D = 10, 15.0, 6000

cost = mnist_dnn_cost()
print(f"paper model: S_m = {cost.model_bits:.0f} bits, C_m = {cost.flops_per_sample:.0f} FLOPs/sample")

profiles = indoor_80211_profile(K, seed=0)
tm = TimeModel.build(
    profiles,
    model_complexity_flops=cost.flops_per_sample,
    model_size_bits=cost.model_bits,
)
prob = AllocationProblem(time_model=tm, T=T, total_samples=D,
                         d_lower=D // (4 * K), d_upper=3 * D // K)

for name, solver in [("optimized (KKT+SAI)", solve_kkt_sai),
                     ("ETA  [10]", solve_eta),
                     ("sync [9]", solve_synchronous)]:
    alloc = solver(prob)
    s = alloc.summary(prob)
    t = tm.cycle_time(alloc.tau, alloc.d)
    print(f"\n{name}")
    print(f"  tau = {alloc.tau.tolist()}")
    print(f"  d   = {alloc.d.tolist()}")
    print(f"  max staleness = {s['max_staleness']}, avg = {s['avg_staleness']:.2f}, "
          f"total updates = {s['total_updates']}, mean utilization = {s['utilization']:.2%}")
    assert np.all(t <= T * 1.000001), "deadline violated!"
