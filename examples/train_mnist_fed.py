"""End-to-end driver (the paper's experiment): asynchronous federated
training of the [784,300,124,60,10] DNN over a heterogeneous 802.11 edge
fleet, a few hundred aggregate local steps on CPU.

  PYTHONPATH=src python examples/train_mnist_fed.py [--cycles 10] [--k 10]
"""

import argparse

from repro.data.pipeline import synthetic_mnist
from repro.fed.simulation import run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--t", type=float, default=15.0)
    ap.add_argument("--cycles", type=int, default=10)
    ap.add_argument("--samples", type=int, default=6000)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()

    train, test = synthetic_mnist(max(args.samples * 2, 12_000), seed=args.seed)
    print(f"K={args.k} T={args.t}s d={args.samples} cycles={args.cycles}")
    print(f"{'scheme':24s} {'per-cycle accuracy'}")
    for scheme, agg in [("kkt_sai", "staleness"), ("sync", "fedavg"), ("eta", "staleness"), ("eta", "fedavg")]:
        res = run_experiment(
            k=args.k, T=args.t, cycles=args.cycles, scheme=scheme, aggregation=agg,
            total_samples=args.samples, seed=args.seed, train=train, test=test,
        )
        accs = " ".join(f"{h['accuracy']:.3f}" for h in res["history"])
        tag = f"{scheme}/{agg}"
        print(f"{tag:24s} {accs}   (max staleness {res['allocation']['max_staleness']})")


if __name__ == "__main__":
    main()
