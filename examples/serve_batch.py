"""Serve a small model with batched requests: prefill a batch of prompts,
then decode tokens with the KV/state cache — runs any of the 10 assigned
architectures in its reduced form on CPU.

  PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-7b --gen 12
"""

import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    # the serving loop lives in the launcher; this example drives it the way
    # an operator would
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--reduced",
        "--batch", str(args.batch),
        "--prompt-len", str(args.prompt_len),
        "--gen", str(args.gen),
    ]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
