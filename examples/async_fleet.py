"""Event-driven asynchronous federation over a drifting edge fleet.

The paper gates every learner to the same cycle budget T; this example
drops the gate and lets the server react per upload (FedAsync) or per
buffer flush (FedBuff/FedAST style), all on the paper's own per-learner
wall-clock cost model and allocation solvers. Three servers train the same
model for the same amount of *virtual* time under the same capacity drift:

  cycle     the paper's scheme (engine barrier regime: buffered, M = K)
  fedasync  mix on every arrival with version-staleness discounting
  buffered  flush a size-M buffer, staleness-weighted, version bump per flush

  PYTHONPATH=src python examples/async_fleet.py
  PYTHONPATH=src python examples/async_fleet.py --trace fedasync  # per-event log
  PYTHONPATH=src python examples/async_fleet.py --bucketed        # scan fast path
"""

import argparse

import numpy as np

from repro.core import CapacityDrift
from repro.fed.simulation import async_mode_sweep, run_async_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--t", type=float, default=5.0, help="cycle/block budget (s)")
    ap.add_argument("--cycles", type=int, default=4,
                    help="virtual-time horizon in multiples of T")
    ap.add_argument("--total", type=int, default=900)
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--staleness-fn", default="poly",
                    choices=("constant", "hinge", "poly"))
    ap.add_argument("--clock-jitter", type=float, default=0.15)
    ap.add_argument("--fading-db", type=float, default=2.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static", action="store_true",
                    help="freeze allocations at the base capacities")
    ap.add_argument("--trace", default=None,
                    metavar="MODE", help="print the per-event log of one mode")
    ap.add_argument("--bucketed", action="store_true",
                    help="also run fedasync through the time-bucket lax.scan")
    args = ap.parse_args()

    drift = CapacityDrift(
        clock_jitter=args.clock_jitter, fading_sigma_db=args.fading_db,
        seed=args.seed,
    )
    kw = dict(
        T=args.t, cycles=args.cycles, total_samples=args.total,
        drift=drift, seed=args.seed, reallocate=not args.static,
        alpha=args.alpha, staleness_fn=args.staleness_fn,
    )
    rows = async_mode_sweep([args.k], **kw)

    print(f"# K={args.k}, horizon={args.cycles}xT={args.cycles * args.t:.0f}s, "
          f"clock jitter ±{args.clock_jitter:.0%}, fading {args.fading_db} dB, "
          f"{'static' if args.static else 'adaptive'} allocation")
    print(f"{'mode':>9} {'final_acc':>9} {'aggs':>5} {'uploads':>7} "
          f"{'stal_mean':>9} {'stal_max':>8}")
    for r in rows:
        if "error" in r:
            print(f"{r['mode']:>9}  {r['error']}")
            continue
        print(f"{r['mode']:>9} {r['final_accuracy']:>9.3f} "
              f"{r['aggregations']:>5d} {r['uploads']:>7d} "
              f"{r['staleness_mean']:>9.2f} {r['staleness_max']:>8d}")

    if args.trace:
        res = run_async_experiment(k=args.k, mode=args.trace, **kw)
        print(f"\n# per-aggregation log ({args.trace})")
        for r in res["history"][:25]:
            acc = f" acc={r['accuracy']:.3f}" if "accuracy" in r else ""
            print(f"t={r['t']:7.2f}s v{r['server_version']:<3d} "
                  f"learners={r['learners']} stal={r['staleness_list']} "
                  f"w={np.round(r['weights'], 3)}{acc}")

    if args.bucketed:
        # num_buckets=0 takes the event-indexed (jagged) path: exact on
        # every schedule, no grid/strict tuning
        res = run_async_experiment(
            k=args.k, mode="fedasync", bucketed=True, **kw,
        )
        print(f"\n# event-indexed scan fast path: "
              f"{res['summary']['aggregations']} aggregations in one XLA "
              f"program, final acc {res['final_accuracy']:.3f}")


if __name__ == "__main__":
    main()
