"""Adaptive vs static task allocation under time-varying edge dynamics.

The paper's core claim is that *adaptive* allocation — re-solving the
staleness-minimizing program as node capacities evolve — beats schemes
that freeze the allocation. This example makes the capacities actually
move: a ``CapacityDrift`` model re-draws per-cycle channel fading and
compute jitter, and we compare

  * static   — solve once on the base capacities, freeze (tau, d); each
               cycle's realized tau_k is whatever the TRUE capacities
               admit with the frozen d_k, so staleness accumulates;
  * adaptive — re-solve every cycle on that cycle's capacities. On the
               fused orchestrator path this re-solve is traced INSIDE the
               scan-over-cycles (``run_fused(reallocate=True)``), so the
               whole drifting run is still one XLA program.

  PYTHONPATH=src python examples/realloc_drift.py
  PYTHONPATH=src python examples/realloc_drift.py --train   # + tiny MEL run
"""

import argparse

import numpy as np

from repro.core import CapacityDrift
from repro.fed.simulation import drift_staleness_sweep, run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, nargs="+", default=[5, 10, 15])
    ap.add_argument("--t", type=float, default=7.5, help="cycle budget (s)")
    ap.add_argument("--cycles", type=int, default=12)
    ap.add_argument("--clock-jitter", type=float, default=0.15)
    ap.add_argument("--fading-db", type=float, default=2.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train", action="store_true",
                    help="also run a small fused in-scan reallocating MEL run")
    args = ap.parse_args()

    drift = CapacityDrift(
        clock_jitter=args.clock_jitter, fading_sigma_db=args.fading_db,
        seed=args.seed,
    )
    rows = drift_staleness_sweep(
        args.k, args.t, cycles=args.cycles, drift=drift,
        schemes=("kkt_sai", "eta"), seed=args.seed,
    )

    print(f"# {args.cycles} cycles, clock jitter ±{args.clock_jitter:.0%}, "
          f"fading sigma {args.fading_db} dB")
    print(f"{'K':>4} {'scheme':>8} {'mode':>9} {'max_stale(mean)':>16} "
          f"{'max_stale(worst)':>17} {'avg_stale(mean)':>16}")
    for r in rows:
        if "error" in r:
            print(f"{r['K']:>4} {r['scheme']:>8}  {r['error']}")
            continue
        print(f"{r['K']:>4} {r['scheme']:>8} {r['mode']:>9} "
              f"{r['max_staleness_mean']:>16.2f} {r['max_staleness_worst']:>17d} "
              f"{r['avg_staleness_mean']:>16.2f}")

    by = {(r["K"], r["scheme"], r.get("mode")): r for r in rows}
    for k in args.k:
        a = by.get((k, "kkt_sai", "adaptive"))
        s = by.get((k, "kkt_sai", "static"))
        if a and s and s["max_staleness_mean"] > 0:
            gain = s["max_staleness_mean"] - a["max_staleness_mean"]
            print(f"# K={k}: adaptive KKT removes {gain:.2f} mean max-staleness "
                  f"vs the frozen allocation")

    if args.train:
        print("\n# fused in-scan reallocation (one XLA program, "
              "per-cycle KKT re-solve on traced capacities)")
        res = run_experiment(
            k=min(args.k), T=15.0, cycles=6, total_samples=1200,
            seed=args.seed, reallocate=True, drift=drift, fused=True,
        )
        for h in res["history"]:
            print(f"cycle {h['cycle']}: tau={np.asarray(h['tau'])} "
                  f"max_staleness={h['max_staleness']} acc={h['accuracy']:.3f}")


if __name__ == "__main__":
    main()
