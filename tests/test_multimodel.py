"""Multi-tenant scheduler (``fed.multimodel`` + the cross-model allocation
layer in ``core.solver_batched``).

Pins the subsystem's acceptance contracts:
  * S = 1 ``MultiModelEngine`` reproduces ``AsyncFedEngine`` record for
    record (versions / weights / staleness / times bitwise, params to
    float tolerance) under faults, drift and availability alike — and
    via the barrier regime, ``Orchestrator.run`` bitwise;
  * the cross-model split never over-commits a learner: summed time (and
    joule) commitments across the S tenants stay within the single-tenant
    budgets, for every split policy, staleness discount and fault mix;
  * the split is permutation-equivariant across models and monotone in
    each model's own deficit — and reads ONLY version deficits (model-
    value-free), so schedules stay bit-reproducible.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import QueueDrift
from repro.core.availability import MarkovAvailability
from repro.core.solver_batched import (
    batched_policy,
    cross_model_weights,
    multimodel_policy,
)
from repro.data.pipeline import synthetic_mnist
from repro.fed.async_engine import AsyncConfig, AsyncFedEngine
from repro.fed.fleet import FleetConfig, FleetEngine, build_fleet_problems
from repro.fed.multimodel import MultiModelEngine, solve_multimodel_rows
from repro.fed.orchestrator import MELConfig, Orchestrator
from repro.fed.simulation import build_energy_problem, build_problem
from repro.models import mlp

from tests._prop import given, settings, st


@pytest.fixture(scope="module")
def data():
    return synthetic_mnist(1500, n_test=300, seed=0)


def _assert_trees_equal(a, b, **kw):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if kw:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_history_match(h1, h2):
    assert len(h1) == len(h2)
    for r1, r2 in zip(h1, h2):
        assert r1["learners"] == r2["learners"]
        assert r1["staleness_list"] == r2["staleness_list"]
        assert r1["server_version"] == r2["server_version"]
        assert r1["keep"] == r2["keep"]
        assert r1["t"] == r2["t"]
        np.testing.assert_array_equal(r1["weights"], r2["weights"])
        np.testing.assert_array_equal(r1["tau"], r2["tau"])
        np.testing.assert_array_equal(r1["d"], r2["d"])


def _run_pair(cfg, prob, train, horizon, *, seed=2, drift=None):
    """(AsyncFedEngine history, S=1 MultiModelEngine history) plus both
    engines, from identical seeds and init params."""
    p1 = mlp.init(jax.random.key(1))
    e1 = AsyncFedEngine(cfg, prob, mlp.loss, p1, seed=seed, drift=drift)
    h1 = e1.run(train, horizon)
    p2 = mlp.init(jax.random.key(1))
    e2 = MultiModelEngine(cfg, [prob], mlp.loss, p2, seed=seed, drift=drift)
    h2 = e2.run(train, horizon)[0]
    return e1, h1, e2, h2


# ---------------------------------------------------------------------------
# S = 1: the single-tenant engine is a fixed point (acceptance anchor)
# ---------------------------------------------------------------------------

def test_s1_matches_async_engine_fedasync_with_faults(data):
    train, _ = data
    prob = build_problem(3, 6.0, total_samples=60, seed=0)
    cfg = AsyncConfig(mode="fedasync", alpha=0.5, staleness_fn="poly",
                      drop_rate=0.2, delay_rate=0.3, straggler_rate=0.2,
                      deadline=15.0)
    e1, h1, e2, h2 = _run_pair(cfg, prob, train, 30.0)
    _assert_history_match(h1, h2)
    _assert_trees_equal(e1.params, e2.params[0], rtol=1e-6, atol=1e-6)
    assert e1.fault_counters == e2.fault_counters


def test_s1_matches_async_engine_buffered_quorum(data):
    train, _ = data
    prob = build_problem(3, 6.0, total_samples=60, seed=0)
    cfg = AsyncConfig(mode="buffered", buffer_size=3, quorum=2,
                      flush_timeout=4.0, delay_rate=0.3,
                      aggregation="staleness")
    e1, h1, e2, h2 = _run_pair(cfg, prob, train, 30.0)
    _assert_history_match(h1, h2)
    assert e1.fault_counters == e2.fault_counters


def test_s1_matches_async_engine_under_availability(data):
    """The churn anchors: adaptive per-block masked re-solves AND the
    frozen-allocation regime both reproduce the single-model engine."""
    train, _ = data
    prob = build_problem(3, 6.0, total_samples=60, seed=0)
    av = MarkovAvailability(p_drop=0.3, p_join=0.6, seed=5)
    for realloc in (True, False):
        cfg = AsyncConfig(mode="fedasync", alpha=0.5, reallocate=realloc)
        e1, h1, e2, h2 = _run_pair(cfg, prob, train, 30.0, drift=av)
        _assert_history_match(h1, h2)
        assert e1.fault_counters == e2.fault_counters


def test_s1_matches_async_engine_energy_ledger(data):
    """With an EnergyModel attached, the per-learner joule ledger (charged
    at dispatch) matches the single-model engine bitwise."""
    train, _ = data
    prob = build_energy_problem(3, 8.0, total_samples=120, seed=0)
    cfg = AsyncConfig(mode="fedasync", alpha=0.5)
    e1, h1, e2, h2 = _run_pair(cfg, prob, train, 40.0)
    _assert_history_match(h1, h2)
    np.testing.assert_array_equal(
        e1.energy_ledger["per_learner"], e2.energy_ledger["per_learner"]
    )
    assert e1.energy_ledger["violations"] == e2.energy_ledger["violations"]


def test_s1_barrier_matches_orchestrator_bitwise(data):
    """PINNED: barrier + M = K at S = 1 IS the paper scheme — tau/d and
    the aggregated params reproduce ``Orchestrator.run`` bitwise."""
    train, _ = data
    prob = build_problem(3, 6.0, total_samples=60, seed=0)
    p0 = mlp.init(jax.random.key(1))
    orch = Orchestrator(MELConfig(T=6.0, total_samples=60), prob,
                        mlp.loss, p0, seed=7)
    ho = orch.run(train, 4)
    p1 = mlp.init(jax.random.key(1))
    eng = MultiModelEngine(
        AsyncConfig(mode="buffered", barrier=True, aggregation="staleness"),
        [prob], mlp.loss, p1, seed=7,
    )
    hm = eng.run(train, cycles=4)[0]
    assert len(ho) == len(hm) == 4
    for ro, rm in zip(ho, hm):
        np.testing.assert_array_equal(ro["tau"], rm["tau"])
        np.testing.assert_array_equal(ro["d"], rm["d"])
        assert ro["max_staleness"] == rm["max_staleness"]
        assert ro["avg_staleness"] == rm["avg_staleness"]
    _assert_trees_equal(orch.params, eng.params[0])


def test_s1_run_events_matches_run(data):
    train, _ = data
    prob = build_problem(3, 6.0, total_samples=60, seed=0)
    cfg = AsyncConfig(mode="buffered", buffer_size=2,
                      aggregation="staleness", delay_rate=0.3)
    p1 = mlp.init(jax.random.key(1))
    e1 = AsyncFedEngine(cfg, prob, mlp.loss, p1, seed=3)
    h1 = e1.run_events(train, 30.0)
    p2 = mlp.init(jax.random.key(1))
    e2 = MultiModelEngine(cfg, [prob], mlp.loss, p2, seed=3)
    h2 = e2.run_events(train, 30.0)[0]
    _assert_history_match(h1, h2)
    _assert_trees_equal(e1.params, e2.params[0], rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20), s=st.integers(1, 4))
def test_s1_policy_is_static_passthrough(seed, s):
    """At S = 1 ``multimodel_policy`` hands the base ``batched_policy``
    bitwise-identical operands (no mask, no scaling); at S > 1 with all-
    zero deficits the equal and deficit splits coincide."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 7))
    with enable_x64():
        c2 = jnp.asarray(rng.uniform(1e-4, 5e-3, (1, k)))
        c1 = jnp.asarray(rng.uniform(1e-5, 1e-3, (1, k)))
        c0 = jnp.asarray(rng.uniform(0.05, 0.3, (1, k)))
        lo = jnp.full((1, k), 5.0)
        hi = jnp.full((1, k), 200.0)
        T = jnp.asarray([float(np.max(np.asarray(c0)) + 8.0)])
        total = jnp.asarray([40 * k], jnp.int64)
        valid = jnp.ones((1, k), bool)
        base = batched_policy("kkt_sai")
        mm = multimodel_policy("kkt_sai", split="deficit")
        t0, d0, ok0 = base(c2, c1, c0, T, total, lo, hi, valid)
        t1, d1, ok1, w = mm(jnp.zeros(1), c2, c1, c0, T, total, lo, hi, valid)
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
        assert np.asarray(w).tolist() == [1.0]
        if s > 1:
            tile = lambda a: jnp.tile(a, (s,) + (1,) * (a.ndim - 1))
            args = (tile(c2), tile(c1), tile(c0), tile(T), tile(total),
                    tile(lo), tile(hi), tile(valid))
            te, de, _, we = multimodel_policy("kkt_sai", split="equal")(
                jnp.zeros(s), *args)
            td, dd, _, wd = multimodel_policy("kkt_sai", split="deficit")(
                jnp.zeros(s), *args)
            np.testing.assert_array_equal(np.asarray(we), np.asarray(wd))
            np.testing.assert_array_equal(np.asarray(te), np.asarray(td))
            np.testing.assert_array_equal(np.asarray(de), np.asarray(dd))


# ---------------------------------------------------------------------------
# budget partition: no learner is ever over-committed
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**20),
    s=st.integers(2, 4),
    split=st.sampled_from(["deficit", "equal"]),
    scheme=st.sampled_from(["kkt_sai", "kkt_energy"]),
)
def test_split_never_overcommits_a_learner(seed, s, split, scheme):
    """Summed per-learner time cost across the S tenants <= T, and summed
    joules <= e_budget (energy scheme), for random deficits and fleets."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 6))
    T = 10.0
    builder = build_energy_problem if scheme == "kkt_energy" else build_problem
    kw = {"e_budget": 6.0} if scheme == "kkt_energy" else {}
    probs = [
        builder(k, T, total_samples=int(rng.integers(40, 200)),
                seed=int(rng.integers(100)), **kw)
        for _ in range(s)
    ]
    # shared fleet: every tenant sees model 0's capacities
    tm = probs[0].time_model
    probs = [
        type(p)(time_model=tm, T=p.T, total_samples=p.total_samples,
                d_lower=p.d_lower, d_upper=p.d_upper,
                energy=probs[0].energy, e_budget=p.e_budget)
        for p in probs
    ]
    deficits = rng.uniform(0.0, 5.0, s)
    tau, d, w = solve_multimodel_rows(
        scheme, tm.c2.astype(np.float64), tm.c1.astype(np.float64),
        tm.c0.astype(np.float64), probs, deficits, split=split,
        label="property",
    )
    assert float(np.asarray(w).sum()) <= 1.0
    on = (d > 0).astype(np.float64)
    cost = (tm.c2[None] * tau * d + tm.c1[None] * d + tm.c0[None] * on)
    assert (cost.sum(axis=0) <= T * (1 + 1e-9)).all()
    if scheme == "kkt_energy":
        e2, e1, e0, eb = probs[0].energy_rows()
        joules = (e2[None] * tau * d + e1[None] * d + e0[None] * on)
        assert (joules.sum(axis=0) <= eb * (1 + 1e-9)).all()


# ---------------------------------------------------------------------------
# split-weight laws: equivariance, monotonicity, grid exactness
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20), s=st.integers(2, 6),
       floor=st.floats(0.0, 0.15))
def test_split_weights_laws(seed, s, floor):
    rng = np.random.default_rng(seed)
    deficits = rng.uniform(0.0, 10.0, s)
    with enable_x64():
        w = np.asarray(cross_model_weights(
            jnp.asarray(deficits), policy="deficit", share_floor=floor))
        # sum exactly representable and <= 1 (2^-20 grid floor)
        assert w.sum() <= 1.0
        # permutation equivariance
        perm = rng.permutation(s)
        wp = np.asarray(cross_model_weights(
            jnp.asarray(deficits[perm]), policy="deficit",
            share_floor=floor))
        np.testing.assert_array_equal(wp, w[perm])
        # monotone in own deficit
        j = int(rng.integers(s))
        bumped = deficits.copy()
        bumped[j] += rng.uniform(0.5, 3.0)
        wb = np.asarray(cross_model_weights(
            jnp.asarray(bumped), policy="deficit", share_floor=floor))
        assert wb[j] >= w[j]
        # floor honored
        if floor > 0:
            grid_floor = np.floor(floor * 2**20) / 2**20
            assert (w >= grid_floor - 2**-20).all()


def test_engine_schedule_is_permutation_equivariant(data):
    """Permuting the tenant models (same engine seed... per-model
    partitioner seeds are drawn in model order, so permute the SAME seed
    set) permutes the schedules: the event system reads only deficits,
    never which slot a model sits in."""
    train, _ = data
    probs = [build_problem(3, 6.0, total_samples=t, seed=0)
             for t in (60, 60, 180)]
    cfg = AsyncConfig(mode="fedasync", alpha=0.5)
    params = tuple(mlp.init(jax.random.key(i)) for i in range(3))
    perm = [2, 0, 1]

    e1 = MultiModelEngine(cfg, probs, mlp.loss, params, seed=2)
    h1 = e1.run([train] * 3, 60.0)
    e2 = MultiModelEngine(cfg, [probs[i] for i in perm], mlp.loss,
                          tuple(params[i] for i in perm), seed=2)
    h2 = e2.run([train] * 3, 60.0)
    # model at permuted slot i is original model perm[i]: its schedule
    # (times, allocations, versions) must transfer — shard draws differ
    # (partitioner seeds are drawn in slot order), so params may not
    for i, src in enumerate(perm):
        ha, hb = h1[src], h2[i]
        assert len(ha) == len(hb)
        for ra, rb in zip(ha, hb):
            assert ra["t"] == rb["t"]
            assert ra["server_version"] == rb["server_version"]
            np.testing.assert_array_equal(ra["tau"], rb["tau"])
            np.testing.assert_array_equal(ra["d"], rb["d"])


# ---------------------------------------------------------------------------
# S > 1 behavior: deficit feedback and validation surface
# ---------------------------------------------------------------------------

def test_deficit_split_self_balances_versions(data):
    """A tenant with 3x the per-round samples completes rounds slower;
    the deficit split must keep final versions close (the FedAST goal),
    where the equal split lets the fast tenants run away."""
    train, _ = data
    probs = [build_problem(3, 6.0, total_samples=t, seed=0)
             for t in (60, 60, 180)]
    cfg = AsyncConfig(mode="fedasync", alpha=0.5)
    params = tuple(mlp.init(jax.random.key(i)) for i in range(3))
    eng = MultiModelEngine(cfg, probs, mlp.loss, params, seed=2,
                           split="deficit")
    hs = eng.run([train] * 3, 60.0)
    vers = np.array([h[-1]["server_version"] for h in hs])
    assert vers.min() > 0
    assert vers.max() - vers.min() <= 3
    # the split layer logged deficit-driven (non-uniform) weights
    w_log = np.stack(eng.split_weight_log)
    assert (np.abs(w_log - w_log[:, :1]) > 1e-6).any()


def test_multimodel_run_events_matches_run(data):
    """The S = 3 device-resident replay matches the eager replay on the
    SAME schedule (histories bitwise, params to float tolerance)."""
    train, _ = data
    probs = [build_problem(3, 6.0, total_samples=t, seed=0)
             for t in (60, 120)]
    cfg = AsyncConfig(mode="buffered", buffer_size=2,
                      aggregation="staleness")
    params = tuple(mlp.init(jax.random.key(i)) for i in range(2))
    e1 = MultiModelEngine(cfg, probs, mlp.loss, params, seed=4)
    h1 = e1.run([train] * 2, 40.0)
    e2 = MultiModelEngine(cfg, probs, mlp.loss, params, seed=4)
    h2 = e2.run_events([train] * 2, 40.0)
    for ha, hb, pa, pb in zip(h1, h2, e1.params, e2.params):
        _assert_history_match(ha, hb)
        _assert_trees_equal(pa, pb, rtol=1e-6, atol=1e-6)


def test_validation_surface():
    prob = build_problem(3, 6.0, total_samples=60, seed=0)
    p = mlp.init(jax.random.key(0))
    # scheduler-level knobs must agree
    with pytest.raises(ValueError, match="scheduler-level"):
        MultiModelEngine(
            [AsyncConfig(mode="fedasync", alpha=0.5),
             AsyncConfig(mode="fedasync", alpha=0.5, scheme="eta")],
            [prob, prob], mlp.loss, p,
        )
    # per-model server knobs may differ
    eng = MultiModelEngine(
        [AsyncConfig(mode="fedasync", alpha=0.5),
         AsyncConfig(mode="buffered", buffer_size=2)],
        [prob, prob], mlp.loss, p,
    )
    assert eng.num_models == 2
    # one physical fleet: K and T must match
    other = build_problem(4, 6.0, total_samples=60, seed=0)
    with pytest.raises(ValueError, match="physical fleet"):
        MultiModelEngine(AsyncConfig(), [prob, other], mlp.loss, p)
    # ... and so must the TimeModel coefficients
    different = build_problem(3, 6.0, total_samples=60, seed=9)
    with pytest.raises(ValueError, match="TimeModel"):
        MultiModelEngine(AsyncConfig(), [prob, different], mlp.loss, p)
    # per-model params tuple must have S entries
    with pytest.raises(ValueError, match="per-model pytrees"):
        MultiModelEngine(AsyncConfig(), [prob, prob], mlp.loss, (p,))
    # state-coupled drift has no S > 1 rollout
    with pytest.raises(ValueError, match="state-coupled"):
        MultiModelEngine(
            AsyncConfig(reallocate=True), [prob, prob], mlp.loss, p,
            drift=QueueDrift(),
        )
    # unknown split policy
    with pytest.raises(ValueError, match="split"):
        MultiModelEngine(AsyncConfig(), [prob], mlp.loss, p, split="greedy")


# ---------------------------------------------------------------------------
# fleet-scale face
# ---------------------------------------------------------------------------

def test_fleet_solve_multimodel():
    bp = build_fleet_problems(3, k=4, T=6.0, total_samples=80, seed=0)
    eng = FleetEngine(FleetConfig(), bp, mlp.loss,
                      mlp.init(jax.random.key(0)), seed=0)
    # S = 1 short-circuits to the single-tenant solve bitwise
    t1, d1, w1 = eng.solve_multimodel(np.zeros(1))
    t0, d0 = eng._solve(eng._real)
    np.testing.assert_array_equal(t1[0], t0)
    np.testing.assert_array_equal(d1[0], d0)
    assert w1.tolist() == [1.0]
    # S = 3: per-learner summed commitment within every fleet's deadline
    t3, d3, w3 = eng.solve_multimodel(np.array([2.0, 1.0, 0.0]))
    assert t3.shape == (3,) + t0.shape
    f = bp.num_problems
    on = (d3[:, :f] > 0).astype(np.float64)
    cost = (bp.c2[None] * t3[:, :f] * d3[:, :f] + bp.c1[None] * d3[:, :f]
            + bp.c0[None] * on).sum(axis=0)
    assert (cost <= bp.T[None].T * (1 + 1e-9)).all()
    # zero-deficit tenant yields the pool to the laggards
    totals = d3[:, :f].sum(axis=(1, 2))
    assert totals[0] >= totals[2]
