"""Energy-constrained allocation (``core/energy.py`` + the ``kkt_energy``
pipeline): model construction, the infinite-budget equivalence to
``kkt_sai`` (architecture invariant 7), budget satisfaction by
construction across every solve path, feasible-or-degraded affordability
masking, ``BatteryDrift`` charge dynamics, the async joule ledger, and
the ``-O``-proof ``Allocation.validate`` rejection surface."""

import dataclasses
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (
    AllocationProblem,
    BatchedProblems,
    BatteryDrift,
    EnergyModel,
    TimeModel,
    batched_policy,
    indoor_80211_profile,
    solve_energy_batched,
    solve_kkt_batched,
    solve_kkt_energy,
    solve_kkt_sai,
)
from repro.data.pipeline import synthetic_mnist
from repro.fed.async_engine import (
    AsyncConfig,
    AsyncFedEngine,
    summarize_async_history,
)
from repro.fed.orchestrator import solve_policy_row, solve_rows_availability
from repro.models import mlp

K = 4


def _models(k: int = K, seed: int = 0):
    profiles = indoor_80211_profile(k, seed=seed)
    tm = TimeModel.build(profiles, model_complexity_flops=1e6,
                         model_size_bits=8e6)
    em = EnergyModel.build(profiles, model_complexity_flops=1e6,
                           model_size_bits=8e6)
    return tm, em


def _prob(e_budget=None, *, total: int = 200, T: float = 5.0, seed: int = 0):
    tm, em = _models(seed=seed)
    return AllocationProblem(
        time_model=tm, T=T, total_samples=total, d_lower=10, d_upper=100,
        energy=em, e_budget=e_budget,
    )


def _energy(prob, alloc):
    return prob.energy.cycle_energy(alloc.tau, alloc.d)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

def test_energy_model_shape_and_idle_cost():
    _, em = _models()
    tau = np.array([3, 1, 2, 4]); d = np.array([30, 20, 0, 25])
    e = em.cycle_energy(tau, d)
    assert e.shape == (K,)
    assert e[2] == 0.0                       # idle learner spends nothing
    assert np.all(e[d > 0] >= em.min_dispatch_energy()[d > 0] * (1 - 1e-12))
    # rows: f64, broadcast scalar budget, +inf default
    e2, e1, e0, eb = em.rows(e_budget=3.0)
    assert all(a.dtype == np.float64 for a in (e2, e1, e0, eb))
    np.testing.assert_array_equal(eb, np.full(K, 3.0))
    assert np.isinf(em.rows()[3]).all()


# ---------------------------------------------------------------------------
# invariant 7: infinite budget == kkt_sai, decision for decision
# ---------------------------------------------------------------------------

def test_infinite_budget_reproduces_kkt_sai_everywhere():
    """Pinned: eb = inf is a bitwise no-op through the NumPy reference,
    the batched program AND the traced policy."""
    for seed in range(4):
        prob = _prob(seed=seed)
        free = dataclasses.replace(prob, e_budget=np.inf)
        ref = solve_kkt_sai(prob)

        a_np = solve_kkt_energy(free)
        np.testing.assert_array_equal(a_np.tau, ref.tau)
        np.testing.assert_array_equal(a_np.d, ref.d)

        bp = BatchedProblems.from_problems([free])
        ba = solve_energy_batched(bp)
        np.testing.assert_array_equal(ba.tau[0], ref.tau)
        np.testing.assert_array_equal(ba.d[0], ref.d)
        ref_b = solve_kkt_batched(BatchedProblems.from_problems([prob]))
        np.testing.assert_array_equal(ba.tau, ref_b.tau)
        np.testing.assert_array_equal(ba.d, ref_b.d)

        with enable_x64():
            args = tuple(jnp.asarray(a) for a in (
                bp.c2, bp.c1, bp.c0, bp.T, bp.total,
                bp.d_lo, bp.d_hi, bp.valid,
            ))
            en = tuple(jnp.asarray(r) for r in bp.energy_rows())
            tau_t, d_t, feas = batched_policy("kkt_energy")(*args, en)
        np.testing.assert_array_equal(np.asarray(tau_t[0]), ref.tau)
        np.testing.assert_array_equal(np.asarray(d_t[0]), ref.d)
        assert bool(feas[0])


# ---------------------------------------------------------------------------
# finite budgets: satisfaction by construction, blind schemes violate
# ---------------------------------------------------------------------------

def test_budget_satisfied_by_construction_and_blind_violates():
    prob = _prob()
    blind = solve_kkt_sai(prob)
    e_blind = _energy(prob, blind)
    eb = 0.8 * float(np.median(e_blind))    # tight: blind must overdraw
    assert (e_blind > eb).any()

    tight = dataclasses.replace(prob, e_budget=eb)
    alloc = solve_kkt_energy(tight)
    assert np.all(_energy(prob, alloc) <= eb * (1 + 1e-9))
    alloc.validate(tight)                    # strict check passes

    ba = solve_energy_batched(BatchedProblems.from_problems([tight]))
    np.testing.assert_array_equal(ba.tau[0], alloc.tau)
    np.testing.assert_array_equal(ba.d[0], alloc.d)

    # the traced policy row used by the orchestrator/async re-solves
    tm = prob.time_model
    tau_r, d_r = solve_policy_row(
        "kkt_energy", tm.c2, tm.c1, tm.c0, tight, label="test row",
    )
    np.testing.assert_array_equal(tau_r, alloc.tau)
    np.testing.assert_array_equal(d_r, alloc.d)


def test_validate_rejects_overdrawn_allocation():
    prob = _prob()
    blind = solve_kkt_sai(prob)
    eb = 0.8 * float(np.median(_energy(prob, blind)))
    tight = dataclasses.replace(prob, e_budget=eb)
    with pytest.raises(ValueError, match="energy budget violated"):
        blind.validate(tight)
    # ... which is why energy-blind schemes cannot SOLVE a strict
    # budgeted problem at all (their own self-validation trips)
    with pytest.raises(ValueError, match="energy budget violated"):
        solve_kkt_sai(tight)


def test_validate_raises_under_dash_O_semantics():
    """Satellite regression: ``Allocation.validate`` must reject garbage
    through ValueErrors, not bare asserts — ``python -O`` strips asserts,
    so each check is exercised in an optimized subprocess."""
    code = """
import numpy as np
from repro.core import AllocationProblem, TimeModel
from repro.core.allocation import Allocation

tm = TimeModel(c2=np.full(3, 0.04), c1=np.full(3, 0.004), c0=np.full(3, 0.4))
prob = AllocationProblem(time_model=tm, T=6.0, total_samples=60,
                         d_lower=10, d_upper=40)
bad = [
    Allocation(tau=np.array([1, 1]), d=np.array([20, 20])),          # shape
    Allocation(tau=np.array([1, 1, 1]), d=np.array([20, 20, 21])),   # sum
    Allocation(tau=np.array([1, 1, 1]), d=np.array([5, 25, 30])),    # bounds
    Allocation(tau=np.array([-1, 1, 1]), d=np.array([20, 20, 20])),  # tau < 0
    Allocation(tau=np.array([99, 1, 1]), d=np.array([20, 20, 20])),  # deadline
]
n = 0
for a in bad:
    try:
        a.validate(prob)
    except ValueError:
        n += 1
assert __debug__ is False, "subprocess must run under -O"
print("caught", n)
"""
    out = subprocess.run(
        [sys.executable, "-O", "-c", code],
        capture_output=True, text=True, check=True,
    )
    assert "caught 5" in out.stdout


def test_feasible_or_degraded_affordability():
    """A learner whose budget cannot cover d_lower degrades to a padded
    slot; the sample budget clips into the surviving fleet's box."""
    prob = _prob()
    em = prob.energy
    # learner 0 cannot afford its minimal dispatch; the rest are free
    eb = np.full(K, np.inf)
    eb[0] = 0.5 * float(em.cycle_energy(
        np.ones(K, np.int64), np.full(K, prob.d_lower, np.int64))[0])
    alloc = solve_kkt_energy(dataclasses.replace(prob, e_budget=eb))
    assert alloc.tau[0] == 0 and alloc.d[0] == 0
    assert (alloc.d[1:] > 0).all()
    assert alloc.d.sum() <= prob.total_samples
    # all-unaffordable: everything degrades to zeros, no raise
    starved = solve_kkt_energy(dataclasses.replace(
        prob, e_budget=0.25 * em.min_dispatch_energy().min()))
    assert (starved.tau == 0).all() and (starved.d == 0).all()


def test_kkt_energy_pallas_requires_f32():
    bp = BatchedProblems.from_problems([_prob(e_budget=5.0)])
    with pytest.raises(ValueError, match="x64=False"):
        solve_energy_batched(bp, use_pallas=True)


def test_kkt_energy_pallas_interpret_matches_reference():
    """The Pallas residual kernel behind ``use_pallas=True`` lands on the
    same integer decisions as the jnp f32 reference (interpret mode)."""
    probs = [_prob(e_budget=5.0, seed=s) for s in range(3)]
    bp = BatchedProblems.from_problems(probs)
    ref = solve_energy_batched(bp, x64=False)
    pal = solve_energy_batched(bp, x64=False, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(pal.tau, ref.tau)
    np.testing.assert_array_equal(pal.d, ref.d)
    np.testing.assert_array_equal(pal.feasible, ref.feasible)


# ---------------------------------------------------------------------------
# BatteryDrift
# ---------------------------------------------------------------------------

def test_battery_drift_dynamics_and_determinism():
    _, em = _models()
    bd = BatteryDrift(energy=em, capacity_j=10.0, recharge_j=1.0,
                      p_plugged=0.5, seed=3)
    state = bd.state_init(K)
    assert np.allclose(np.asarray(state), 10.0)
    tau = jnp.asarray(np.full(K, 2, np.int64))
    d = jnp.asarray(np.array([30, 0, 20, 25], np.int64))
    drained = bd.state_update(0, state, tau=tau, d=d)
    cost = em.cycle_energy(np.asarray(tau), np.asarray(d))
    # idle learner only recharges; busy learners drain their joule cost
    assert float(np.asarray(drained)[1]) >= 10.0 - 1e-6
    assert np.all(np.asarray(drained) >= -1e-6)
    assert np.all(np.asarray(drained) <= 10.0 + 1e-6)
    spent = 10.0 - np.asarray(drained, np.float64)
    assert np.all(spent[cost > 0] <= cost[cost > 0] + 1e-5)
    # deterministic per (seed, cycle)
    again = bd.state_update(0, bd.state_init(K), tau=tau, d=d)
    np.testing.assert_array_equal(np.asarray(drained), np.asarray(again))
    # flat battery = offline; full battery = online
    assert not bool(np.asarray(
        bd.online_at(1, K, jnp.zeros((K,), jnp.float32))).any())
    assert bool(np.asarray(bd.online_at(1, K, state)).all())
    # budget_at exposes the charge as the per-dispatch solve cap (f64)
    b = bd.budget_at(1, K, drained)
    assert b.dtype == np.float64
    np.testing.assert_allclose(b, np.asarray(drained, np.float64))


def test_battery_rollout_never_overdraws_the_charge():
    prob = _prob(total=120)
    bd = BatteryDrift(energy=prob.energy, capacity_j=7.0, recharge_j=0.8,
                      p_plugged=0.5, seed=11)
    _, (taus, ds), masks = solve_rows_availability(
        "kkt_energy", bd, prob, 10, label="cycle {}")
    assert (ds[~masks] == 0).all() and (taus[~masks] == 0).all()
    state = bd.state_init(K)
    for c in range(10):
        charge = np.asarray(state, np.float64)
        cost = prob.energy.cycle_energy(taus[c], ds[c])
        assert np.all(cost <= charge * (1 + 1e-6) + 1e-9), (c, cost, charge)
        state = bd.state_update(c, state, tau=jnp.asarray(taus[c]),
                                d=jnp.asarray(ds[c]))


# ---------------------------------------------------------------------------
# async energy accounting: the seeded property sweep
# ---------------------------------------------------------------------------

def _async_cfg(mode: str):
    if mode == "cycle":
        return AsyncConfig(mode="buffered", barrier=True, scheme="kkt_energy")
    if mode == "buffered":
        return AsyncConfig(mode="buffered", buffer_size=2,
                           scheme="kkt_energy", reallocate=True)
    return AsyncConfig(mode="fedasync", scheme="kkt_energy", reallocate=True)


@pytest.mark.parametrize("mode", ["fedasync", "buffered", "cycle"])
@pytest.mark.parametrize("budget_frac", [0.6, 1.0, np.inf])
@pytest.mark.parametrize("battery", [False, True])
def test_async_sweep_zero_violations(mode, budget_frac, battery):
    """budgets x drift x async modes: every dispatched task fits its
    budget (ledger violations == 0) while the fleet stays
    feasible-or-degraded — no cell may stall or raise."""
    prob0 = _prob(total=120, T=5.0)
    blind = solve_kkt_sai(prob0)
    eb = (np.inf if np.isinf(budget_frac)
          else float(budget_frac) * float(np.median(_energy(prob0, blind))))
    prob = dataclasses.replace(prob0, e_budget=eb)
    drift = (BatteryDrift(energy=prob0.energy, capacity_j=8.0,
                          recharge_j=1.0, p_plugged=0.5, seed=5)
             if battery else None)
    if battery and mode == "cycle":
        pytest.skip("the barrier regime is the fault-free paper path")
    train, _ = synthetic_mnist(1200, n_test=10, seed=0)
    params = mlp.init(jax.random.key(1))
    eng = AsyncFedEngine(_async_cfg(mode), prob, mlp.loss, params,
                         seed=7, drift=drift)
    if mode == "cycle":
        history = eng.run(train, cycles=3)
    else:
        history = eng.run(train, 3 * prob.T)
    s = summarize_async_history(history, counters=eng.fault_counters,
                                energy=eng.energy_ledger)
    assert s["energy"]["violations"] == 0
    assert s["aggregations"] > 0              # degraded, never dead
    assert s["energy"]["joules_total"] > 0
    # the ledger meters at DISPATCH (in-flight and dropped uploads burned
    # their joules too), so it bounds the flushed-history total from above
    per = np.asarray(s["energy"]["per_learner"])
    assert per.shape == (K,)
    assert per.sum() >= s["energy"]["joules_total"] * (1 - 1e-9)
    # replay every metered dispatch against the static budget
    if not battery and np.isfinite(eb):
        for rec in history:
            e = np.atleast_1d(rec.get("energy", []))
            assert np.all(e <= eb * (1 + 1e-9))


def test_async_ledger_eager_matches_jagged():
    prob = _prob(total=120, e_budget=6.0)
    train, _ = synthetic_mnist(1200, n_test=10, seed=0)
    params = mlp.init(jax.random.key(2))
    cfg = AsyncConfig(mode="buffered", buffer_size=2, scheme="kkt_energy",
                      reallocate=True)
    h_e = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=3).run(
        train, 2 * prob.T)
    h_j = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=3).run_events(
        train, 2 * prob.T)
    assert len(h_e) == len(h_j) > 0
    for r1, r2 in zip(h_e, h_j):
        np.testing.assert_array_equal(
            np.atleast_1d(r1["energy"]), np.atleast_1d(r2["energy"]))


def test_plain_problem_reports_zero_energy():
    tm, _ = _models()
    prob = AllocationProblem(time_model=tm, T=5.0, total_samples=120,
                             d_lower=10, d_upper=100)
    train, _ = synthetic_mnist(1200, n_test=10, seed=0)
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss,
                         mlp.init(jax.random.key(0)), seed=1)
    h = eng.run(train, prob.T)
    s = summarize_async_history(h, energy=eng.energy_ledger)
    assert s["energy"]["joules_total"] == 0.0
    assert s["energy"]["violations"] == 0
    np.testing.assert_array_equal(s["energy"]["per_learner"], np.zeros(K))
