"""Batched allocation engine: per-problem equivalence with the NumPy
KKT+SAI pipeline, Pallas water-filling residual parity, mixed-K padding,
and the fused scan-over-cycles orchestrator against the eager loop."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    AllocationProblem,
    BatchedProblems,
    TimeModel,
    batched_avg_staleness,
    batched_max_staleness,
    indoor_80211_profile,
    mnist_dnn_cost,
    pod_slice_profile,
    solve_eta,
    solve_eta_batched,
    solve_kkt_batched,
    solve_kkt_sai,
    solve_pgd_batched,
)
from repro.core.solver_kkt import solve_relaxed


def make_problem(k=10, T=15.0, d=6000, seed=0, profile="edge"):
    cost = mnist_dnn_cost()
    profs = (
        indoor_80211_profile(k, seed=seed)
        if profile == "edge"
        else pod_slice_profile(k, seed=seed)
    )
    tm = TimeModel.build(
        profs,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    return AllocationProblem(
        time_model=tm,
        T=T,
        total_samples=d,
        d_lower=max(1, d // (4 * k)),
        d_upper=min(d, 3 * d // k),
    )


def _random_feasible_problems(n=30):
    """Randomized feasible instances across fleet sizes, budgets, profiles."""
    rng = np.random.default_rng(42)
    probs = []
    while len(probs) < n:
        k = int(rng.integers(3, 14))
        T = float(rng.choice([5.0, 7.5, 15.0, 30.0]))
        d = int(rng.choice([2000, 4000, 6000]))
        profile = str(rng.choice(["edge", "pod"]))
        seed = int(rng.integers(0, 10_000))
        prob = make_problem(k=k, T=T, d=d, seed=seed, profile=profile)
        try:
            solve_relaxed(prob)  # keep only time-feasible instances
        except ValueError:
            continue
        probs.append(prob)
    return probs


# ---------------------------------------------------------------------------
# solve_kkt_batched vs per-problem solve_kkt_sai
# ---------------------------------------------------------------------------

def test_kkt_batched_matches_per_problem_randomized():
    """Per-problem (tau, d) exact match over randomized feasible instances.

    Documented tie-break tolerance: the batched residual reduction can
    differ from NumPy's pairwise sum by last-ulp noise, which may shift
    tau* within the bisection tolerance and flip a remainder tie; we allow
    at most 10% such problems, and they must still be feasible with the
    same max staleness and per-entry |delta d| <= 2.
    """
    probs = _random_feasible_problems(30)
    refs = [solve_kkt_sai(p) for p in probs]
    ba = solve_kkt_batched(probs)
    assert bool(ba.feasible.all())

    mismatched = 0
    for i, (p, ref) in enumerate(zip(probs, refs)):
        got = ba.allocation(i)
        got.validate(p)
        if np.array_equal(got.tau, ref.tau) and np.array_equal(got.d, ref.d):
            continue
        mismatched += 1
        assert int(got.tau.max() - got.tau.min()) == int(ref.tau.max() - ref.tau.min())
        assert np.abs(got.d - ref.d).max() <= 2
    assert mismatched <= len(probs) // 10, f"{mismatched} tie-break mismatches"


def test_kkt_batched_relaxed_matches_reference():
    probs = [make_problem(k=8, seed=s) for s in (0, 3, 7)]
    ba = solve_kkt_batched(probs)
    for i, p in enumerate(probs):
        tau_r, d_r, tau_star, _ = solve_relaxed(p)
        np.testing.assert_allclose(ba.relaxed_d[i, : p.num_learners], d_r, rtol=1e-8)
        np.testing.assert_allclose(ba.relaxed_tau[i, : p.num_learners], tau_r, rtol=1e-8)
        np.testing.assert_allclose(ba.tau_star[i], tau_star, rtol=1e-6)


def test_kkt_batched_mixed_fleet_sizes_padded():
    """Fleets of different K batch together via the valid mask."""
    probs = [make_problem(k=k, seed=k) for k in (4, 7, 11)]
    ba = solve_kkt_batched(probs)
    assert ba.tau.shape == (3, 11)
    for i, p in enumerate(probs):
        ref = solve_kkt_sai(p)
        got = ba.allocation(i)
        got.validate(p)
        np.testing.assert_array_equal(got.tau, ref.tau)
        np.testing.assert_array_equal(got.d, ref.d)
        # padded slots carry no work
        assert not ba.d[i, p.num_learners:].any()
        assert not ba.tau[i, p.num_learners:].any()


def test_kkt_batched_flags_infeasible():
    """A deadline too tight to absorb d is flagged, not silently solved,
    and does not poison the feasible problems sharing the batch."""
    ok = make_problem(k=6, T=15.0, d=2000)
    tm = ok.time_model
    bad = AllocationProblem(
        time_model=tm, T=float(np.max(tm.c0) * 1.01), total_samples=2000,
        d_lower=1, d_upper=2000,
    )
    with pytest.raises(ValueError):
        solve_relaxed(bad)
    ba = solve_kkt_batched([ok, bad])
    assert bool(ba.feasible[0]) and not bool(ba.feasible[1])
    ref = solve_kkt_sai(ok)
    np.testing.assert_array_equal(ba.allocation(0).tau, ref.tau)
    with pytest.raises(ValueError):
        ba.allocation(1)


def test_eta_batched_matches_per_problem():
    probs = [make_problem(k=k, T=7.5, seed=s) for k in (5, 9) for s in (0, 4)]
    be = solve_eta_batched(probs)
    for i, p in enumerate(probs):
        ref = solve_eta(p)
        got = be.allocation(i)
        got.validate(p)
        np.testing.assert_array_equal(got.tau, ref.tau)
        np.testing.assert_array_equal(got.d, ref.d)


def test_batched_staleness_metrics():
    tau = np.array([[3, 7, 5, 0], [2, 2, 2, 9]])
    valid = np.array([[True, True, True, False], [True, True, True, False]])
    np.testing.assert_array_equal(batched_max_staleness(tau, valid), [4, 0])
    np.testing.assert_allclose(
        batched_avg_staleness(tau, valid), [(4 + 2 + 2) / 3.0, 0.0]
    )


# ---------------------------------------------------------------------------
# Pallas water-filling residual kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k", [(4, 10), (8, 128), (13, 37), (3, 150)])
def test_waterfill_residual_pallas_parity(b, k):
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_residual_ref

    rng = np.random.default_rng(b * 100 + k)
    c2 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c1 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c0 = jnp.asarray(rng.uniform(0.1, 2.0, (b, k)), jnp.float32)
    T = jnp.asarray(rng.uniform(5.0, 20.0, (b,)), jnp.float32)
    lo = jnp.full((b, k), 10.0, jnp.float32)
    hi = jnp.full((b, k), 900.0, jnp.float32)
    tot = jnp.asarray(rng.uniform(1e3, 5e3, (b,)), jnp.float32)
    tau = jnp.asarray(rng.uniform(0.0, 50.0, (b,)), jnp.float32)

    want = waterfill_residual_ref(tau, c2, c1, c0, T, lo, hi, tot)
    got = ops.waterfill_residual(
        tau, c2, c1, c0, T, lo, hi, tot, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-3)


def test_kkt_batched_via_pallas_residual():
    """The full batched solve with every bisection step through the Pallas
    kernel (interpret mode, f32) stays feasible and near the f64 solution."""
    probs = [make_problem(k=6, T=15.0, d=2000, seed=s) for s in (0, 1)]
    ba64 = solve_kkt_batched(probs)
    ba32 = solve_kkt_batched(probs, x64=False, use_pallas=True, interpret=True)
    for i, p in enumerate(probs):
        got = ba32.allocation(i)
        got.validate(p)
        s64 = int(ba64.tau[i].max() - ba64.tau[i].min())
        s32 = int(ba32.tau[i, : p.num_learners].max() - ba32.tau[i, : p.num_learners].min())
        assert abs(s32 - s64) <= 1


# ---------------------------------------------------------------------------
# PGD routed through the BatchedProblems struct
# ---------------------------------------------------------------------------

def test_pgd_batched_struct_routing():
    probs = [make_problem(k=6, T=15.0, d=3000, seed=s) for s in range(4)]
    bp = BatchedProblems.from_problems(probs)
    tau, d = solve_pgd_batched(bp, steps=300)
    assert tau.shape == (4, 6) and d.shape == (4, 6)
    np.testing.assert_allclose(np.asarray(d.sum(1)), bp.total.astype(float), rtol=1e-3)
    assert np.all(np.asarray(d) >= bp.d_lo - 1e-3)
    assert np.all(np.asarray(d) <= bp.d_hi + 1e-3)
    # mixed-K batches are rejected, not silently mis-solved
    mixed = BatchedProblems.from_problems([probs[0], make_problem(k=4, seed=9)])
    with pytest.raises(ValueError):
        solve_pgd_batched(mixed)


# ---------------------------------------------------------------------------
# fused scan-over-cycles orchestrator vs eager loop
# ---------------------------------------------------------------------------

def test_fused_orchestrator_matches_eager_history():
    from repro.fed.simulation import run_experiment

    eager = run_experiment(k=4, T=15.0, cycles=3, total_samples=1200, seed=3)
    fused = run_experiment(k=4, T=15.0, cycles=3, total_samples=1200, seed=3,
                           fused=True)
    he, hf = eager["history"], fused["history"]
    assert len(he) == len(hf) == 3
    for re_, rf in zip(he, hf):
        np.testing.assert_array_equal(re_["tau"], rf["tau"])
        np.testing.assert_array_equal(re_["d"], rf["d"])
        assert re_["max_staleness"] == rf["max_staleness"]
        assert re_["cycle"] == rf["cycle"] and re_["elapsed_s"] == rf["elapsed_s"]
    np.testing.assert_allclose(
        [h["accuracy"] for h in he], [h["accuracy"] for h in hf], atol=1e-4
    )


def test_fused_orchestrator_rejects_reallocate():
    from repro.data.pipeline import synthetic_mnist
    from repro.fed.orchestrator import MELConfig, Orchestrator
    from repro.models import mlp

    train, _ = synthetic_mnist(2000, n_test=10, seed=0)
    prob = make_problem(k=4, T=15.0, d=1000)
    mel = MELConfig(T=15.0, total_samples=1000)
    orch = Orchestrator(mel, prob, mlp.loss, mlp.init(jax.random.key(0)))
    with pytest.raises(ValueError):
        orch.run(train, 2, fused=True, reallocate=True)


def test_batched_sweep_matches_eager_sweep():
    from repro.fed.simulation import staleness_sweep

    kw = dict(schemes=("kkt_sai", "eta"), seed=0, total_samples=4000)
    assert staleness_sweep([5, 8], 7.5, **kw) == staleness_sweep(
        [5, 8], 7.5, use_batched=False, **kw
    )
