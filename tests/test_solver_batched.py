"""Batched allocation engine: per-problem equivalence with the NumPy
KKT+SAI pipeline, Pallas water-filling residual parity, mixed-K padding,
and the fused scan-over-cycles orchestrator against the eager loop."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _prop import batched_problems, given, settings, st
from repro.core import (
    AllocationProblem,
    BatchedProblems,
    CapacityDrift,
    TimeModel,
    batched_avg_staleness,
    batched_max_staleness,
    indoor_80211_profile,
    mnist_dnn_cost,
    pod_slice_profile,
    solve_eta,
    solve_eta_batched,
    solve_kkt_batched,
    solve_kkt_sai,
    solve_pgd_batched,
)
from repro.core.solver_kkt import solve_relaxed


def make_problem(k=10, T=15.0, d=6000, seed=0, profile="edge"):
    cost = mnist_dnn_cost()
    profs = (
        indoor_80211_profile(k, seed=seed)
        if profile == "edge"
        else pod_slice_profile(k, seed=seed)
    )
    tm = TimeModel.build(
        profs,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    return AllocationProblem(
        time_model=tm,
        T=T,
        total_samples=d,
        d_lower=max(1, d // (4 * k)),
        d_upper=min(d, 3 * d // k),
    )


def _random_feasible_problems(n=30):
    """Randomized feasible instances across fleet sizes, budgets, profiles."""
    rng = np.random.default_rng(42)
    probs = []
    while len(probs) < n:
        k = int(rng.integers(3, 14))
        T = float(rng.choice([5.0, 7.5, 15.0, 30.0]))
        d = int(rng.choice([2000, 4000, 6000]))
        profile = str(rng.choice(["edge", "pod"]))
        seed = int(rng.integers(0, 10_000))
        prob = make_problem(k=k, T=T, d=d, seed=seed, profile=profile)
        try:
            solve_relaxed(prob)  # keep only time-feasible instances
        except ValueError:
            continue
        probs.append(prob)
    return probs


# ---------------------------------------------------------------------------
# solve_kkt_batched vs per-problem solve_kkt_sai
# ---------------------------------------------------------------------------

def test_kkt_batched_matches_per_problem_randomized():
    """Per-problem (tau, d) exact match over randomized feasible instances.

    Documented tie-break tolerance: the batched residual reduction can
    differ from NumPy's pairwise sum by last-ulp noise, which may shift
    tau* within the bisection tolerance and flip a remainder tie; we allow
    at most 10% such problems, and they must still be feasible with the
    same max staleness and per-entry |delta d| <= 2.
    """
    probs = _random_feasible_problems(30)
    refs = [solve_kkt_sai(p) for p in probs]
    ba = solve_kkt_batched(probs)
    assert bool(ba.feasible.all())

    mismatched = 0
    for i, (p, ref) in enumerate(zip(probs, refs)):
        got = ba.allocation(i)
        got.validate(p)
        if np.array_equal(got.tau, ref.tau) and np.array_equal(got.d, ref.d):
            continue
        mismatched += 1
        assert int(got.tau.max() - got.tau.min()) == int(ref.tau.max() - ref.tau.min())
        assert np.abs(got.d - ref.d).max() <= 2
    assert mismatched <= len(probs) // 10, f"{mismatched} tie-break mismatches"


@settings(max_examples=12, deadline=None)
@given(case=batched_problems())
def test_kkt_batched_property_mixed_degenerate(case):
    """Property: over mixed-K batches with degenerate (d_lo == d_hi) boxes
    and zero-capacity padded slots, every problem's batched solution matches
    the per-problem NumPy pipeline (same tie-break tolerance as the
    randomized equivalence test)."""
    probs, bp = case
    refs = [solve_kkt_sai(p) for p in probs]
    ba = solve_kkt_batched(bp)
    assert bool(ba.feasible.all())
    for i, (p, ref) in enumerate(zip(probs, refs)):
        got = ba.allocation(i)
        got.validate(p)
        # padded slots never carry work
        assert not ba.d[i, p.num_learners:].any()
        assert not ba.tau[i, p.num_learners:].any()
        if np.array_equal(got.tau, ref.tau) and np.array_equal(got.d, ref.d):
            continue
        assert int(got.tau.max() - got.tau.min()) == int(ref.tau.max() - ref.tau.min())
        assert np.abs(got.d - ref.d).max() <= 2


def test_kkt_batched_relaxed_matches_reference():
    probs = [make_problem(k=8, seed=s) for s in (0, 3, 7)]
    ba = solve_kkt_batched(probs)
    for i, p in enumerate(probs):
        tau_r, d_r, tau_star, _ = solve_relaxed(p)
        np.testing.assert_allclose(ba.relaxed_d[i, : p.num_learners], d_r, rtol=1e-8)
        np.testing.assert_allclose(ba.relaxed_tau[i, : p.num_learners], tau_r, rtol=1e-8)
        np.testing.assert_allclose(ba.tau_star[i], tau_star, rtol=1e-6)


def test_kkt_batched_mixed_fleet_sizes_padded():
    """Fleets of different K batch together via the valid mask."""
    probs = [make_problem(k=k, seed=k) for k in (4, 7, 11)]
    ba = solve_kkt_batched(probs)
    assert ba.tau.shape == (3, 11)
    for i, p in enumerate(probs):
        ref = solve_kkt_sai(p)
        got = ba.allocation(i)
        got.validate(p)
        np.testing.assert_array_equal(got.tau, ref.tau)
        np.testing.assert_array_equal(got.d, ref.d)
        # padded slots carry no work
        assert not ba.d[i, p.num_learners:].any()
        assert not ba.tau[i, p.num_learners:].any()


def test_kkt_batched_flags_infeasible():
    """A deadline too tight to absorb d is flagged, not silently solved,
    and does not poison the feasible problems sharing the batch."""
    ok = make_problem(k=6, T=15.0, d=2000)
    tm = ok.time_model
    bad = AllocationProblem(
        time_model=tm, T=float(np.max(tm.c0) * 1.01), total_samples=2000,
        d_lower=1, d_upper=2000,
    )
    with pytest.raises(ValueError):
        solve_relaxed(bad)
    ba = solve_kkt_batched([ok, bad])
    assert bool(ba.feasible[0]) and not bool(ba.feasible[1])
    ref = solve_kkt_sai(ok)
    np.testing.assert_array_equal(ba.allocation(0).tau, ref.tau)
    with pytest.raises(ValueError):
        ba.allocation(1)


def test_eta_batched_matches_per_problem():
    probs = [make_problem(k=k, T=7.5, seed=s) for k in (5, 9) for s in (0, 4)]
    be = solve_eta_batched(probs)
    for i, p in enumerate(probs):
        ref = solve_eta(p)
        got = be.allocation(i)
        got.validate(p)
        np.testing.assert_array_equal(got.tau, ref.tau)
        np.testing.assert_array_equal(got.d, ref.d)


def test_batched_staleness_metrics():
    tau = np.array([[3, 7, 5, 0], [2, 2, 2, 9]])
    valid = np.array([[True, True, True, False], [True, True, True, False]])
    np.testing.assert_array_equal(batched_max_staleness(tau, valid), [4, 0])
    np.testing.assert_allclose(
        batched_avg_staleness(tau, valid), [(4 + 2 + 2) / 3.0, 0.0]
    )


# ---------------------------------------------------------------------------
# Pallas water-filling residual kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k", [(4, 10), (8, 128), (13, 37), (3, 150)])
def test_waterfill_residual_pallas_parity(b, k):
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_residual_ref

    rng = np.random.default_rng(b * 100 + k)
    c2 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c1 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c0 = jnp.asarray(rng.uniform(0.1, 2.0, (b, k)), jnp.float32)
    T = jnp.asarray(rng.uniform(5.0, 20.0, (b,)), jnp.float32)
    lo = jnp.full((b, k), 10.0, jnp.float32)
    hi = jnp.full((b, k), 900.0, jnp.float32)
    tot = jnp.asarray(rng.uniform(1e3, 5e3, (b,)), jnp.float32)
    tau = jnp.asarray(rng.uniform(0.0, 50.0, (b,)), jnp.float32)

    want = waterfill_residual_ref(tau, c2, c1, c0, T, lo, hi, tot)
    got = ops.waterfill_residual(
        tau, c2, c1, c0, T, lo, hi, tot, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-3)


def test_kkt_batched_via_pallas_residual():
    """The full batched solve with every bisection step through the Pallas
    kernel (interpret mode, f32) stays feasible and near the f64 solution."""
    probs = [make_problem(k=6, T=15.0, d=2000, seed=s) for s in (0, 1)]
    ba64 = solve_kkt_batched(probs)
    ba32 = solve_kkt_batched(probs, x64=False, use_pallas=True, interpret=True)
    for i, p in enumerate(probs):
        got = ba32.allocation(i)
        got.validate(p)
        s64 = int(ba64.tau[i].max() - ba64.tau[i].min())
        s32 = int(ba32.tau[i, : p.num_learners].max() - ba32.tau[i, : p.num_learners].min())
        assert abs(s32 - s64) <= 1


# ---------------------------------------------------------------------------
# PGD routed through the BatchedProblems struct
# ---------------------------------------------------------------------------

def test_pgd_batched_struct_routing():
    probs = [make_problem(k=6, T=15.0, d=3000, seed=s) for s in range(4)]
    bp = BatchedProblems.from_problems(probs)
    tau, d = solve_pgd_batched(bp, steps=300)
    assert tau.shape == (4, 6) and d.shape == (4, 6)
    np.testing.assert_allclose(np.asarray(d.sum(1)), bp.total.astype(float), rtol=1e-3)
    assert np.all(np.asarray(d) >= bp.d_lo - 1e-3)
    assert np.all(np.asarray(d) <= bp.d_hi + 1e-3)


def test_pgd_batched_padded_mixed_k_regression():
    """Mixed-K padded batches solve exactly like their unpadded rows —
    regression for the pre-mask behavior where padded slots entered the
    smoothed staleness objective and the projection mass, silently skewing
    every real learner's d."""
    from repro.core.solver_numeric import _pgd_run

    small = make_problem(k=4, T=15.0, d=2000, seed=9)
    probs = [make_problem(k=6, T=15.0, d=3000, seed=0), small]
    bp = BatchedProblems.from_problems(probs)
    tau, d = solve_pgd_batched(bp, steps=300)
    tau, d = np.asarray(tau), np.asarray(d)

    # padded slots carry exactly zero work and zero tau
    assert not d[1, 4:].any() and not tau[1, 4:].any()
    for i, p in enumerate(probs):
        kk = p.num_learners
        np.testing.assert_allclose(d[i, :kk].sum(), p.total_samples, rtol=1e-3)
        assert np.all(d[i, :kk] >= p.d_lower - 1e-3)
        assert np.all(d[i, :kk] <= p.d_upper + 1e-3)

    # the padded row reproduces the standalone unpadded solve up to float
    # noise (padded slots contribute exact zeros, but the wider K axis
    # reassociates reductions, and 300 annealed steps amplify the ULPs)
    tm = small.time_model
    d0 = np.full(4, small.total_samples / 4, np.float32)
    tau_s, d_s = _pgd_run(
        jnp.asarray(d0), jnp.asarray(tm.c2, jnp.float32),
        jnp.asarray(tm.c1, jnp.float32), jnp.asarray(tm.c0, jnp.float32),
        jnp.float32(small.T), jnp.float32(small.d_lower),
        jnp.float32(small.d_upper), jnp.float32(small.total_samples), 300,
    )
    np.testing.assert_allclose(d[1, :4], np.asarray(d_s), rtol=1e-2, atol=1.0)
    np.testing.assert_allclose(tau[1, :4], np.asarray(tau_s), rtol=1e-2, atol=1.0)


# ---------------------------------------------------------------------------
# fused scan-over-cycles orchestrator vs eager loop
# ---------------------------------------------------------------------------

def test_fused_orchestrator_matches_eager_history():
    from repro.fed.simulation import run_experiment

    eager = run_experiment(k=4, T=15.0, cycles=3, total_samples=1200, seed=3)
    fused = run_experiment(k=4, T=15.0, cycles=3, total_samples=1200, seed=3,
                           fused=True)
    he, hf = eager["history"], fused["history"]
    assert len(he) == len(hf) == 3
    for re_, rf in zip(he, hf):
        np.testing.assert_array_equal(re_["tau"], rf["tau"])
        np.testing.assert_array_equal(re_["d"], rf["d"])
        assert re_["max_staleness"] == rf["max_staleness"]
        assert re_["cycle"] == rf["cycle"] and re_["elapsed_s"] == rf["elapsed_s"]
    np.testing.assert_allclose(
        [h["accuracy"] for h in he], [h["accuracy"] for h in hf], atol=1e-4
    )


def test_fused_realloc_matches_eager_drift_history():
    """run_fused(reallocate=True): the in-scan per-cycle KKT re-solve on
    drifted capacities reproduces the eager per-cycle-reallocation history
    (tau, d, shard draws) exactly for a fixed seed; accuracies agree to
    float tolerance (different zero-padding widths reassociate the masked
    loss reductions)."""
    from repro.fed.simulation import run_experiment

    drift = CapacityDrift(clock_jitter=0.15, fading_sigma_db=2.0, seed=5)
    kw = dict(k=4, T=15.0, cycles=3, total_samples=1200, seed=3,
              reallocate=True, drift=drift)
    eager = run_experiment(**kw)
    fused = run_experiment(**kw, fused=True)
    he, hf = eager["history"], fused["history"]
    assert len(he) == len(hf) == 3
    for re_, rf in zip(he, hf):
        np.testing.assert_array_equal(re_["tau"], rf["tau"])
        np.testing.assert_array_equal(re_["d"], rf["d"])
        assert re_["max_staleness"] == rf["max_staleness"]
        assert re_["cycle"] == rf["cycle"] and re_["elapsed_s"] == rf["elapsed_s"]
    # the drift actually moves the allocation between cycles
    taus = np.stack([h["tau"] for h in he])
    ds = np.stack([h["d"] for h in he])
    assert not (np.all(taus == taus[0]) and np.all(ds == ds[0]))
    np.testing.assert_allclose(
        [h["accuracy"] for h in he], [h["accuracy"] for h in hf], atol=5e-3
    )


def test_fused_realloc_policy_swap_eta():
    """The in-scan reallocation policy follows MELConfig.scheme: the eta
    baseline swaps in for the KKT pipeline and still matches its eager
    twin exactly."""
    from repro.fed.simulation import run_experiment

    drift = CapacityDrift(seed=7)
    kw = dict(k=4, T=15.0, cycles=2, total_samples=1200, seed=3,
              scheme="eta", reallocate=True, drift=drift)
    eager = run_experiment(**kw)
    fused = run_experiment(**kw, fused=True)
    for re_, rf in zip(eager["history"], fused["history"]):
        np.testing.assert_array_equal(re_["tau"], rf["tau"])
        np.testing.assert_array_equal(re_["d"], rf["d"])


def test_fused_realloc_infeasible_drift_raises_from_in_scan_guard():
    """An infeasible drifted cycle raises from the IN-SCAN feasibility
    guard: the scan latches dead at the first bad cycle (no training runs
    on a neutralized allocation from that point on), the error names that
    cycle, and the orchestrator's params stay usable — they hold the state
    trained through the feasible prefix only (finite, and bitwise equal to
    an eager run truncated at the same cycle)."""
    from repro.data.pipeline import synthetic_mnist
    from repro.fed.orchestrator import MELConfig, Orchestrator
    from repro.models import mlp

    train, _ = synthetic_mnist(3000, n_test=10, seed=0)
    prob = make_problem(k=4, T=15.0, d=1200)
    drift = CapacityDrift(fading_sigma_db=30.0, fading_clip_db=30.0, seed=0)
    orch = Orchestrator(MELConfig(T=15.0, total_samples=1200), prob, mlp.loss,
                        mlp.init(jax.random.key(0)), drift=drift)
    with pytest.raises(ValueError, match="cannot absorb") as ei:
        orch.run(train, 3, fused=True, reallocate=True)
    assert "at cycle" in str(ei.value)
    for leaf in jax.tree_util.tree_leaves(orch.params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_fused_realloc_rejects_untraced_scheme():
    from repro.data.pipeline import synthetic_mnist
    from repro.fed.orchestrator import MELConfig, Orchestrator
    from repro.models import mlp

    train, _ = synthetic_mnist(2000, n_test=10, seed=0)
    prob = make_problem(k=4, T=15.0, d=1000)
    mel = MELConfig(T=15.0, total_samples=1000, scheme="slsqp")
    orch = Orchestrator(mel, prob, mlp.loss, mlp.init(jax.random.key(0)))
    with pytest.raises(ValueError, match="no batched/traced policy"):
        orch.run(train, 2, fused=True, reallocate=True)


def test_drift_staleness_sweep_adaptive_beats_static():
    """The paper's core claim under time-varying capacities: re-solving
    each cycle (adaptive) never does worse than freezing the allocation
    (static), and the KKT scheme strictly improves on this drift path."""
    from repro.fed.simulation import staleness_sweep

    rows = staleness_sweep(
        [5, 8], 7.5, schemes=("kkt_sai", "eta"), reallocate=True, cycles=6,
        total_samples=4000,
    )
    by = {(r["K"], r["scheme"], r["mode"]): r for r in rows}
    for k in (5, 8):
        for scheme in ("kkt_sai", "eta"):
            ada = by[(k, scheme, "adaptive")]
            sta = by[(k, scheme, "static")]
            assert ada["max_staleness_mean"] <= sta["max_staleness_mean"] + 1e-9
        assert (by[(k, "kkt_sai", "adaptive")]["max_staleness_mean"]
                < by[(k, "kkt_sai", "static")]["max_staleness_mean"])


def test_batched_sweep_matches_eager_sweep():
    from repro.fed.simulation import staleness_sweep

    kw = dict(schemes=("kkt_sai", "eta"), seed=0, total_samples=4000)
    assert staleness_sweep([5, 8], 7.5, **kw) == staleness_sweep(
        [5, 8], 7.5, use_batched=False, **kw
    )
