"""Event-driven asynchronous federation engine (fed.async_engine).

Pins the acceptance contracts of the subsystem:
  * buffered mode with M = K and a cycle barrier reproduces the paper-scheme
    ``Orchestrator.run`` tau/d/staleness history (and params) exactly;
  * the event-indexed (jagged) ``run_events`` fast path replays the eager
    event loop EXACTLY on every schedule — including the tied/near-tie
    completion times of a KKT allocator, which the legacy fixed grid could
    only handle via ``strict=False`` merging or not at all;
  * the legacy fixed-grid ``run_bucketed`` path still matches the eager
    loop when the grid resolves individual arrivals;
  * version staleness, the FedAsync discount functions, and the schedule's
    virtual-clock bookkeeping behave as specified.
"""

import numpy as np
import pytest

import jax

from repro.core import AllocationProblem, CapacityDrift, QueueDrift, TimeModel
from repro.core.staleness import staleness_factor
from repro.data.pipeline import synthetic_mnist
from repro.fed.async_engine import (
    AsyncConfig,
    AsyncFedEngine,
    _event_segments,
    summarize_async_history,
)
from repro.fed.orchestrator import MELConfig, Orchestrator
from repro.fed.simulation import (
    build_problem,
    build_spread_problem as spread_problem,
    run_async_experiment,
)
from repro.models import mlp

from tests._prop import given, settings, st


@pytest.fixture(scope="module")
def data():
    return synthetic_mnist(3000, n_test=600, seed=0)


def _assert_trees_equal(a, b, **kw):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if kw:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _tied_problem(k: int = 3) -> AllocationProblem:
    """A homogeneous fleet: every learner completes at the bitwise-same
    virtual time, so NO time grid separates the arrivals (the regime the
    fixed-grid path cannot represent at all)."""
    tm = TimeModel(c2=np.full(k, 0.04), c1=np.full(k, 0.004),
                   c0=np.full(k, 0.4))
    return AllocationProblem(time_model=tm, T=6.0, total_samples=60,
                             d_lower=10, d_upper=40)


def _near_tie_problem() -> AllocationProblem:
    """A KKT near-tie fleet: capacities differ by ~1e-7 relative, so the
    completion gaps are microscopic and resolving them on a uniform grid
    needs millions of buckets — the regime that previously forced
    ``strict=False`` merging."""
    eps = np.array([0.0, 1e-7, 2.3e-7])
    tm = TimeModel(c2=0.04 * (1 + eps), c1=np.full(3, 0.004),
                   c0=np.full(3, 0.4))
    return AllocationProblem(time_model=tm, T=6.0, total_samples=60,
                             d_lower=10, d_upper=40)


def _min_grid(cfg, prob, train, horizon, *, seed=2) -> int:
    """Smallest uniform grid that resolves every kept arrival into its own
    bucket (the exact-replay regime of the legacy ``run_bucketed``), read
    off a probe engine's schedule. The probe shares the production
    engine's seed, so its rng stream — and therefore its schedule — is
    identical; the production engine's own rng is untouched."""
    from repro.data.pipeline import FederatedPartitioner

    probe = AsyncFedEngine(cfg, prob, mlp.loss, mlp.init(jax.random.key(1)),
                           seed=seed)
    part = FederatedPartitioner(train, seed=int(probe.rng.integers(2**31)))
    sched = probe._build_schedule(part, horizon, 100_000)
    ts = sorted(a.t for a in sched.arrivals if a.flush_id >= 0)
    gaps = [b - a for a, b in zip(ts, ts[1:]) if b > a]
    assert gaps and len(gaps) == len(ts) - 1, "schedule ties: no exact grid"
    return int(np.ceil(horizon / min(gaps))) + 1


def _run_both(cfg, prob, train, horizon, *, seed=2, drift=None,
              eval_fn=None, eval_batch=None):
    """Run the eager loop and the event-indexed scan from the same seed
    and return (eager_engine, eager_hist, jagged_engine, jagged_hist)."""
    params = mlp.init(jax.random.key(1))
    e1 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed, drift=drift)
    h1 = e1.run(train, horizon, eval_fn=eval_fn, eval_batch=eval_batch)
    e2 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed, drift=drift)
    h2 = e2.run_events(train, horizon, eval_fn=eval_fn,
                       eval_batch=eval_batch)
    return e1, h1, e2, h2


def _assert_history_match(h1, h2, *, acc_atol=None):
    """Versions, learners, staleness and weights must match BITWISE (both
    paths consume one shared schedule); accuracies to float tolerance."""
    assert len(h1) == len(h2)
    for r1, r2 in zip(h1, h2):
        assert r1["learners"] == r2["learners"]
        assert r1["staleness_list"] == r2["staleness_list"]
        assert r1["server_version"] == r2["server_version"]
        np.testing.assert_array_equal(r1["weights"], r2["weights"])
        np.testing.assert_array_equal(r1["tau"], r2["tau"])
        np.testing.assert_array_equal(r1["d"], r2["d"])
        assert r1["keep"] == r2["keep"]
    if acc_atol is not None:
        np.testing.assert_allclose(
            [r["accuracy"] for r in h1], [r["accuracy"] for r in h2],
            atol=acc_atol,
        )


# ---------------------------------------------------------------------------
# barrier regime == paper scheme
# ---------------------------------------------------------------------------

def test_buffered_barrier_matches_orchestrator(data):
    """M = K + cycle barrier IS the paper's scheme: tau/d/staleness history
    and the aggregated params match Orchestrator.run bitwise."""
    train, _ = data
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    params = mlp.init(jax.random.key(3))

    orch = Orchestrator(MELConfig(T=15.0, total_samples=1200), prob,
                        mlp.loss, params, seed=3)
    ho = orch.run(train, 3)
    eng = AsyncFedEngine(AsyncConfig(mode="buffered", barrier=True), prob,
                         mlp.loss, params, seed=3)
    ha = eng.run(train, cycles=3)

    assert len(ho) == len(ha) == 3
    for ro, ra in zip(ho, ha):
        np.testing.assert_array_equal(ro["tau"], ra["tau"])
        np.testing.assert_array_equal(ro["d"], ra["d"])
        assert ro["max_staleness"] == ra["max_staleness"]
        assert ro["avg_staleness"] == ra["avg_staleness"]
        assert ra["version_staleness_max"] == 0
    _assert_trees_equal(orch.params, eng.params)


def test_buffered_barrier_matches_orchestrator_under_drift(data):
    """The equivalence holds with per-cycle reallocation under drift too
    (same coefficient path, same traced policy re-solves)."""
    train, _ = data
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    params = mlp.init(jax.random.key(3))
    drift = CapacityDrift(clock_jitter=0.15, fading_sigma_db=2.0, seed=5)

    orch = Orchestrator(MELConfig(T=15.0, total_samples=1200), prob,
                        mlp.loss, params, seed=3, drift=drift)
    ho = orch.run(train, 3, reallocate=True)
    eng = AsyncFedEngine(
        AsyncConfig(mode="buffered", barrier=True, reallocate=True), prob,
        mlp.loss, params, seed=3, drift=drift,
    )
    ha = eng.run(train, cycles=3)
    for ro, ra in zip(ho, ha):
        np.testing.assert_array_equal(ro["tau"], ra["tau"])
        np.testing.assert_array_equal(ro["d"], ra["d"])
    _assert_trees_equal(orch.params, eng.params)


def test_barrier_requires_full_buffer():
    prob = spread_problem()
    params = mlp.init(jax.random.key(0))
    with pytest.raises(ValueError, match="buffer_size == K"):
        AsyncFedEngine(
            AsyncConfig(mode="buffered", barrier=True, buffer_size=2),
            prob, mlp.loss, params,
        )
    with pytest.raises(ValueError, match="cycle gate"):
        AsyncConfig(mode="fedasync", barrier=True)


# ---------------------------------------------------------------------------
# event mode: schedule + staleness semantics
# ---------------------------------------------------------------------------

def test_fedasync_versions_and_staleness(data):
    """Server version grows by one per arrival; staleness is the number of
    aggregations that happened while the upload was in flight; the history
    is ordered in virtual time within the horizon."""
    train, _ = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync", alpha=0.5), prob,
                         mlp.loss, params, seed=2)
    hist = eng.run(train, 18.0)
    assert len(hist) >= 6
    ts = [r["t"] for r in hist]
    assert ts == sorted(ts) and ts[-1] <= 18.0
    assert [r["server_version"] for r in hist] == list(range(1, len(hist) + 1))
    # the first K arrivals were dispatched at version 0, so staleness
    # equals the number of earlier arrivals
    k = prob.num_learners
    assert [r["staleness_list"][0] for r in hist[:k]] == list(range(k))
    # the mixing weight is alpha * discount(staleness)
    for r in hist:
        s = r["staleness_list"][0]
        beta = 0.5 * staleness_factor(s, kind="poly", a=0.5, b=4.0)
        np.testing.assert_allclose(r["weights"][0], beta)
        np.testing.assert_allclose(r["keep"], 1.0 - beta)
    summ = summarize_async_history(hist)
    assert summ["aggregations"] == len(hist)
    assert summ["staleness"]["max"] >= 1


def test_buffered_flush_weights_normalized(data):
    train, _ = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    eng = AsyncFedEngine(AsyncConfig(mode="buffered", buffer_size=2), prob,
                         mlp.loss, params, seed=2)
    hist = eng.run(train, 18.0)
    assert len(hist) >= 2
    for r in hist:
        assert len(r["learners"]) == 2
        np.testing.assert_allclose(r["weights"].sum(), 1.0)
        assert r["keep"] == 0.0
    # version bumps once per flush, not per upload
    assert [r["server_version"] for r in hist] == list(range(1, len(hist) + 1))


def test_async_engine_learns(data):
    """Accuracy at the end of the virtual horizon beats the init model.
    (lr kept moderate: GD on tiny shards is chaotic enough that XLA-CPU
    thread-partitioning noise can fork trajectories run-to-run; at 0.05
    every fork still learns.)"""
    train, test = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync", alpha=0.6, lr=0.05),
                         prob, mlp.loss, params, seed=2)
    hist = eng.run(train, 36.0, eval_fn=mlp.accuracy,
                   eval_batch=(test.x, test.y))
    acc0 = float(mlp.accuracy(params, test.x, test.y))
    assert hist[-1]["accuracy"] > acc0 + 0.05


def test_reallocate_composes_with_drift(data):
    """Per-block re-solves through the batched policy react to drift: the
    dispatched (tau, d) change across blocks."""
    train, _ = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    drift = CapacityDrift(clock_jitter=0.25, fading_sigma_db=3.0, seed=4)
    eng = AsyncFedEngine(
        AsyncConfig(mode="fedasync", reallocate=True), prob, mlp.loss,
        params, seed=2, drift=drift,
    )
    hist = eng.run(train, 24.0)
    taus = {tuple(map(int, r["tau"])) for r in hist}
    ds = {tuple(map(int, r["d"])) for r in hist}
    assert len(taus) > 1 or len(ds) > 1


# ---------------------------------------------------------------------------
# bucketed fast path == eager event loop
# ---------------------------------------------------------------------------

def test_bucketed_matches_eager_fedasync(data):
    train, test = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    cfg = AsyncConfig(mode="fedasync", alpha=0.6)

    e1 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=2)
    h1 = e1.run(train, 18.0, eval_fn=mlp.accuracy,
                eval_batch=(test.x[:400], test.y[:400]))
    e2 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=2)
    nb = _min_grid(cfg, prob, train, 18.0)
    h2 = e2.run_bucketed(train, 18.0, nb, eval_fn=mlp.accuracy,
                         eval_batch=(test.x[:400], test.y[:400]))

    # identical schedule: same aggregation sequence metadata
    assert len(h1) == len(h2)
    for r1, r2 in zip(h1, h2):
        assert r1["learners"] == r2["learners"]
        assert r1["staleness_list"] == r2["staleness_list"]
        np.testing.assert_allclose(r1["weights"], r2["weights"])
        np.testing.assert_array_equal(r1["tau"], r2["tau"])
    # same aggregation VALUES to float tolerance
    np.testing.assert_allclose(
        [r["accuracy"] for r in h1], [r["accuracy"] for r in h2], atol=2e-3
    )
    _assert_trees_equal(e1.params, e2.params, atol=1e-5)


def test_bucketed_matches_eager_buffered(data):
    train, _ = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    cfg = AsyncConfig(mode="buffered", buffer_size=2)

    e1 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=2)
    h1 = e1.run(train, 18.0)
    e2 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=2)
    nb = _min_grid(cfg, prob, train, 18.0)
    h2 = e2.run_bucketed(train, 18.0, nb)
    assert [r["learners"] for r in h1] == [r["learners"] for r in h2]
    _assert_trees_equal(e1.params, e2.params, atol=1e-5)


def test_bucketed_guards(data):
    """Grids too coarse to replay the schedule raise with a remedy instead
    of silently diverging."""
    train, _ = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss,
                         params, seed=2)
    # 1 bucket holds every learner's repeat arrivals
    with pytest.raises(ValueError, match="increase num_buckets"):
        eng.run_bucketed(train, 18.0, 1)
    # barrier regime is served by Orchestrator.run_fused
    ebar = AsyncFedEngine(AsyncConfig(mode="buffered", barrier=True), prob,
                          mlp.loss, params, seed=2)
    with pytest.raises(ValueError, match="run_fused"):
        ebar.run_bucketed(train, 18.0, 64)


def test_bucketed_strict_false_merges_collisions(data):
    """With strict=False, near-tie fedasync arrivals merge into one bucket
    via sequentially-composed weights: every upload is still aggregated
    exactly once with the schedule's staleness bookkeeping, and the merged
    run still trains (the mid-bucket redispatch model is the documented
    approximation, so parameter trajectories may drift from the eager
    loop's — the per-flush metadata may not)."""
    train, test = data
    prob = spread_problem()
    params = mlp.init(jax.random.key(1))
    cfg = AsyncConfig(mode="fedasync", alpha=0.6)
    e1 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=2)
    h1 = e1.run(train, 18.0)
    e2 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=2)
    h2 = e2.run_bucketed(train, 18.0, 24, strict=False,
                         eval_fn=mlp.accuracy, eval_batch=(test.x, test.y))
    assert len(h1) == len(h2)
    assert sum(len(r["learners"]) for r in h2) == len(h1)
    for r1, r2 in zip(h1, h2):
        assert r1["learners"] == r2["learners"]
        assert r1["staleness_list"] == r2["staleness_list"]
    acc0 = float(mlp.accuracy(params, test.x, test.y))
    assert h2[-1]["accuracy"] > acc0
    for leaf in jax.tree_util.tree_leaves(e2.params):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# event-indexed (jagged) fast path == eager event loop, with NO grid caveats
# ---------------------------------------------------------------------------

def test_run_events_matches_eager_spread(data):
    """On a well-spread schedule run_events reproduces run: metadata
    bitwise, params and accuracies to float tolerance."""
    train, test = data
    prob = spread_problem()
    for cfg in (AsyncConfig(mode="fedasync", alpha=0.6),
                AsyncConfig(mode="buffered", buffer_size=2)):
        e1, h1, e2, h2 = _run_both(
            cfg, prob, train, 18.0, eval_fn=mlp.accuracy,
            eval_batch=(test.x[:400], test.y[:400]),
        )
        _assert_history_match(h1, h2, acc_atol=2e-3)
        _assert_trees_equal(e1.params, e2.params, atol=1e-5)


def test_run_events_exact_on_tied_schedule(data):
    """ACCEPTANCE: a homogeneous fleet completes at bitwise-identical
    times — no grid separates its arrivals into distinct buckets — yet
    the event-indexed path replays the eager loop exactly in BOTH
    server modes."""
    train, test = data
    prob = _tied_problem()
    for cfg in (AsyncConfig(mode="fedasync", alpha=0.6),
                AsyncConfig(mode="buffered", buffer_size=2)):
        e1, h1, e2, h2 = _run_both(
            cfg, prob, train, 12.0, eval_fn=mlp.accuracy,
            eval_batch=(test.x[:400], test.y[:400]),
        )
        assert len(h1) > 0
        _assert_history_match(h1, h2, acc_atol=2e-3)
        _assert_trees_equal(e1.params, e2.params, atol=1e-5)


def test_run_events_exact_on_near_tie_kkt(data):
    """ACCEPTANCE: on a KKT near-tie schedule (completion gaps ~1e-6 of
    the horizon) the old grid needs millions of buckets — past the cap,
    i.e. the regime that previously required strict=False — while
    run_events matches the eager loop exactly (tau/d/staleness history
    and weights/versions bitwise, params within float tolerance)."""
    train, test = data
    prob = _near_tie_problem()
    e1, h1, e2, h2 = _run_both(
        AsyncConfig(mode="fedasync", alpha=0.6), prob, train, 12.0,
        eval_fn=mlp.accuracy, eval_batch=(test.x[:400], test.y[:400]),
    )
    assert len(h1) >= 6
    _assert_history_match(h1, h2, acc_atol=2e-3)
    _assert_trees_equal(e1.params, e2.params, atol=1e-5)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16), alpha=st.floats(0.2, 0.9),
       fn=st.sampled_from(["constant", "hinge", "poly"]),
       buffered=st.sampled_from([0, 2]))
def test_run_events_matches_eager_property(seed, alpha, fn, buffered):
    """Property: across engine seeds (shard draws), mixing rates,
    staleness discounts and server modes, the jagged replay of a near-tie
    KKT schedule stays exact (the case the old fixed grid could not
    represent). Mirrors the seed-pin style of test_aggregation_props."""
    train, _ = synthetic_mnist(1200, n_test=50, seed=1)
    prob = _near_tie_problem()
    cfg = (AsyncConfig(mode="buffered", buffer_size=buffered, alpha=alpha,
                       staleness_fn=fn)
           if buffered else
           AsyncConfig(mode="fedasync", alpha=alpha, staleness_fn=fn))
    e1, h1, e2, h2 = _run_both(cfg, prob, train, 12.0, seed=seed)
    assert len(h1) > 0
    _assert_history_match(h1, h2)
    _assert_trees_equal(e1.params, e2.params, atol=1e-5)


def test_event_segments_invariants(data):
    """The jagged partition: at most one arrival per learner per segment,
    at most one flush per segment and always last, fedasync segments are
    singletons, and every aggregated arrival appears exactly once."""
    train, _ = data
    prob = _tied_problem()
    for cfg in (AsyncConfig(mode="fedasync"),
                AsyncConfig(mode="buffered", buffer_size=2)):
        eng = AsyncFedEngine(cfg, prob, mlp.loss,
                             mlp.init(jax.random.key(0)), seed=2)
        from repro.data.pipeline import FederatedPartitioner

        part = FederatedPartitioner(train, seed=0)
        sched = eng._build_schedule(part, 12.0, 100_000)
        segs = _event_segments(sched.arrivals)
        seen = []
        for evs in segs:
            learners = [a.learner for a in evs]
            assert len(set(learners)) == len(learners)
            flush_pos = [i for i, a in enumerate(evs) if a.flush]
            assert len(flush_pos) <= 1
            if flush_pos:
                assert flush_pos[0] == len(evs) - 1
            if cfg.mode == "fedasync":
                assert len(evs) == 1 and evs[0].flush
            seen.extend(a.seq for a in evs)
        kept = [a.seq for a in sched.arrivals if a.flush_id >= 0]
        assert sorted(seen) == kept


def test_run_async_experiment_bucketed_routes_to_jagged(data):
    """bucketed=True with num_buckets=0 takes the event-indexed path: it
    must succeed on a tied schedule no grid can represent."""
    train, test = data
    res = run_async_experiment(
        mode="fedasync", cycles=2, problem=_tied_problem(), train=train,
        test=test, seed=2, bucketed=True,
    )
    assert res["final_accuracy"] is not None
    assert res["summary"]["aggregations"] > 0


def test_run_async_experiment_modes(data):
    """The simulation wiring drives all three modes on a custom fleet and
    reports comparable summaries at equal virtual time."""
    train, test = data
    prob = spread_problem()
    out = {}
    for mode in ("cycle", "fedasync", "buffered"):
        res = run_async_experiment(
            mode=mode, cycles=3, problem=prob, train=train, test=test,
            seed=2, buffer_size=2,
        )
        assert res["final_accuracy"] is not None
        assert res["summary"]["virtual_time"] <= 3 * prob.T + 1e-9
        out[mode] = res
    # the cycle-gated scheme aggregates exactly once per cycle; the async
    # servers aggregate more often within the same virtual time
    assert out["cycle"]["summary"]["aggregations"] == 3
    assert out["fedasync"]["summary"]["aggregations"] > 3
    # version staleness exists only without the barrier
    assert out["cycle"]["summary"]["staleness"]["max"] == 0
    assert out["fedasync"]["summary"]["staleness"]["max"] >= 1


# ---------------------------------------------------------------------------
# run_events: staging cache + seg_batch sub-batching
# ---------------------------------------------------------------------------

def test_run_events_stages_once_per_schedule(data):
    """The (S, K, d_cap, F) staging tensor is built ONCE per distinct
    (dataset, schedule) and served from cache on replays — a second
    same-seed engine re-running the identical schedule must not restage."""
    from repro.fed.async_engine import clear_staging_cache, staging_cache_stats

    train, _ = data
    prob = spread_problem()
    clear_staging_cache()
    try:
        for _ in range(2):
            eng = AsyncFedEngine(AsyncConfig(mode="fedasync"), prob,
                                 mlp.loss, mlp.init(jax.random.key(1)),
                                 seed=2)
            eng.run_events(train, 30.0)
        stats = staging_cache_stats()
        assert stats == {"stages": 1, "hits": 1}, stats
        # a different seed is a different schedule: restage, never serve
        # another schedule's tensors
        eng = AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss,
                             mlp.init(jax.random.key(1)), seed=5)
        eng.run_events(train, 30.0)
        stats = staging_cache_stats()
        assert stats == {"stages": 2, "hits": 1}, stats
    finally:
        clear_staging_cache()


def test_run_events_seg_batch_matches_dense(data):
    """Sub-batched jagged segments (seg_batch): history rows bitwise equal
    to the dense staging; params to float tolerance only — the chunked
    accumulate folds the same weighted sums in a different order."""
    train, _ = data
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    cfg = AsyncConfig(mode="buffered", buffer_size=4)

    runs = []
    for sb in (None, 2):
        eng = AsyncFedEngine(cfg, prob, mlp.loss,
                             mlp.init(jax.random.key(2)), seed=2)
        hist = eng.run_events(train, 45.0, seg_batch=sb)
        runs.append((hist, eng.params))

    (h0, p0), (h1, p1) = runs
    assert len(h0) == len(h1) >= 2
    _assert_history_match(h0, h1)
    _assert_trees_equal(p0, p1, atol=1e-4, rtol=0)


def test_run_events_seg_batch_pallas_matches_seg_batch_unfused(data):
    """seg_batch and the megakernel compose: the compact scan body through
    ops.train_agg_step is bitwise equal to its unfused twin."""
    train, _ = data
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    cfg = AsyncConfig(mode="buffered", buffer_size=4)

    runs = []
    for up in (False, True):
        eng = AsyncFedEngine(cfg, prob, mlp.loss,
                             mlp.init(jax.random.key(2)), seed=2)
        hist = eng.run_events(train, 45.0, seg_batch=2, use_pallas=up,
                              interpret=up)
        runs.append((hist, eng.params))

    (h0, p0), (h1, p1) = runs
    _assert_history_match(h0, h1)
    _assert_trees_equal(p0, p1)
