"""Availability (client churn) processes: the drift-protocol surface of
``core.availability``, mask dynamics and determinism, composition with
base capacity drifts, masked allocation solves, and the rejection
surface of every consumer that needs standalone capacity rows."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ActiveRateAvailability,
    AllocationProblem,
    CapacityDrift,
    MarkovAvailability,
    QueueDrift,
    TimeModel,
    TraceAvailability,
    apply_active_mask,
    availability_masks,
    capacity_state_coupled,
    has_availability,
)
from repro.core.time_model import is_state_coupled
from repro.fed.orchestrator import (
    MELConfig,
    Orchestrator,
    coefficient_rows,
    solve_policy_row,
    solve_rows_availability,
)
from repro.models import mlp


def _prob(k: int = 3) -> AllocationProblem:
    tm = TimeModel(c2=np.full(k, 0.04), c1=np.full(k, 0.004),
                   c0=np.full(k, 0.4))
    return AllocationProblem(time_model=tm, T=6.0, total_samples=60,
                             d_lower=10, d_upper=40)


# ---------------------------------------------------------------------------
# protocol probes
# ---------------------------------------------------------------------------

def test_protocol_probes():
    """Availability processes satisfy the drift protocol AND expose
    ``online_at``; plain capacity drifts do not."""
    for drift in (MarkovAvailability(), ActiveRateAvailability(),
                  TraceAvailability(np.ones((2, 3), bool))):
        assert has_availability(drift)
        assert is_state_coupled(drift)  # carries state_init/state_update
    assert not has_availability(None)
    assert not has_availability(CapacityDrift())
    assert not has_availability(QueueDrift())


def test_capacity_state_coupled_looks_through_to_base():
    """Churn alone does NOT couple capacities to allocations — a frozen
    schedule stays well defined — but a queue-backlogged base does."""
    assert not capacity_state_coupled(MarkovAvailability())
    assert not capacity_state_coupled(MarkovAvailability(base=CapacityDrift()))
    assert capacity_state_coupled(MarkovAvailability(base=QueueDrift()))
    assert capacity_state_coupled(QueueDrift())
    assert not capacity_state_coupled(CapacityDrift())
    assert not capacity_state_coupled(None)


def test_parameter_validation():
    with pytest.raises(ValueError, match="p_drop"):
        MarkovAvailability(p_drop=1.5)
    with pytest.raises(ValueError, match="p_join"):
        MarkovAvailability(p_join=-0.1)
    with pytest.raises(ValueError, match="median"):
        ActiveRateAvailability(median=0.0)
    with pytest.raises(ValueError, match="sigma"):
        ActiveRateAvailability(sigma=-1.0)
    with pytest.raises(ValueError, match="trace"):
        TraceAvailability(np.ones((4,), bool))


# ---------------------------------------------------------------------------
# mask dynamics
# ---------------------------------------------------------------------------

def test_markov_masks_start_online_and_are_deterministic():
    av = MarkovAvailability(p_drop=0.4, p_join=0.5, seed=0)
    m1 = availability_masks(av, 4, 8)
    m2 = availability_masks(av, 4, 8)
    assert m1.shape == (8, 4) and m1.dtype == bool
    assert m1[0].all()                       # everyone online at block 0
    np.testing.assert_array_equal(m1, m2)    # seeded → reproducible
    m3 = availability_masks(MarkovAvailability(p_drop=0.4, seed=1), 4, 8)
    assert not np.array_equal(m1, m3)        # seed actually matters


def test_markov_degenerate_chains():
    always = availability_masks(MarkovAvailability(p_drop=0.0, p_join=1.0), 3, 6)
    assert always.all()
    gone = availability_masks(MarkovAvailability(p_drop=1.0, p_join=0.0), 3, 6)
    assert gone[0].all() and not gone[1:].any()


def test_active_rate_rates_and_masks():
    av = ActiveRateAvailability(median=0.7, sigma=0.6, floor=0.1, seed=3)
    r = np.asarray(av.rates(16))
    assert r.shape == (16,)
    assert (r >= 0.1 - 1e-7).all() and (r <= 1.0 + 1e-7).all()
    np.testing.assert_array_equal(r, np.asarray(av.rates(16)))
    m = availability_masks(av, 16, 6)
    np.testing.assert_array_equal(m, availability_masks(av, 16, 6))
    # a rate floor of 1 pins every learner online every block
    sat = ActiveRateAvailability(median=1.0, sigma=0.0, floor=1.0)
    assert availability_masks(sat, 5, 4).all()


def test_trace_wraps_periodically_and_validates_fleet_size():
    tr = np.array([[True, True], [True, False], [False, True]])
    av = TraceAvailability(tr)
    m = availability_masks(av, 2, 7)
    for c in range(7):
        np.testing.assert_array_equal(m[c], tr[c % 3])
    with pytest.raises(ValueError, match="fleet has 5"):
        av.state_init(5)


def test_composition_with_base_drift():
    """``factors_at`` delegates to the wrapped base so churn composes
    with time-varying capacity; without a base, factors are ones."""
    base = CapacityDrift(clock_jitter=0.2, fading_sigma_db=2.0, seed=7)
    av = MarkovAvailability(p_drop=0.3, seed=0, base=base)
    state = av.state_init(4)
    for c in range(3):
        cf, rf = av.factors_at(c, 4, state)
        bcf, brf = base.factors_at(c, 4)
        np.testing.assert_array_equal(np.asarray(cf), np.asarray(bcf))
        np.testing.assert_array_equal(np.asarray(rf), np.asarray(brf))
        state = av.state_update(c, state, jnp.zeros(4, jnp.int32),
                                jnp.zeros(4, jnp.int32))
    bare = MarkovAvailability(p_drop=0.3, seed=0)
    cf, rf = bare.factors_at(0, 4, bare.state_init(4))
    np.testing.assert_array_equal(np.asarray(cf), np.ones(4, np.float32))
    np.testing.assert_array_equal(np.asarray(rf), np.ones(4, np.float32))
    # same seed → the availability component is identical with/without base
    np.testing.assert_array_equal(
        availability_masks(av, 4, 6), availability_masks(bare, 4, 6)
    )


def test_queue_coupled_base_state_advances_with_allocation():
    """With a queue-backlogged base the joint state carries BOTH pytree
    leaves and the base leaf responds to the dispatched load."""
    av = MarkovAvailability(p_drop=0.0, p_join=1.0,
                            base=QueueDrift(congestion=1.0, gain=2.0))
    state = av.state_init(3)
    avail0, q0 = state
    assert np.asarray(q0).shape == (3,)
    heavy = av.state_update(0, state, jnp.asarray([5, 5, 5]),
                            jnp.asarray([40, 10, 10]))
    _, q1 = heavy
    assert not np.array_equal(np.asarray(q1), np.asarray(q0))


# ---------------------------------------------------------------------------
# masked allocation solves
# ---------------------------------------------------------------------------

def test_apply_active_mask_padded_slot_semantics():
    total = jnp.asarray([100.0])
    lo = jnp.asarray([[10.0, 10.0, 10.0]])
    hi = jnp.asarray([[40.0, 40.0, 40.0]])
    valid = jnp.asarray([[True, True, True]])
    act = jnp.asarray([[True, False, True]])
    tot, lo2, hi2, v2 = apply_active_mask(total, lo, hi, valid, act)
    np.testing.assert_array_equal(np.asarray(lo2), [[10.0, 0.0, 10.0]])
    np.testing.assert_array_equal(np.asarray(hi2), [[40.0, 0.0, 40.0]])
    np.testing.assert_array_equal(np.asarray(v2), [[True, False, True]])
    # budget clipped into the live fleet's box: 100 > 2 * 40
    np.testing.assert_array_equal(np.asarray(tot), [80.0])
    # and up to the live lower bound when the fleet thins drastically
    tot2, *_ = apply_active_mask(jnp.asarray([5.0]), lo, hi, valid, act)
    np.testing.assert_array_equal(np.asarray(tot2), [20.0])


def test_masked_solve_redistributes_budget():
    prob = _prob()
    c2s, c1s, c0s = coefficient_rows(prob, None, 1)
    tau_f, d_f = solve_policy_row("kkt_sai", c2s[0], c1s[0], c0s[0], prob,
                                  label="full")
    tau_m, d_m = solve_policy_row("kkt_sai", c2s[0], c1s[0], c0s[0], prob,
                                  label="masked",
                                  active=np.array([True, False, True]))
    assert d_m[1] == 0 and tau_m[1] == 0
    assert d_m.sum() == np.clip(d_f.sum(), 2 * prob.d_lower, 2 * prob.d_upper)
    assert (d_m[[0, 2]] >= prob.d_lower).all()


def test_masked_solve_all_offline_is_zero_budget():
    prob = _prob()
    c2s, c1s, c0s = coefficient_rows(prob, None, 1)
    tau, d = solve_policy_row("kkt_sai", c2s[0], c1s[0], c0s[0], prob,
                              label="dark", active=np.zeros(3, bool))
    assert tau.sum() == 0 and d.sum() == 0
    assert tau.dtype == np.int64 and d.dtype == np.int64


def test_masked_solve_infeasible_names_online_count():
    """An infeasible *masked* fleet reports how many learners were live."""
    k = 3
    tm = TimeModel(c2=np.full(k, 50.0), c1=np.full(k, 5.0),
                   c0=np.full(k, 0.4))
    prob = AllocationProblem(time_model=tm, T=1.0, total_samples=60,
                             d_lower=20, d_upper=40)
    c2s, c1s, c0s = coefficient_rows(prob, None, 1)
    with pytest.raises(ValueError, match="2/3 learners online"):
        solve_policy_row("kkt_sai", c2s[0], c1s[0], c0s[0], prob,
                         label="tight", active=np.array([True, False, True]))


def test_solve_rows_availability_joint_rollout():
    prob = _prob()
    av = MarkovAvailability(p_drop=0.5, p_join=0.3, seed=2)
    (c2s, c1s, c0s), (taus, ds), masks = solve_rows_availability(
        "kkt_sai", av, prob, 6, label="cycle {}"
    )
    assert c2s.shape == taus.shape == ds.shape == masks.shape == (6, 3)
    # a Markov process without a queue base ignores tau/d, so the joint
    # rollout's masks equal the frozen-allocation rollout's
    np.testing.assert_array_equal(masks, availability_masks(av, 3, 6))
    # offline slots get nothing; live slots honor the (clipped) budget
    assert (ds[~masks] == 0).all() and (taus[~masks] == 0).all()
    for c in range(6):
        n_on = int(masks[c].sum())
        if n_on:
            assert ds[c].sum() >= n_on * prob.d_lower
        else:
            assert ds[c].sum() == 0
    assert not masks.all()  # p_drop=0.5 actually churned someone


def test_committed_uptime_trace_replays_through_masked_solve():
    """The committed FLGo-style usage-ping fixture (288 five-minute ticks
    x 12 clients, bursty sessions under a diurnal envelope) replays
    bit-exactly through ``TraceAvailability`` and drives the masked-solve
    path: online sets come from the measured trace, offline clients get
    tau = d = 0, and the budget redistributes over whoever is up."""
    import pathlib

    csv = pathlib.Path(__file__).parent / "data" / "uptime_trace.csv"
    trace = np.loadtxt(csv, delimiter=",", dtype=np.int8).astype(bool)
    c_tr, k = trace.shape
    assert (c_tr, k) == (288, 12)
    # the fixture is bursty, not i.i.d.: multi-tick sessions dominate
    flips = np.abs(np.diff(trace.astype(int), axis=0)).sum()
    assert 0 < flips < 0.5 * trace.size
    assert trace.any(axis=0).all()            # every client pings

    av = TraceAvailability(trace)
    tm = TimeModel(c2=np.full(k, 0.04), c1=np.full(k, 0.004),
                   c0=np.full(k, 0.4))
    prob = AllocationProblem(time_model=tm, T=6.0, total_samples=240,
                             d_lower=10, d_upper=40)
    cycles = 36
    _, (taus, ds), masks = solve_rows_availability(
        "kkt_sai", av, prob, cycles, label="trace cycle {}"
    )
    np.testing.assert_array_equal(masks, trace[:cycles])
    assert (ds[~masks] == 0).all() and (taus[~masks] == 0).all()
    for c in range(cycles):
        n_on = int(masks[c].sum())
        if n_on:   # live fleet absorbs the (box-clipped) budget
            assert n_on * prob.d_lower <= ds[c].sum() <= n_on * prob.d_upper
        else:
            assert ds[c].sum() == 0
    # the replay wraps periodically past the measured horizon
    wrapped = availability_masks(av, k, c_tr + 7)
    np.testing.assert_array_equal(wrapped[c_tr:], trace[:7])


# ---------------------------------------------------------------------------
# rejection surface
# ---------------------------------------------------------------------------

def test_coefficient_rows_rejects_availability():
    with pytest.raises(TypeError, match="an availability process"):
        coefficient_rows(_prob(), MarkovAvailability(), 4)
    with pytest.raises(TypeError, match="solve_rows_availability"):
        coefficient_rows(_prob(), TraceAvailability(np.ones((1, 3), bool)), 4)


def test_coefficient_rows_still_rejects_state_coupled():
    with pytest.raises(TypeError, match="a state-coupled drift"):
        coefficient_rows(_prob(), QueueDrift(), 4)


def test_orchestrator_rejects_availability():
    prob = _prob()
    params = mlp.init(jax.random.key(0))
    with pytest.raises(TypeError, match="no offline semantics"):
        Orchestrator(MELConfig(T=6.0, total_samples=60), prob, mlp.loss,
                     params, drift=MarkovAvailability())
