"""Sharded fleet path: the fleet engine on a REAL multi-device mesh.

Needs >= 8 devices — the fleet-scale CI step provides them by setting
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
initializes, so ``host_mesh()`` resolves to the (2, 4) ``"test"`` spec and
``compat.shard_map`` genuinely partitions the fleet axis. On fewer
devices every test here skips (tier-1 covers the 1-device semantics in
``tests/test_fleet.py``).

The sharded solve is NOT asserted equal to the 1-device solve: the
per-shard batch shape changes the residual-sum reduction order, which can
move tau* within the bisection tolerance and shift +-1 sample between
remainder-tied learners (the repo's documented reduction-order ULP
tolerance). The invariants below are what the engine actually relies on:
feasibility, exact budget totals, box bounds, and padded/sampled-out rows
solving to zeros.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.fed.fleet import FleetConfig, FleetEngine, build_fleet_problems
from repro.launch.mesh import host_device_flags, host_mesh
from repro.models import mlp
from repro.sharding.rules import fleet_partition_axes

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason=f"needs >= 8 devices: set XLA_FLAGS={host_device_flags(8)} "
           "before jax import (the fleet-scale CI step does)",
)


@pytest.fixture(scope="module")
def data():
    from repro.data.pipeline import synthetic_mnist

    return synthetic_mnist(1200, n_test=200, seed=0)


def test_host_mesh_resolves_test_spec():
    mesh = host_mesh()
    assert dict(mesh.shape) == {"data": 2, "model": 4}
    assert fleet_partition_axes(16, mesh) == ("data", "model")


def test_sharded_solve_invariants():
    """One shard_map'd batched_policy call over 16 fleets split across 8
    devices: feasible rows, exact per-fleet budgets, box bounds, zeros in
    sampled-out rows."""
    mesh = host_mesh()
    bp = build_fleet_problems(16, 4, T=6.0, total_samples=40, seed=0)
    eng = FleetEngine(FleetConfig(), bp, mlp.loss,
                      mlp.init(jax.random.key(0)), seed=0, mesh=mesh)
    assert eng.fleet_axes == ("data", "model")

    sampled = np.zeros(16, bool)
    sampled[::2] = True
    tau, d = eng._solve(sampled)
    assert eng._last_feasible.all()
    assert (tau[~sampled] == 0).all() and (d[~sampled] == 0).all()
    np.testing.assert_array_equal(
        d[sampled].sum(axis=1), np.asarray(bp.total)[sampled]
    )
    assert (d[sampled] >= np.asarray(bp.d_lo)[sampled]).all()
    assert (d[sampled] <= np.asarray(bp.d_hi)[sampled]).all()


def test_engine_runs_sharded_with_padding(data):
    """F = 10 pads to 16 on the 8-device mesh: the run trains, merges and
    re-solves with padded fleets never sampled, never weighted, and real
    fleets accruing version staleness under 50% participation."""
    train, test = data
    eng = FleetEngine(
        FleetConfig(participation=0.5),
        build_fleet_problems(10, 3, T=6.0, total_samples=30, seed=2),
        mlp.loss, mlp.init(jax.random.key(0)), seed=1,
    )
    assert eng.problems.c2.shape[0] == 16          # padded to the mesh
    assert eng._real.sum() == 10
    hist = eng.run(train, 3, eval_fn=mlp.accuracy,
                   eval_batch=(test.x, test.y))
    assert [r["sampled_fleets"] for r in hist] == [5, 5, 5]
    assert all(np.isfinite(r["accuracy"]) for r in hist)
    assert all((r["d"].sum(axis=1) == 30).all() for r in hist)
    assert max(r["fleet_staleness_max"] for r in hist) >= 1
    # padded fleets never merge: their pull version stays at the origin
    assert (eng.pull_version[~eng._real] == 0).all()
    assert eng.pull_version[eng._real].max() == eng.global_version == 3
