"""Integration tests for the asynchronous MEL system (orchestrator +
data pipeline + aggregation + checkpointing)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.core import aggregate, fedavg_weights, staleness_weights
from repro.data.pipeline import FederatedPartitioner, synthetic_mnist
from repro.fed.orchestrator import local_train
from repro.fed.simulation import build_problem, run_experiment, staleness_sweep
from repro.models import mlp


def test_synthetic_mnist_learnable():
    train, test = synthetic_mnist(4000, n_test=1000, seed=0)
    params = mlp.init(jax.random.key(0))
    batch = {"x": jnp.asarray(train.x[:1000]), "y": jnp.asarray(train.y[:1000])}
    for _ in range(25):
        g = jax.grad(mlp.loss)(params, batch)
        params = jax.tree_util.tree_map(lambda p, gi: p - 0.1 * gi, params, g)
    acc = float(mlp.accuracy(params, jnp.asarray(test.x), jnp.asarray(test.y)))
    assert acc > 0.6


def test_partitioner_sizes_and_disjoint():
    train, _ = synthetic_mnist(2000, n_test=10, seed=1)
    part = FederatedPartitioner(train, seed=0)
    d = np.array([100, 300, 50])
    shards = part.draw(d)
    assert [s.size for s in shards] == [100, 300, 50]
    # one replace=False draw split contiguously: shards are disjoint ...
    flat = np.concatenate([np.asarray(s.x).view(np.uint8).reshape(s.size, -1)
                           for s in shards])
    assert len(np.unique(flat, axis=0)) == 450
    # ... and, with (seed, draw-index)-keyed draws, cross-process stable
    np.testing.assert_array_equal(
        FederatedPartitioner(train, seed=0).draw_indices(450)[:6],
        [1902, 1843, 896, 84, 1768, 974],
    )


def test_local_train_masked_tau():
    """Learners with tau=0 must return the global params untouched; higher
    tau must move farther."""
    train, _ = synthetic_mnist(600, n_test=10, seed=2)
    params = mlp.init(jax.random.key(1))
    k, dmax = 3, 200
    x = jnp.asarray(train.x[: k * dmax].reshape(k, dmax, -1))
    y = jnp.asarray(train.y[: k * dmax].reshape(k, dmax))
    m = jnp.ones((k, dmax), jnp.float32)
    tau = jnp.asarray([0, 1, 8])
    out = local_train(params, x, y, m, tau, jnp.float32(0.05), max_tau=8, loss_fn=mlp.loss)

    def dist(i):
        return float(
            sum(
                jnp.sum((jax.tree_util.tree_leaves(out)[j][i] - l) ** 2)
                for j, l in enumerate(jax.tree_util.tree_leaves(params))
            )
        )

    assert dist(0) == 0.0
    assert 0.0 < dist(1) < dist(2)


def test_staleness_weights_reduce_to_fedavg():
    d = np.array([100, 200, 300])
    tau = np.array([4, 4, 4])
    np.testing.assert_allclose(staleness_weights(tau, d), fedavg_weights(d))
    tau2 = np.array([1, 4, 4])
    w = staleness_weights(tau2, d)
    assert w[0] < fedavg_weights(d)[0]  # stale learner downweighted


def test_aggregate_weighted_mean():
    models = {"w": jnp.arange(6, dtype=jnp.float32).reshape(3, 2)}
    w = jnp.asarray([0.5, 0.25, 0.25])
    out = aggregate(models, w)
    np.testing.assert_allclose(out["w"], np.array([1.5, 2.5]))


@pytest.mark.slow
def test_end_to_end_accuracy_improves():
    res = run_experiment(k=6, T=15.0, cycles=4, scheme="kkt_sai", total_samples=3000, seed=1)
    accs = [h["accuracy"] for h in res["history"]]
    assert accs[-1] > accs[0]
    assert accs[-1] > 0.7
    assert res["allocation"]["max_staleness"] <= 2


@pytest.mark.slow
def test_optimized_staleness_beats_eta_system_level():
    rows = staleness_sweep([6, 10], 7.5, schemes=("kkt_sai", "eta"), seed=0)
    by = {(r["K"], r["scheme"]): r for r in rows if "error" not in r}
    for k in (6, 10):
        assert by[(k, "kkt_sai")]["max_staleness"] <= by[(k, "eta")]["max_staleness"]


def test_wall_clock_accounting():
    prob = build_problem(5, 7.5, total_samples=2000)
    from repro.core import solve_kkt_sai

    alloc = solve_kkt_sai(prob)
    t = prob.time_model.cycle_time(alloc.tau, alloc.d)
    assert np.all(t <= 7.5 * (1 + 1e-9))


def test_checkpoint_roundtrip(tmp_path):
    params = mlp.init(jax.random.key(3))
    path = tmp_path / "model.npz"
    ckpt.save(path, params, step=7)
    restored = ckpt.restore(path, params)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.load_metadata(path.with_suffix(".json"))["step"] == 7


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    params = mlp.init(jax.random.key(3))
    path = tmp_path / "model.npz"
    ckpt.save(path, params)
    bad = mlp.init(jax.random.key(3), layers=[784, 10, 10])
    with pytest.raises((ValueError, KeyError)):
        ckpt.restore(path, bad)
