"""Loop-aware HLO cost analyzer: validated against hand-unrolled scans and
the builtin HloCostAnalysis on loop-free graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import cost_analysis_dict as _builtin_cost
from repro.compat import shard_map as _shard_map
from repro.roofline.hlo_cost import analyze_hlo


def _body(x, w):
    return jnp.tanh(x @ w), None


def _scanned(x, ws):
    return jax.lax.scan(_body, x, ws)[0]


def _unrolled(x, ws):
    for i in range(ws.shape[0]):
        x, _ = _body(x, ws[i])
    return x


@pytest.mark.parametrize("n", [2, 8, 17])
def test_scan_matches_unroll(n):
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, 256, 256), jnp.float32)
    a_s = analyze_hlo(jax.jit(_scanned).lower(x, ws).compile().as_text())
    a_u = analyze_hlo(jax.jit(_unrolled).lower(x, ws).compile().as_text())
    assert a_s.flops == pytest.approx(a_u.flops, rel=0.05)
    # dot flops dominate: n * 2 * 128 * 256 * 256
    assert a_s.flops == pytest.approx(n * 2 * 128 * 256 * 256, rel=0.05)
    # scan bytes scale with n (state round-trips through HBM each step)
    assert a_s.bytes > n * 128 * 256 * 4


def test_matches_builtin_on_loop_free():
    def f(a, b):
        return jax.nn.relu(a @ b) @ b.T

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    ours = analyze_hlo(compiled.as_text())
    builtin = _builtin_cost(compiled)
    assert ours.flops == pytest.approx(builtin["flops"], rel=0.10)


def test_builtin_undercounts_scans():
    """The reason this module exists."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 256, 256), jnp.float32)
    compiled = jax.jit(_scanned).lower(x, ws).compile()
    builtin = _builtin_cost(compiled)["flops"]
    ours = analyze_hlo(compiled.as_text()).flops
    assert ours > 10 * builtin


def test_nested_scan_multiplies():
    def inner(c, x):
        return c + jnp.sin(x @ x), None

    def outer(c, xs):
        c2, _ = jax.lax.scan(inner, c, xs)
        return c2, None

    def f(c, xss):
        return jax.lax.scan(outer, c, xss)[0]

    c = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    xss = jax.ShapeDtypeStruct((4, 5, 32, 32), jnp.float32)
    ours = analyze_hlo(jax.jit(f).lower(c, xss).compile().as_text())
    # 4*5 = 20 dots of 2*32^3
    assert ours.flops == pytest.approx(20 * 2 * 32**3, rel=0.2)


def test_collectives_scaled_by_trips():
    mesh = jax.make_mesh((1,), ("d",))

    def body(x, _):
        return jax.lax.psum(x, "d"), None

    def f(x):
        return jax.lax.scan(body, x, None, length=6)[0]

    from jax.sharding import NamedSharding, PartitionSpec as P
    from functools import partial

    with mesh:
        g = jax.jit(
            _shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P("d"), check_vma=False)
        )
        compiled = g.lower(jax.ShapeDtypeStruct((8, 16), jnp.float32)).compile()
    ours = analyze_hlo(compiled.as_text())
    total = sum(v["count"] for v in ours.collectives.values())
    # 6 trips x 1 all-reduce (some backends elide on 1 device: allow 0 or 6)
    assert total in (0.0, 6.0)
