"""The §Perf optimization variants must preserve model semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import set_mesh
from repro.configs import get_reduced
from repro.kernels.ref import flash_attention_ref
from repro.models.layers import flash_attention
from repro.models.model import Model
from repro.models.rwkv6 import wkv_chunked, wkv_scan

KEY = jax.random.key(0)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_wkv_chunked_matches_scan(chunk):
    b, s, h, hd = 2, 128, 3, 32
    mk = lambda i, sc=0.5: jax.random.normal(jax.random.key(i), (b, s, h, hd)) * sc
    r, k, v = mk(0), mk(1), mk(2)
    # adversarially strong decays: exponent safety is the point
    w = jnp.exp(-jnp.exp(jax.random.normal(jax.random.key(3), (b, s, h, hd)) * 2.5))
    u = jax.random.normal(jax.random.key(4), (h, hd)) * 0.1
    s0 = jax.random.normal(jax.random.key(5), (b, h, hd, hd)) * 0.1
    y1, st1 = wkv_scan(r, k, v, w, u, s0=s0)
    y2, st2 = wkv_chunked(r, k, v, w, u, s0=s0, chunk=chunk)
    np.testing.assert_allclose(y1, y2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st1, st2, rtol=2e-3, atol=2e-3)


def test_wkv_chunked_no_overflow_extreme_decay():
    b, s, h, hd = 1, 96, 1, 16
    r = jnp.ones((b, s, h, hd)) * 0.3
    k = jnp.ones((b, s, h, hd)) * 0.3
    v = jnp.ones((b, s, h, hd))
    w = jnp.full((b, s, h, hd), 1e-9)      # near-total forgetting each step
    u = jnp.zeros((h, hd))
    y, st = wkv_chunked(r, k, v, w, u, chunk=32)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.all(np.isfinite(np.asarray(st)))
    y2, st2 = wkv_scan(r, k, v, w, u)
    np.testing.assert_allclose(y, y2, rtol=1e-4, atol=1e-4)


def test_attn_q_block_exact():
    q = jax.random.normal(KEY, (2, 128, 4, 32))
    k = jax.random.normal(jax.random.key(1), (2, 128, 2, 32))
    v = jax.random.normal(jax.random.key(2), (2, 128, 2, 32))
    want = flash_attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, chunk=32, q_block=32)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attn_p_bf16_close():
    q = jax.random.normal(KEY, (1, 128, 2, 32))
    k = jax.random.normal(jax.random.key(1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.key(2), (1, 128, 2, 32))
    want = flash_attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, chunk=32, p_bf16=True)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_rwkv_model_chunked_backend_end_to_end():
    cfg = dataclasses.replace(get_reduced("rwkv6-7b"), wkv_backend="chunked", wkv_chunk=8)
    base = get_reduced("rwkv6-7b")
    m1, m2 = Model(base), Model(cfg)
    params = m1.init(KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (2, 32), 0, base.vocab_size),
        "labels": jax.random.randint(jax.random.key(1), (2, 32), 0, base.vocab_size),
    }
    l1 = float(m1.loss(params, batch))
    l2 = float(m2.loss(params, batch))
    assert abs(l1 - l2) < 1e-3


def test_moe_shard_map_matches_plain_vmap():
    cfg = get_reduced("deepseek-moe-16b")
    m = Model(cfg)
    params = m.init(KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size),
    }
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plain = float(m.loss(params, batch))  # no mesh context -> vmap path
    with set_mesh(mesh):
        sharded = float(jax.jit(m.loss)(params, batch))  # shard_map path
    assert abs(plain - sharded) < 1e-4
