"""Property tests for core/aggregation.py and the staleness helpers, plus
the CapacityDrift seed-determinism pin (host coefficient_path vs per-cycle
traced factors_at)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    CapacityDrift,
    TimeModel,
    aggregate,
    fedavg_weights,
    staleness_weights,
)
from repro.core.staleness import (
    staleness_factor,
    version_staleness,
    version_staleness_profile,
)

from tests._prop import given, settings, st


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**20), k=st.integers(1, 12),
       gamma=st.floats(0.1, 5.0))
def test_staleness_weights_zero_staleness_is_fedavg(seed, k, gamma):
    """With every tau equal, the staleness discount is 1 for all learners
    and the weights reduce to FedAvg exactly."""
    rng = np.random.default_rng(seed)
    tau = np.full(k, int(rng.integers(0, 50)))
    d = rng.integers(1, 500, size=k)
    np.testing.assert_allclose(
        staleness_weights(tau, d, gamma=gamma), fedavg_weights(d)
    )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**20), k=st.integers(2, 12),
       gamma=st.floats(0.1, 5.0))
def test_staleness_weights_permutation_equivariant(seed, k, gamma):
    """Relabeling learners permutes the weights the same way (no hidden
    positional dependence), and the weights always form a distribution
    that downweights stale learners."""
    rng = np.random.default_rng(seed)
    tau = rng.integers(0, 30, size=k)
    d = rng.integers(1, 500, size=k)
    w = staleness_weights(tau, d, gamma=gamma)
    np.testing.assert_allclose(w.sum(), 1.0)
    perm = rng.permutation(k)
    np.testing.assert_allclose(
        staleness_weights(tau[perm], d[perm], gamma=gamma), w[perm]
    )
    # stalest learner never outweighs a fresher learner with >= data
    i = int(np.argmin(tau))   # most stale (tau_max - tau largest)
    j = int(np.argmax(tau))
    if d[i] <= d[j]:
        assert w[i] <= w[j] + 1e-12


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20), k=st.integers(1, 6))
def test_aggregate_is_weighted_mean(seed, k):
    """aggregate() reproduces the numpy weighted sum on every leaf."""
    rng = np.random.default_rng(seed)
    models = {
        "w": jnp.asarray(rng.standard_normal((k, 4, 3)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal((k, 5)).astype(np.float32)),
    }
    w = rng.random(k).astype(np.float32) + 0.1
    w /= w.sum()
    out = aggregate(models, jnp.asarray(w))
    for name in models:
        ref = np.tensordot(w, np.asarray(models[name]), axes=(0, 0))
        np.testing.assert_allclose(np.asarray(out[name]), ref, atol=1e-6)


def test_staleness_factor_properties():
    s = np.arange(0, 20)
    for kind in ("constant", "hinge", "poly"):
        f = staleness_factor(s, kind=kind, a=0.5, b=4.0)
        assert np.all(f <= 1.0 + 1e-12) and np.all(f > 0)
        assert np.all(np.diff(f) <= 1e-12)          # non-increasing
        assert staleness_factor(0, kind=kind) == 1.0
    # hinge is flat until the knee, then decays
    h = staleness_factor(s, kind="hinge", a=0.5, b=4.0)
    assert np.all(h[:5] == 1.0) and h[5] < 1.0
    # version staleness clamps at zero
    np.testing.assert_array_equal(
        version_staleness([3, 5, 2], [1, 5, 4]), [2, 0, 0]
    )
    prof = version_staleness_profile([0, 1, 2, 3])
    assert prof["max"] == 3 and prof["count"] == 4 and prof["frac_stale"] == 0.75


# ---------------------------------------------------------------------------
# CapacityDrift: host path vs traced per-cycle factors
# ---------------------------------------------------------------------------

def test_capacity_drift_path_matches_traced_factors_at():
    """``coefficient_path`` (the host materialization the eager paths use)
    replays the per-cycle ``factors_at`` sequence the fused scan evaluates
    on the traced cycle index. The f32 random draws are bit-identical in
    both contexts; the dB->linear transcendental may differ by 1 f32 ULP
    between jit-fused and eager compilation (the documented contract), so
    the rows are pinned to ULP tolerance AND the derived integer
    allocations are pinned exactly."""
    k = 7
    tm = TimeModel(
        c2=np.linspace(1e-4, 5e-3, k),
        c1=np.linspace(1e-5, 1e-3, k),
        c0=np.linspace(0.05, 0.5, k),
    )
    drift = CapacityDrift(clock_jitter=0.2, fading_sigma_db=2.5, seed=123)
    cycles = 6
    c2s, c1s, c0s = drift.coefficient_path(tm, cycles)

    from jax.experimental import enable_x64

    @jax.jit
    def traced_row(c):
        clock, rate = drift.factors_at(c, k)
        f64 = jnp.float64
        return (jnp.asarray(tm.c2, f64) / clock.astype(f64),
                jnp.asarray(tm.c1, f64) / rate.astype(f64),
                jnp.asarray(tm.c0, f64) / rate.astype(f64))

    from repro.core import AllocationProblem
    from repro.fed.orchestrator import _jitted_policy, policy_problem_args

    prob = AllocationProblem(time_model=tm, T=1.0, total_samples=70,
                             d_lower=2, d_upper=40)
    policy = _jitted_policy("kkt_sai")
    T1, total1, lo1, hi1, valid1 = policy_problem_args(prob)

    with enable_x64():
        for c in range(cycles):
            r2, r1, r0 = traced_row(c)
            # clock factors divide exactly; rate-driven rows to 1 f32 ULP
            np.testing.assert_array_equal(np.asarray(r2), c2s[c])
            np.testing.assert_allclose(np.asarray(r1), c1s[c], rtol=2e-7)
            np.testing.assert_allclose(np.asarray(r0), c0s[c], rtol=2e-7)
            # ...and the integer allocations agree exactly
            args = (jnp.asarray(T1), jnp.asarray(total1), jnp.asarray(lo1),
                    jnp.asarray(hi1), jnp.asarray(valid1))
            ta, da, _ = policy(jnp.asarray(r2[None]), jnp.asarray(r1[None]),
                               jnp.asarray(r0[None]), *args)
            tb, db, _ = policy(jnp.asarray(c2s[c][None]),
                               jnp.asarray(c1s[c][None]),
                               jnp.asarray(c0s[c][None]), *args)
            np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
            np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


def test_capacity_drift_seed_determinism():
    """Same seed => identical path; different seed => different path."""
    k, cycles = 5, 4
    tm = TimeModel(c2=np.full(k, 1e-3), c1=np.full(k, 1e-4),
                   c0=np.full(k, 0.1))
    a = CapacityDrift(seed=9).coefficient_path(tm, cycles)
    b = CapacityDrift(seed=9).coefficient_path(tm, cycles)
    c = CapacityDrift(seed=10).coefficient_path(tm, cycles)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))
