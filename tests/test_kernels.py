"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fed_agg import fed_agg_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.swiglu import swiglu_pallas
from repro.kernels.wkv6 import wkv6_pallas
from repro.models.layers import flash_attention as flash_chunked


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,h,kvh,d,causal,window,bq,bk",
    [
        (2, 128, 128, 4, 2, 64, True, None, 64, 64),
        (1, 256, 256, 8, 8, 32, True, None, 128, 64),
        (2, 64, 64, 4, 1, 64, True, 32, 32, 32),
        (1, 128, 128, 2, 2, 128, False, None, 64, 64),
        (1, 192, 192, 4, 2, 64, True, None, 64, 64),
    ],
)
def test_flash_attention_sweep(b, sq, skv, h, kvh, d, causal, window, bq, bk, dtype):
    q = (jax.random.normal(jax.random.key(0), (b, sq, h, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.key(1), (b, skv, kvh, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.key(2), (b, skv, kvh, d)) * 0.5).astype(dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk, interpret=True
    )
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


def test_flash_attention_chunked_jnp_matches_dense_ref():
    """The model-side chunked scan (used in training) against the dense ref."""
    q = jax.random.normal(jax.random.key(0), (2, 96, 4, 32))
    k = jax.random.normal(jax.random.key(1), (2, 96, 2, 32))
    v = jax.random.normal(jax.random.key(2), (2, 96, 2, 32))
    for window in (None, 24):
        out = flash_chunked(q, k, v, causal=True, window=window, chunk=32)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,hd,bt,with_state",
    [
        (2, 128, 2, 64, 32, True),
        (1, 96, 4, 32, 48, False),
        (3, 64, 1, 64, 64, True),
    ],
)
def test_wkv6_sweep(b, s, h, hd, bt, with_state, dtype):
    mk = lambda i, scale=0.5: (jax.random.normal(jax.random.key(i), (b, s, h, hd)) * scale).astype(dtype)
    r, k, v = mk(0), mk(1), mk(2)
    w = (jax.nn.sigmoid(jax.random.normal(jax.random.key(3), (b, s, h, hd))) * 0.5 + 0.45).astype(dtype)
    u = (jax.random.normal(jax.random.key(4), (h, hd)) * 0.1).astype(jnp.float32)
    s0 = (
        jax.random.normal(jax.random.key(5), (b, h, hd, hd)).astype(jnp.float32) * 0.1
        if with_state else None
    )
    y, s_last = wkv6_pallas(r, k, v, w, u, s0=s0, block_t=bt, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, w, u, s0=s0)
    np.testing.assert_allclose(y, yr, **_tol(dtype))
    np.testing.assert_allclose(s_last, sr, **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("k,shape,block", [(4, (1000,), 256), (8, (37, 53), 512), (2, (4096,), 4096)])
def test_fed_agg_sweep(k, shape, block, dtype):
    x = (jax.random.normal(jax.random.key(0), (k, *shape)) * 2.0).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.key(1), (k,)))
    out = fed_agg_pallas(x, w, block_n=block, interpret=True)
    want = ref.fed_agg_ref(x, w)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )
    assert out.shape == shape and out.dtype == x.dtype


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,f,bm,bf", [(64, 128, 256, 32, 128), (96, 64, 192, 96, 64)])
def test_swiglu_sweep(m, d, f, bm, bf, dtype):
    x = (jax.random.normal(jax.random.key(0), (m, d)) * 0.5).astype(dtype)
    wg = (jax.random.normal(jax.random.key(1), (d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(jax.random.key(2), (d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(jax.random.key(3), (f, d)) * 0.05).astype(dtype)
    out = swiglu_pallas(x, wg, wu, wd, block_m=bm, block_f=bf, interpret=True)
    want = ref.swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


def test_ops_dispatch_pallas_interpret():
    """The ops-layer use_pallas path is exercisable end-to-end (interpret)."""
    from repro.kernels import ops

    q = jax.random.normal(jax.random.key(0), (1, 64, 2, 32))
    k = jax.random.normal(jax.random.key(1), (1, 64, 2, 32))
    v = jax.random.normal(jax.random.key(2), (1, 64, 2, 32))
    a = ops.flash_attention(q, k, v, use_pallas=True, interpret=True)
    b = ops.flash_attention(q, k, v, use_pallas=False, chunk=32)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bsz,s,d,n,bd,bt", [(2, 96, 64, 8, 32, 32), (1, 128, 32, 16, 32, 64)])
def test_mamba_scan_sweep(bsz, s, d, n, bd, bt, dtype):
    from repro.kernels.mamba_scan import mamba_scan_pallas

    key = jax.random.key
    dt = jax.nn.softplus(jax.random.normal(key(0), (bsz, s, d)) * 0.5).astype(dtype)
    x = (jax.random.normal(key(1), (bsz, s, d)) * 0.5).astype(dtype)
    b = (jax.random.normal(key(2), (bsz, s, n)) * 0.5).astype(dtype)
    c = (jax.random.normal(key(3), (bsz, s, n)) * 0.5).astype(dtype)
    a = -jnp.exp(jax.random.normal(key(4), (d, n)) * 0.3)
    h0 = jax.random.normal(key(5), (bsz, d, n)) * 0.1
    yp, hp = mamba_scan_pallas(dt, x, b, c, a, h0, block_d=bd, block_t=bt, interpret=True)
    yr, hr = ref.mamba_scan_ref(dt, x, b, c, a, h0)
    np.testing.assert_allclose(yp, yr, **_tol(dtype))
    np.testing.assert_allclose(hp, hr, **_tol(dtype))


# ---------------------------------------------------------------------------
# waterfill residual: clip-boundary edge cases (Pallas interpret vs ref)
# ---------------------------------------------------------------------------

def _waterfill_case(b, k, tau, scale_T=1.0):
    rng = np.random.default_rng(b * 7 + k)
    c2 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c1 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c0 = jnp.asarray(rng.uniform(0.1, 2.0, (b, k)), jnp.float32)
    T = jnp.asarray(rng.uniform(5.0, 20.0, (b,)) * scale_T, jnp.float32)
    lo = jnp.full((b, k), 10.0, jnp.float32)
    hi = jnp.full((b, k), 900.0, jnp.float32)
    tot = jnp.asarray(rng.uniform(1e3, 5e3, (b,)), jnp.float32)
    return jnp.full((b,), tau, jnp.float32), c2, c1, c0, T, lo, hi, tot


@pytest.mark.parametrize(
    "name,tau,scale_T",
    [
        # tau* so large every learner clips at d_lo: residual == K*lo - total
        ("all_saturated_lo", 1e6, 1.0),
        # tau* = 0 with a huge budget: every learner clips at d_hi
        ("all_slack_hi", 0.0, 1e4),
    ],
)
@pytest.mark.parametrize("b,k", [(4, 10), (3, 37)])
def test_waterfill_residual_all_clipped(name, tau, scale_T, b, k):
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_residual_ref

    args = _waterfill_case(b, k, tau, scale_T)
    got = ops.waterfill_residual(*args, use_pallas=True, interpret=True)
    want = waterfill_residual_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-3)
    # closed form at the clip boundary
    _, _, _, _, _, lo, hi, tot = args
    bound = lo if name == "all_saturated_lo" else hi
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(bound.sum(axis=1) - tot), rtol=2e-5, atol=2e-3
    )


def test_waterfill_residual_k1_fleet():
    """K=1 fleets: the lane axis is pure padding; the single learner's
    clipped absorption must survive the 128-lane pad exactly."""
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_residual_ref

    args = _waterfill_case(5, 1, 2.0)
    got = ops.waterfill_residual(*args, use_pallas=True, interpret=True)
    want = waterfill_residual_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-3)
    tau, c2, c1, c0, T, lo, hi, tot = (np.asarray(a) for a in args)
    d = np.clip((T[:, None] - c0) / (c2 * tau[:, None] + c1), lo, hi)
    np.testing.assert_allclose(
        np.asarray(got), d.sum(axis=1) - tot, rtol=2e-5, atol=2e-3
    )


# ---------------------------------------------------------------------------
# energy-budgeted waterfill residual (Pallas interpret vs ref)
# ---------------------------------------------------------------------------

def _energy_case(b, k, tau, scale_T=1.0, eb_value=None):
    """The ``_waterfill_case`` fixtures extended with energy rows: same
    time coefficients and seeding, plus ``(e2, e1, e0, eb)`` drawn from
    the same generator (``eb_value`` pins the budget, e.g. +inf)."""
    tau_v, c2, c1, c0, T, lo, hi, tot = _waterfill_case(b, k, tau, scale_T)
    rng = np.random.default_rng(b * 7 + k + 1000)
    e2 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    e1 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    e0 = jnp.asarray(rng.uniform(0.05, 1.0, (b, k)), jnp.float32)
    eb = jnp.asarray(
        np.full((b, k), eb_value) if eb_value is not None
        else rng.uniform(2.0, 12.0, (b, k)),
        jnp.float32,
    )
    return tau_v, c2, c1, c0, T, e2, e1, e0, eb, lo, hi, tot


@pytest.mark.parametrize(
    "name,tau,scale_T",
    [
        # tau* so large both hyperbolae collapse: every learner clips at d_lo
        ("all_saturated_lo", 1e6, 1.0),
        # tau* = 0 with huge deadline AND budget: every learner clips at d_hi
        ("all_slack_hi", 0.0, 1e4),
    ],
)
@pytest.mark.parametrize("b,k", [(4, 10), (3, 37)])
def test_waterfill_energy_residual_all_clipped(name, tau, scale_T, b, k):
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_energy_residual_ref

    eb_value = 1e9 if name == "all_slack_hi" else None
    args = _energy_case(b, k, tau, scale_T, eb_value=eb_value)
    got = ops.waterfill_energy_residual(*args, use_pallas=True, interpret=True)
    want = waterfill_energy_residual_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)
    lo, hi, tot = args[9], args[10], args[11]
    bound = lo if name == "all_saturated_lo" else hi
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(bound.sum(axis=1) - tot),
        rtol=2e-5, atol=2e-3,
    )


def test_waterfill_energy_residual_binding_budget():
    """Mid-range tau* with finite budgets: the energy hyperbola binds for
    some learners and the kernel must pick min(d_time, d_energy) per
    learner, exactly as the ref does."""
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_energy_residual_ref

    args = _energy_case(4, 10, 2.0)
    got = ops.waterfill_energy_residual(*args, use_pallas=True, interpret=True)
    want = waterfill_energy_residual_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)
    tau, c2, c1, c0, T, e2, e1, e0, eb, lo, hi, tot = (
        np.asarray(a) for a in args
    )
    dt = (T[:, None] - c0) / (c2 * tau[:, None] + c1)
    de = (eb - e0) / (e2 * tau[:, None] + e1)
    assert (de < dt).any(), "fixture must make the budget bind somewhere"
    d = np.clip(np.minimum(dt, de), lo, hi)
    np.testing.assert_allclose(
        np.asarray(got), d.sum(axis=1) - tot, rtol=2e-5, atol=2e-3
    )


def test_waterfill_energy_residual_inf_budget_matches_time_only():
    """eb = +inf rows reproduce the unbudgeted residual BITWISE on both
    backends (IEEE min(d_time, inf) selects the time branch)."""
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_residual_ref

    args = _energy_case(3, 37, 2.0, eb_value=np.inf)
    tau, c2, c1, c0, T = args[:5]
    lo, hi, tot = args[9], args[10], args[11]
    time_only = (tau, c2, c1, c0, T, lo, hi, tot)
    for use_pallas in (False, True):
        got = ops.waterfill_energy_residual(
            *args, use_pallas=use_pallas, interpret=use_pallas
        )
        want = ops.waterfill_residual(
            *time_only, use_pallas=use_pallas, interpret=use_pallas
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_waterfill_energy_residual_k1_fleet():
    """K=1 fleets: the single learner's budgeted absorption must survive
    the 128-lane pad exactly (pad lanes use unit rows + zero box)."""
    from repro.kernels import ops
    from repro.kernels.ref import waterfill_energy_residual_ref

    args = _energy_case(5, 1, 2.0)
    got = ops.waterfill_energy_residual(*args, use_pallas=True, interpret=True)
    want = waterfill_energy_residual_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)
