"""Sharding-rule unit tests + a dry-run smoke in a subprocess (so the main
pytest process never sees a forced device count)."""

import json
import os
import pathlib
import subprocess
import sys

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model import Model
from repro.roofline.analysis import hlo_collectives, roofline_terms
from repro.sharding import rules as R

REPO = pathlib.Path(__file__).resolve().parents[1]


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by resolve_spec."""

    def __init__(self, shape: dict):
        self.shape = shape


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_spans_pod_and_data():
    spec = R.resolve_spec(("batch", "seq"), (256, 4096), MULTI, R.TRAIN_RULES)
    assert spec == P(("pod", "data"), None)


def test_divisibility_fallback_drops_axis():
    # whisper: 12 heads on a 16-way model axis -> replicate
    spec = R.resolve_spec(("embed", "heads", "head_dim"), (768, 12, 64), POD, R.TRAIN_RULES)
    assert spec == P("data", None, None)


def test_batch_one_falls_back_to_replicated_and_seq_shards():
    spec = R.resolve_spec(("batch", "cache_seq", "kv_heads", "head_dim"),
                          (1, 524288, 8, 128), POD, R.SERVE_RULES)
    assert spec == P(None, "data", None, None)


def test_no_mesh_axis_reused_within_leaf():
    spec = R.resolve_spec(("mlp", "mlp"), (1024, 1024), POD, R.TRAIN_RULES)
    assert spec == P("model", None)


def test_serve_rules_weight_stationary():
    spec = R.resolve_spec(("embed", "heads", "head_dim"), (4096, 32, 128), POD, R.SERVE_RULES)
    assert spec == P(None, "model", None)


def test_expert_parallel_rules():
    spec = R.resolve_spec(("experts", "embed", "moe_mlp"), (64, 2048, 1408), POD,
                          R.EXPERT_PARALLEL_RULES)
    assert spec == P("model", "data", None)


def test_param_shardings_cover_whole_tree():
    cfg = get_config("llama3-8b")
    m = Model(cfg)
    sh = R.tree_shardings(m.param_axes(), m.abstract_params(), POD_REAL(), R.TRAIN_RULES)
    leaves = jax.tree_util.tree_leaves(sh)
    assert leaves and all(l is not None for l in leaves)


def POD_REAL():
    # a real (tiny) mesh with the production axis names for NamedSharding
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %ar = f32[1024,128]{1,0} all-reduce(f32[1024,128]{1,0} %x), replica_groups={}
  %ag.1 = bf16[64,256]{1,0} all-gather(bf16[8,256]{1,0} %y), dimensions={0}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(f32[16,16]{1,0} %a, f32[16,16]{1,0} %b)
  %cp-start = bf16[32]{0} collective-permute-start(bf16[32]{0} %z)
  %cp-done = bf16[32]{0} collective-permute-done(bf16[32]{0} %cp-start)
"""


def test_hlo_collective_parser():
    c = hlo_collectives(HLO_SAMPLE)
    assert c["all-reduce"]["bytes"] == 1024 * 128 * 4
    assert c["all-gather"]["bytes"] == 64 * 256 * 2
    assert c["all-to-all"]["bytes"] == 2 * 16 * 16 * 4
    assert c["collective-permute"]["count"] == 1          # -done not double counted
    assert c["collective-permute"]["bytes"] == 32 * 2


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e9 * 0.5, {"all-reduce": {"bytes": 0, "count": 0}})
    assert t["dominant"] == "compute"
    t2 = roofline_terms(1.0, 1.0, {"all-reduce": {"bytes": int(50e9), "count": 1}})
    assert t2["dominant"] == "collective"


# ---------------------------------------------------------------------------
# dry-run smoke (subprocess with 8 fake devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize(
    "arch,shape",
    [
        ("llama3-8b", "train_4k"),
        ("deepseek-moe-16b", "decode_32k"),
        ("rwkv6-7b", "long_500k"),
        ("jamba-v0.1-52b", "train_4k"),
    ],
)
def test_dryrun_subprocess(arch, shape, tmp_path):
    mesh = "multitest"
    env = dict(os.environ)
    env["REPRO_DRYRUN_XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads((tmp_path / f"{arch}__{shape}__{mesh}.json").read_text())
    assert rec["flops_per_device"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
