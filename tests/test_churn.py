"""Churn robustness: fault injection (drops / delays / stragglers),
deadline-retry redispatch, quorum-degraded buffered flushes, and
availability-gated dispatch — all through the schedule/execute split, so
the event-indexed (jagged) scan must replay the eager loop EXACTLY under
every fault schedule. Pins:

  * ``AsyncConfig`` fault-knob validation and the engine's churn guards;
  * termination and counter bookkeeping of the faulty scheduler
    (all-drop fleets, deadline retries, quorum timers);
  * the ``_event_segments`` invariants under dropped/retried arrivals;
  * eager-vs-jagged bitwise equivalence with the full fault cocktail on
    (property-tested over drop rate x mode x staleness_fn);
  * fault counters surfacing through ``summarize_async_history`` and
    ``fed.simulation.run_async_experiment``.
"""

import numpy as np
import pytest

import jax

from repro.core import (
    AllocationProblem,
    MarkovAvailability,
    QueueDrift,
    TimeModel,
    TraceAvailability,
)
from repro.data.pipeline import FederatedPartitioner, synthetic_mnist
from repro.fed.async_engine import (
    FAULT_COUNTERS,
    AsyncConfig,
    AsyncFedEngine,
    _event_segments,
    summarize_async_history,
)
from repro.fed.simulation import run_async_experiment
from repro.models import mlp

from tests._prop import given, settings, st


@pytest.fixture(scope="module")
def data():
    return synthetic_mnist(1200, n_test=50, seed=0)


def _prob(k: int = 3) -> AllocationProblem:
    tm = TimeModel(c2=np.full(k, 0.04), c1=np.full(k, 0.004),
                   c0=np.full(k, 0.4))
    return AllocationProblem(time_model=tm, T=6.0, total_samples=60,
                             d_lower=10, d_upper=40)


def _cocktail(**kw) -> AsyncConfig:
    base = dict(mode="buffered", buffer_size=3, alpha=0.6,
                drop_rate=0.25, delay_rate=0.3, delay_mean=2.0,
                straggler_rate=0.25, straggler_factor=3.0,
                deadline=15.0, retry_backoff=1.5, retry_backoff_cap=6.0,
                quorum=2, flush_timeout=9.0)
    base.update(kw)
    return AsyncConfig(**base)


def _run_both(cfg, prob, train, horizon, *, seed=2, drift=None):
    params = mlp.init(jax.random.key(1))
    e1 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed, drift=drift)
    h1 = e1.run(train, horizon)
    e2 = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed, drift=drift)
    h2 = e2.run_events(train, horizon)
    return e1, h1, e2, h2


def _assert_history_match(h1, h2):
    assert len(h1) == len(h2)
    for r1, r2 in zip(h1, h2):
        assert r1["learners"] == r2["learners"]
        assert r1["staleness_list"] == r2["staleness_list"]
        assert r1["server_version"] == r2["server_version"]
        assert r1["t"] == r2["t"]
        np.testing.assert_array_equal(r1["weights"], r2["weights"])
        np.testing.assert_array_equal(r1["tau"], r2["tau"])
        np.testing.assert_array_equal(r1["d"], r2["d"])
        assert r1["keep"] == r2["keep"]


def _assert_params_close(e1, e2, atol=1e-5):
    for a, b in zip(jax.tree_util.tree_leaves(e1.params),
                    jax.tree_util.tree_leaves(e2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


# ---------------------------------------------------------------------------
# config validation + engine guards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(drop_rate=1.5), "drop_rate"),
    (dict(straggler_rate=-0.1), "straggler_rate"),
    (dict(straggler_rate=0.5, straggler_factor=0.5), "straggler_factor"),
    (dict(delay_rate=0.5, delay_mean=0.0), "delay_mean"),
    (dict(deadline=-1.0), "deadline must be"),
    (dict(deadline=5.0, retry_backoff=0.0), "retry_backoff > 0"),
    (dict(deadline=5.0, retry_backoff=2.0, retry_backoff_cap=1.0),
     "retry_backoff_cap"),
    (dict(quorum=-1), "quorum must be"),
    (dict(mode="fedasync", quorum=2, flush_timeout=3.0), "buffered"),
    (dict(mode="buffered", quorum=2), "flush_timeout > 0"),
    (dict(mode="buffered", flush_timeout=3.0), "flush_timeout without"),
    (dict(mode="buffered", barrier=True, drop_rate=0.1),
     "fault-free paper regime"),
    (dict(mode="buffered", barrier=True, deadline=5.0),
     "fault-free paper regime"),
])
def test_config_rejects_bad_fault_knobs(kw, match):
    with pytest.raises(ValueError, match=match):
        AsyncConfig(**kw)


def test_has_faults_flag():
    assert not AsyncConfig().has_faults
    assert not AsyncConfig(mode="buffered", barrier=True).has_faults
    assert AsyncConfig(drop_rate=0.1).has_faults
    assert AsyncConfig(delay_rate=0.1).has_faults
    assert AsyncConfig(straggler_rate=0.1).has_faults
    assert AsyncConfig(deadline=5.0).has_faults
    assert AsyncConfig(mode="buffered", quorum=1, flush_timeout=2.0).has_faults


def test_engine_guards(data):
    train, _ = data
    prob = _prob()
    params = mlp.init(jax.random.key(0))
    with pytest.raises(ValueError, match="quorum .* buffer_size"):
        AsyncFedEngine(
            AsyncConfig(mode="buffered", buffer_size=2, quorum=3,
                        flush_timeout=5.0),
            prob, mlp.loss, params,
        )
    with pytest.raises(ValueError, match="no barrier regime"):
        AsyncFedEngine(
            AsyncConfig(mode="buffered", barrier=True),
            prob, mlp.loss, params, drift=MarkovAvailability(),
        )
    # churn over a queue-coupled base inherits the reallocate requirement
    with pytest.raises(ValueError, match="reallocate=True"):
        AsyncFedEngine(
            AsyncConfig(mode="fedasync"), prob, mlp.loss, params,
            drift=MarkovAvailability(base=QueueDrift()),
        )
    # ... but churn over a plain/exogenous base does NOT (frozen schedule)
    AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss, params,
                   drift=MarkovAvailability())


# ---------------------------------------------------------------------------
# scheduler: termination + counters
# ---------------------------------------------------------------------------

def test_all_drop_no_deadline_terminates_empty(data):
    """Every upload lost and no deadline: the run ends (no events left)
    with an empty history instead of spinning."""
    train, _ = data
    prob = _prob()
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync", drop_rate=1.0),
                         prob, mlp.loss, mlp.init(jax.random.key(0)), seed=2)
    hist = eng.run(train, 30.0)
    assert hist == []
    c = eng.fault_counters
    assert set(c) == set(FAULT_COUNTERS)
    assert c["dispatches"] == c["drops"] == prob.num_learners
    assert c["retries"] == 0


def test_all_drop_with_deadline_keeps_retrying(data):
    """Deadlines turn a silent drop into a miss + capped-backoff retry:
    the fleet keeps redispatching until the horizon, never stalling."""
    train, _ = data
    prob = _prob()
    eng = AsyncFedEngine(
        AsyncConfig(mode="fedasync", drop_rate=1.0, deadline=8.0,
                    retry_backoff=1.0, retry_backoff_cap=4.0),
        prob, mlp.loss, mlp.init(jax.random.key(0)), seed=2,
    )
    hist = eng.run(train, 40.0)
    assert hist == []                       # nothing ever arrives ...
    c = eng.fault_counters
    assert c["deadline_misses"] == c["retries"] > 0   # ... but we retried
    assert c["dispatches"] == prob.num_learners + c["retries"]
    assert c["drops"] == c["dispatches"]


def test_straggler_deadline_late_discard(data):
    """A guaranteed straggler blows every deadline: the in-flight task is
    cancelled, its late upload discarded, and the retry (still straggling)
    repeats — versions only ever advance via fresh dispatches."""
    train, _ = data
    prob = _prob()
    eng = AsyncFedEngine(
        AsyncConfig(mode="fedasync", straggler_rate=1.0,
                    straggler_factor=50.0, deadline=6.0, retry_backoff=1.0),
        prob, mlp.loss, mlp.init(jax.random.key(0)), seed=2,
    )
    hist = eng.run(train, 30.0)
    c = eng.fault_counters
    assert c["stragglers"] == c["dispatches"] > prob.num_learners
    assert c["deadline_misses"] > 0
    assert hist == [] or c["late_discards"] > 0


def test_fault_free_counters_are_zero(data):
    train, _ = data
    prob = _prob()
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss,
                         mlp.init(jax.random.key(0)), seed=2)
    hist = eng.run(train, 12.0)
    assert len(hist) > 0
    c = eng.fault_counters
    assert c["dispatches"] > 0
    assert all(c[k] == 0 for k in FAULT_COUNTERS if k != "dispatches")


def test_fault_counters_reset_per_run(data):
    """Counters tally the LAST run only. Rerunning the same engine yields
    the identical counter dict (all-drop tallies are schedule-independent:
    K dispatches, K drops), never an accumulated one; and a run whose
    schedule build raises mid-way resets to zeros instead of leaving the
    previous run's tallies dangling (the old reporting bug)."""
    train, _ = data
    prob = _prob()
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync", drop_rate=1.0),
                         prob, mlp.loss, mlp.init(jax.random.key(0)), seed=2)
    eng.run(train, 30.0)
    first = dict(eng.fault_counters)
    assert first["dispatches"] == first["drops"] == prob.num_learners
    eng.run(train, 30.0)
    assert eng.fault_counters == first          # identical, not doubled
    eng.run_events(train, 30.0)
    assert eng.fault_counters == first          # same seam on the fast path
    # a schedule build that raises (shard draw larger than the dataset)
    # leaves zeroed counters, not the completed run's
    tiny, _ = synthetic_mnist(4, n_test=4, seed=0)
    with pytest.raises(ValueError):
        eng.run(tiny, 30.0)
    assert set(eng.fault_counters) == set(FAULT_COUNTERS)
    assert all(v == 0 for v in eng.fault_counters.values())


def test_quorum_timer_flushes_partial_buffers(data):
    """With churned uploads a full M-buffer never forms; the quorum timer
    flushes partial groups (extending once below quorum) so the server
    keeps aggregating."""
    train, _ = data
    prob = _prob()
    eng = AsyncFedEngine(
        AsyncConfig(mode="buffered", buffer_size=3, drop_rate=0.4,
                    quorum=2, flush_timeout=5.0),
        prob, mlp.loss, mlp.init(jax.random.key(0)), seed=3,
    )
    hist = eng.run(train, 60.0)
    c = eng.fault_counters
    assert c["drops"] > 0
    timer_closes = (c["quorum_flushes"] + c["quorum_degradations"])
    assert timer_closes > 0                # progress despite lost uploads
    assert len(hist) >= timer_closes
    versions = [r["server_version"] for r in hist]
    assert versions == sorted(versions)    # flushes bump monotonically


def test_availability_gates_dispatch(data):
    """An offline learner is never dispatched: every aggregated upload
    comes from a learner that was online in its dispatch block, and
    deferrals are counted."""
    train, _ = data
    prob = _prob()
    trace = np.array([[True, True, False],
                      [True, False, False],
                      [True, True, True]])
    drift = TraceAvailability(trace)
    eng = AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss,
                         mlp.init(jax.random.key(0)), seed=2, drift=drift)
    part = FederatedPartitioner(train, seed=int(eng.rng.integers(2**31)))
    sched = eng._build_schedule(part, 30.0, 100_000)
    assert len(sched.arrivals) > 0
    T = prob.T
    for a in sched.arrivals:
        block = int(a.dispatch_t // T)
        assert trace[block % 3, a.learner]      # dispatched while online
    assert sched.counters["offline_deferrals"] > 0


# ---------------------------------------------------------------------------
# jagged replay under faults
# ---------------------------------------------------------------------------

def test_event_segments_invariants_under_faults(data):
    """Dropped arrivals never enter the schedule; cancelled-then-late
    arrivals are discarded; the surviving flush-ordered sequence still
    satisfies every jagged-segment invariant."""
    train, _ = data
    prob = _prob()
    for cfg in (_cocktail(), _cocktail(mode="fedasync", buffer_size=0,
                                       quorum=0, flush_timeout=0.0)):
        eng = AsyncFedEngine(cfg, prob, mlp.loss,
                             mlp.init(jax.random.key(0)), seed=4)
        part = FederatedPartitioner(train, seed=0)
        sched = eng._build_schedule(part, 36.0, 100_000)
        c = sched.counters
        assert c["drops"] > 0 or c["deadline_misses"] > 0
        segs = _event_segments(sched.arrivals)
        flushed = [a for a in sched.arrivals if a.flush_id >= 0]
        assert sum(len(s) for s in segs) == len(flushed)
        for evs in segs:
            learners = [a.learner for a in evs]
            assert len(set(learners)) == len(learners)   # one slot each
            flush_pos = [i for i, a in enumerate(evs) if a.flush]
            assert len(flush_pos) <= 1
            if flush_pos:
                assert flush_pos[0] == len(evs) - 1      # flush is last
            if cfg.mode == "fedasync":
                assert len(evs) == 1 and evs[0].flush
        # rebuilding from a same-seed engine replays the fault stream
        eng2 = AsyncFedEngine(cfg, prob, mlp.loss,
                              mlp.init(jax.random.key(0)), seed=4)
        part2 = FederatedPartitioner(train, seed=0)
        sched2 = eng2._build_schedule(part2, 36.0, 100_000)
        assert sched2.counters == c
        assert [(a.learner, a.t, a.flush, a.flush_id)
                for a in sched2.arrivals] == \
               [(a.learner, a.t, a.flush, a.flush_id)
                for a in sched.arrivals]


def test_cocktail_eager_jagged_equivalence(data):
    """The full fault cocktail (drops + delays + stragglers + deadlines +
    quorum timers): the jagged scan replays the eager loop bitwise."""
    train, _ = data
    e1, h1, e2, h2 = _run_both(_cocktail(), _prob(), train, 36.0, seed=2)
    assert len(h1) > 0
    _assert_history_match(h1, h2)
    _assert_params_close(e1, e2)
    assert e1.fault_counters == e2.fault_counters
    assert e1.fault_counters["dispatches"] > 0


def test_availability_realloc_eager_jagged_equivalence(data):
    """Churn + adaptive per-block re-solves: both executors consume the
    same masked-solve schedule."""
    train, _ = data
    drift = MarkovAvailability(p_drop=0.4, p_join=0.5, seed=0)
    cfg = AsyncConfig(mode="buffered", buffer_size=2, reallocate=True)
    e1, h1, e2, h2 = _run_both(cfg, _prob(), train, 36.0, seed=2,
                               drift=drift)
    assert len(h1) > 0
    _assert_history_match(h1, h2)
    _assert_params_close(e1, e2)
    assert e1.fault_counters == e2.fault_counters


@settings(max_examples=4, deadline=None)
@given(drop=st.floats(0.0, 0.5),
       mode=st.sampled_from(["fedasync", "buffered"]),
       fn=st.sampled_from(["constant", "hinge", "poly"]),
       seed=st.integers(0, 2**16))
def test_faulty_replay_property(drop, mode, fn, seed):
    """Property: across drop rates, server modes, staleness discounts and
    engine seeds (which drive the fault rng), the jagged scan's replay of
    the faulty schedule stays exact and the two executors agree on every
    fault counter."""
    train, _ = synthetic_mnist(1200, n_test=50, seed=1)
    kw = dict(drop_rate=drop, straggler_rate=0.3, straggler_factor=2.5,
              delay_rate=0.3, delay_mean=1.5, deadline=14.0,
              retry_backoff=1.0, staleness_fn=fn)
    cfg = (AsyncConfig(mode="buffered", buffer_size=2, **kw)
           if mode == "buffered" else AsyncConfig(mode="fedasync", **kw))
    e1, h1, e2, h2 = _run_both(cfg, _prob(), train, 24.0, seed=seed)
    _assert_history_match(h1, h2)
    _assert_params_close(e1, e2)
    assert e1.fault_counters == e2.fault_counters


# ---------------------------------------------------------------------------
# summaries + simulation surface
# ---------------------------------------------------------------------------

def test_summary_carries_faults_and_quantiles(data):
    train, _ = data
    eng = AsyncFedEngine(_cocktail(), _prob(), mlp.loss,
                         mlp.init(jax.random.key(0)), seed=2)
    hist = eng.run(train, 36.0)
    s = summarize_async_history(hist, counters=eng.fault_counters)
    assert s["faults"] == eng.fault_counters
    assert {"p50", "p90", "p99"} <= s["staleness"].keys()
    # counters default to all-zero when none are supplied
    s0 = summarize_async_history(hist)
    assert set(s0["faults"]) == set(FAULT_COUNTERS)
    assert all(v == 0 for v in s0["faults"].values())


def test_run_async_experiment_forwards_faults(data):
    train, test = data
    out = run_async_experiment(
        mode="buffered", cycles=4, problem=_prob(), train=train, test=test,
        bucketed=True, faults=dict(drop_rate=0.3, deadline=14.0,
                                   retry_backoff=1.0),
        drift=MarkovAvailability(p_drop=0.3, p_join=0.5, seed=0),
    )
    f = out["summary"]["faults"]
    assert f["dispatches"] > 0
    assert f["drops"] + f["retries"] + f["offline_deferrals"] > 0
    with pytest.raises(ValueError, match="fault-free paper regime"):
        run_async_experiment(mode="cycle", cycles=2, problem=_prob(),
                             train=train, test=test,
                             faults=dict(drop_rate=0.3))
