"""Consolidated Pallas-vs-ref parity over EVERY ``repro.kernels.ops`` entry
point, in one harness.

Three layers:

* a property sweep — hypothesis-drawn seeds/variants (``tests._prop``)
  mapped through deterministic builders, each op's ``use_pallas=True,
  interpret=True`` dispatch checked against its ``ref.py`` oracle to the
  shared dtype tolerance;
* one degenerate-case table (K=1, all-masked data, zero local steps,
  infinite energy budget) where the contracts tighten to bitwise;
* the ``ops.train_agg_step`` megakernel contract: interpret-mode output
  matches the unfused ``local_train_stacked`` + accumulate + ``fed_agg``
  composition BITWISE on f32 fixtures across seeds x (K, tau, mask), in
  both the cycle and the async (server/acc/keep/flush) forms — and the
  same equivalence threaded through the three scan bodies
  (``Orchestrator.run_fused``, ``AsyncFedEngine.run_events``,
  ``FleetEngine.run``).

Per-kernel block-size sweeps stay in ``tests/test_kernels.py``; this file
owns the cross-cutting dispatch contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import mlp

from tests._prop import given, settings, st  # hypothesis, or fixed-seed fallback

_DTYPES = [jnp.float32, jnp.bfloat16]
_LAYERS = [6, 5, 3]  # tiny MLP family the megakernel fixtures train


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


def _allclose(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def _trees_bitwise(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# deterministic per-op builders (rng carries all the entropy)
# ---------------------------------------------------------------------------

def _check_flash_attention(rng, variant):
    dtype = _DTYPES[variant % 2]
    b = int(rng.integers(1, 3))
    s = int(rng.choice([16, 32, 64]))
    h = int(rng.choice([2, 4]))
    kv = int(rng.choice([1, h]))
    d = int(rng.choice([8, 16]))
    causal = bool(rng.integers(2))
    window = None if rng.integers(2) else max(4, s // 4)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)) * 0.5, dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              use_pallas=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    _allclose(got, want, dtype)


def _check_wkv6(rng, variant):
    dtype = _DTYPES[variant % 2]
    b = int(rng.integers(1, 3))
    s = int(rng.choice([16, 32]))
    h = int(rng.choice([1, 2]))
    hd = int(rng.choice([8, 16]))
    mk = lambda scale: jnp.asarray(rng.standard_normal((b, s, h, hd)) * scale, dtype)
    r, k, v = mk(0.5), mk(0.5), mk(0.5)
    w = jnp.asarray(rng.uniform(0.5, 0.95, (b, s, h, hd)), dtype)
    u = jnp.asarray(rng.standard_normal((h, hd)) * 0.1, jnp.float32)
    s0 = (jnp.asarray(rng.standard_normal((b, h, hd, hd)) * 0.1, jnp.float32)
          if variant % 3 else None)
    y, s_last = ops.wkv6(r, k, v, w, u, s0=s0, use_pallas=True, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, w, u, s0=s0)
    _allclose(y, yr, dtype)
    _allclose(s_last, sr, dtype)


def _check_fed_agg(rng, variant):
    dtype = _DTYPES[variant % 2]
    k = int(rng.integers(1, 7))
    shape = [(257,), (33, 7), (16, 3, 5)][variant % 3]
    x = jnp.asarray(rng.standard_normal((k, *shape)) * 2.0, dtype)
    w = jnp.asarray(rng.uniform(0.0, 1.0, (k,)), jnp.float32)
    w = w / w.sum()
    got = ops.fed_agg(x, w, use_pallas=True, interpret=True)
    want = ref.fed_agg_ref(x, w)
    _allclose(got, want, dtype)
    assert got.shape == shape and got.dtype == x.dtype


def _check_swiglu_fused(rng, variant):
    dtype = _DTYPES[variant % 2]
    m = int(rng.choice([16, 32]))
    d = int(rng.choice([8, 16]))
    f = int(rng.choice([32, 64]))
    x = jnp.asarray(rng.standard_normal((m, d)) * 0.5, dtype)
    wg = jnp.asarray(rng.standard_normal((d, f)) * 0.05, dtype)
    wu = jnp.asarray(rng.standard_normal((d, f)) * 0.05, dtype)
    wd = jnp.asarray(rng.standard_normal((f, d)) * 0.05, dtype)
    got = ops.swiglu_fused(x, wg, wu, wd, use_pallas=True, interpret=True)
    want = ref.swiglu_ref(x, wg, wu, wd)
    _allclose(got, want, dtype)


def _check_mamba_scan(rng, variant):
    dtype = _DTYPES[variant % 2]
    bsz = int(rng.integers(1, 3))
    s = int(rng.choice([16, 32]))
    d = int(rng.choice([8, 16]))
    n = int(rng.choice([4, 8]))
    sp = lambda z: np.log1p(np.exp(z))  # softplus, stays in numpy
    dt = jnp.asarray(sp(rng.standard_normal((bsz, s, d)) * 0.5), dtype)
    x = jnp.asarray(rng.standard_normal((bsz, s, d)) * 0.5, dtype)
    b = jnp.asarray(rng.standard_normal((bsz, s, n)) * 0.5, dtype)
    c = jnp.asarray(rng.standard_normal((bsz, s, n)) * 0.5, dtype)
    a = -jnp.exp(jnp.asarray(rng.standard_normal((d, n)) * 0.3, jnp.float32))
    h0 = (jnp.asarray(rng.standard_normal((bsz, d, n)) * 0.1, jnp.float32)
          if variant % 3 else None)
    yp, hp = ops.mamba_scan(dt, x, b, c, a, h0=h0, use_pallas=True, interpret=True)
    yr, hr = ref.mamba_scan_ref(dt, x, b, c, a, h0=h0)
    _allclose(yp, yr, dtype)
    _allclose(hp, hr, dtype)


def _time_rows(rng, b, k, variant):
    """Shared waterfill fixture: f32 time coefficients + a tau* that lands
    in the interior / lo-saturated / hi-slack regimes by variant."""
    c2 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c1 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    c0 = jnp.asarray(rng.uniform(0.1, 2.0, (b, k)), jnp.float32)
    tau_v, scale_T = [(50.0, 1.0), (1e6, 1.0), (0.0, 1e4)][variant % 3]
    T = jnp.asarray(rng.uniform(5.0, 20.0, (b,)) * scale_T, jnp.float32)
    lo = jnp.full((b, k), 10.0, jnp.float32)
    hi = jnp.full((b, k), 900.0, jnp.float32)
    tot = jnp.asarray(rng.uniform(1e3, 5e3, (b,)), jnp.float32)
    return jnp.full((b,), tau_v, jnp.float32), c2, c1, c0, T, lo, hi, tot


def _check_waterfill_residual(rng, variant):
    b = int(rng.integers(1, 6))
    k = int(rng.integers(1, 14))
    args = _time_rows(rng, b, k, variant)
    got = ops.waterfill_residual(*args, use_pallas=True, interpret=True)
    want = ref.waterfill_residual_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)


def _check_waterfill_energy_residual(rng, variant):
    b = int(rng.integers(1, 6))
    k = int(rng.integers(1, 14))
    tau_v, c2, c1, c0, T, lo, hi, tot = _time_rows(rng, b, k, variant)
    e2 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    e1 = jnp.asarray(rng.uniform(1e-4, 1e-2, (b, k)), jnp.float32)
    e0 = jnp.asarray(rng.uniform(0.05, 1.0, (b, k)), jnp.float32)
    eb = jnp.asarray(
        np.full((b, k), np.inf) if variant % 4 == 0
        else rng.uniform(2.0, 12.0, (b, k)),
        jnp.float32,
    )
    args = (tau_v, c2, c1, c0, T, e2, e1, e0, eb, lo, hi, tot)
    got = ops.waterfill_energy_residual(*args, use_pallas=True, interpret=True)
    want = ref.waterfill_energy_residual_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)


def _train_fixture(rng, k, n, *, mask_kind):
    """f32 megakernel operands: per-learner start params, padded data with
    mask, per-learner tau/weights — the exact ``_bucketed_events`` shapes."""
    feat, classes = _LAYERS[0], _LAYERS[-1]
    stack = [mlp.init(jax.random.key(int(s)), _LAYERS)
             for s in rng.integers(2**31, size=k)]
    disp = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *stack)
    x = jnp.asarray(rng.standard_normal((k, n, feat)), jnp.float32)
    y = jnp.asarray(rng.integers(0, classes, (k, n)), jnp.int32)
    if mask_kind == "random":
        m = jnp.asarray(rng.integers(0, 2, (k, n)), jnp.float32)
    elif mask_kind == "full":
        m = jnp.ones((k, n), jnp.float32)
    else:  # one learner fully masked out
        m = jnp.ones((k, n), jnp.float32)
        m = m.at[int(rng.integers(k))].set(0.0)
    tau = jnp.asarray(rng.integers(0, 4, (k,)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, (k,)), jnp.float32)
    return disp, x, y, m, tau, w


def _check_train_agg_step(rng, variant):
    k = int(rng.integers(1, 5))
    n = int(rng.integers(3, 9))
    mask_kind = ["random", "full", "one_out"][variant % 3]
    disp, x, y, m, tau, w = _train_fixture(rng, k, n, mask_kind=mask_kind)
    lr = jnp.float32(0.05)
    max_tau = max(1, int(tau.max()))

    # cycle form: BITWISE against the unfused composition on f32
    want, _ = ops.train_agg_step(disp, x, y, m, tau, w, lr,
                                 loss_fn=mlp.loss, max_tau=max_tau)
    got, _ = ops.train_agg_step(disp, x, y, m, tau, w, lr, loss_fn=mlp.loss,
                                use_pallas=True, interpret=True)
    _trees_bitwise(got, want)

    # async form: server/acc carry + keep/flush contraction, still bitwise
    server = mlp.init(jax.random.key(int(rng.integers(2**31))), _LAYERS)
    acc = jax.tree_util.tree_map(
        lambda l: jnp.asarray(rng.standard_normal(l.shape) * 0.1, jnp.float32),
        server)
    keep = jnp.float32(rng.uniform(0.0, 1.0))
    flush = jnp.float32(rng.uniform(0.0, 1.0))
    s_ref, a_ref = ops.train_agg_step(
        disp, x, y, m, tau, w, lr, loss_fn=mlp.loss, max_tau=max_tau,
        server=server, acc=acc, keep=keep, flush=flush)
    s_pal, a_pal = ops.train_agg_step(
        disp, x, y, m, tau, w, lr, loss_fn=mlp.loss,
        server=server, acc=acc, keep=keep, flush=flush,
        use_pallas=True, interpret=True)
    _trees_bitwise(s_pal, s_ref)
    _trees_bitwise(a_pal, a_ref)


CHECKS = {
    "flash_attention": _check_flash_attention,
    "wkv6": _check_wkv6,
    "fed_agg": _check_fed_agg,
    "swiglu_fused": _check_swiglu_fused,
    "mamba_scan": _check_mamba_scan,
    "waterfill_residual": _check_waterfill_residual,
    "waterfill_energy_residual": _check_waterfill_energy_residual,
    "train_agg_step": _check_train_agg_step,
}

assert sorted(CHECKS) == sorted(ops.__all__), "every ops entry point is covered"


@pytest.mark.parametrize("op", sorted(CHECKS))
def test_ops_parity_property(op):
    """Hypothesis-drawn shapes/dtypes/seeds: ops(use_pallas=True,
    interpret=True) vs the ref oracle, per-op tolerance (bitwise for
    train_agg_step)."""
    check = CHECKS[op]

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16 - 1), variant=st.integers(0, 23))
    def prop(seed, variant):
        check(np.random.default_rng(seed * 31 + 7), variant)

    prop()


# ---------------------------------------------------------------------------
# degenerate-case table: the contracts tighten to bitwise
# ---------------------------------------------------------------------------

def _degen_fed_agg_k1():
    """K=1 with unit weight is the identity, bit for bit."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 129)), jnp.float32)
    w = jnp.ones((1,), jnp.float32)
    got = ops.fed_agg(x, w, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(ref.fed_agg_ref(x, w)),
                                  np.asarray(x[0]))


def _degen_train_k1():
    rng = np.random.default_rng(1)
    _check_train_agg_step(rng, variant=1)  # draws K from rng; force K=1 below
    rng = np.random.default_rng(2)
    disp, x, y, m, tau, w = _train_fixture(rng, 1, 5, mask_kind="full")
    got, _ = ops.train_agg_step(disp, x, y, m, tau, w, jnp.float32(0.05),
                                loss_fn=mlp.loss, use_pallas=True,
                                interpret=True)
    want, _ = ops.train_agg_step(disp, x, y, m, tau, w, jnp.float32(0.05),
                                 loss_fn=mlp.loss,
                                 max_tau=max(1, int(tau.max())))
    _trees_bitwise(got, want)


def _degen_train_all_masked():
    """All-masked data: the loss contraction zeroes every gradient, so the
    fused step reduces to fed_agg over the UNTRAINED dispatch params."""
    rng = np.random.default_rng(3)
    disp, x, y, _, _, w = _train_fixture(rng, 3, 5, mask_kind="full")
    m = jnp.zeros_like(x[..., 0])
    tau = jnp.asarray([3, 1, 2], jnp.int32)
    got, _ = ops.train_agg_step(disp, x, y, m, tau, w, jnp.float32(0.05),
                                loss_fn=mlp.loss, use_pallas=True,
                                interpret=True)
    want = jax.tree_util.tree_map(lambda l: ref.fed_agg_ref(l, w), disp)
    _trees_bitwise(got, want)


def _degen_train_zero_tau():
    """tau == 0 everywhere: no GD step runs; the kernel's traced
    ``max(tau)`` loop bound hits zero and the output is the plain
    aggregate of the start params."""
    rng = np.random.default_rng(4)
    disp, x, y, m, _, w = _train_fixture(rng, 3, 5, mask_kind="random")
    tau = jnp.zeros((3,), jnp.int32)
    got, _ = ops.train_agg_step(disp, x, y, m, tau, w, jnp.float32(0.05),
                                loss_fn=mlp.loss, use_pallas=True,
                                interpret=True)
    want = jax.tree_util.tree_map(lambda l: ref.fed_agg_ref(l, w), disp)
    _trees_bitwise(got, want)


def _degen_energy_inf_budget():
    """eb = +inf rows reproduce the time-only residual bitwise on BOTH
    backends (the documented ops contract)."""
    rng = np.random.default_rng(5)
    tau_v, c2, c1, c0, T, lo, hi, tot = _time_rows(rng, 3, 7, 0)
    e2 = jnp.asarray(rng.uniform(1e-4, 1e-2, (3, 7)), jnp.float32)
    e1 = jnp.asarray(rng.uniform(1e-4, 1e-2, (3, 7)), jnp.float32)
    e0 = jnp.asarray(rng.uniform(0.05, 1.0, (3, 7)), jnp.float32)
    eb = jnp.full((3, 7), jnp.inf, jnp.float32)
    for backend in (dict(use_pallas=True, interpret=True), dict()):
        with_e = ops.waterfill_energy_residual(
            tau_v, c2, c1, c0, T, e2, e1, e0, eb, lo, hi, tot, **backend)
        time_only = ops.waterfill_residual(
            tau_v, c2, c1, c0, T, lo, hi, tot, **backend)
        np.testing.assert_array_equal(np.asarray(with_e),
                                      np.asarray(time_only))


DEGENERATE = {
    "fed_agg_k1": _degen_fed_agg_k1,
    "train_k1": _degen_train_k1,
    "train_all_masked": _degen_train_all_masked,
    "train_zero_tau": _degen_train_zero_tau,
    "energy_inf_budget": _degen_energy_inf_budget,
}


@pytest.mark.parametrize("case", sorted(DEGENERATE))
def test_degenerate_cases(case):
    DEGENERATE[case]()


# ---------------------------------------------------------------------------
# engine threading: the three scan bodies accept use_pallas and agree
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_data():
    from repro.data.pipeline import synthetic_mnist

    return synthetic_mnist(1500, n_test=300, seed=0)


@pytest.mark.parametrize("reallocate", [False, True])
def test_run_fused_pallas_matches_unfused(small_data, reallocate):
    """Orchestrator.run_fused: the megakernel cycle body is bitwise equal
    to the unfused scan body (fresh params per run — the fused cycles
    donate their carry)."""
    from repro.fed.orchestrator import MELConfig, Orchestrator
    from repro.fed.simulation import build_problem

    train, _ = small_data
    prob = build_problem(3, 10.0, total_samples=600, seed=3)

    runs = []
    for use_pallas in (False, True):
        orch = Orchestrator(MELConfig(T=10.0, total_samples=600), prob,
                            mlp.loss, mlp.init(jax.random.key(3)), seed=3)
        hist = orch.run(train, 3, fused=True, reallocate=reallocate,
                        use_pallas=use_pallas, interpret=use_pallas)
        runs.append((hist, orch.params))

    (h0, p0), (h1, p1) = runs
    assert len(h0) == len(h1) == 3
    for r0, r1 in zip(h0, h1):
        np.testing.assert_array_equal(r0["tau"], r1["tau"])
        np.testing.assert_array_equal(r0["d"], r1["d"])
    _trees_bitwise(p0, p1)


def test_run_events_pallas_matches_unfused(small_data):
    """AsyncFedEngine.run_events: every jagged-segment scan step through
    the megakernel reproduces the unfused history and params bitwise."""
    from repro.fed.async_engine import AsyncConfig, AsyncFedEngine
    from repro.fed.simulation import build_problem

    train, _ = small_data
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)

    runs = []
    for use_pallas in (False, True):
        eng = AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss,
                             mlp.init(jax.random.key(2)), seed=2)
        hist = eng.run_events(train, 40.0, use_pallas=use_pallas,
                              interpret=use_pallas)
        runs.append((hist, eng.params))

    (h0, p0), (h1, p1) = runs
    assert len(h0) == len(h1) >= 3
    for r0, r1 in zip(h0, h1):
        assert r0["server_version"] == r1["server_version"]
        assert r0["staleness_list"] == r1["staleness_list"]
        np.testing.assert_array_equal(r0["weights"], r1["weights"])
    _trees_bitwise(p0, p1)


def test_fleet_rounds_pallas_matches_unfused(small_data):
    """FleetEngine: the vmapped per-fleet round through the megakernel is
    bitwise equal to the unfused local_train + weighted-sum body."""
    from repro.fed.fleet import FleetConfig, FleetEngine, build_fleet_problems
    from repro.launch.mesh import make_mesh_by_name

    train, _ = small_data
    probs = build_fleet_problems(2, 3, T=2.0, total_samples=30, seed=2)

    runs = []
    for use_pallas in (False, True):
        eng = FleetEngine(FleetConfig(), probs, mlp.loss,
                          mlp.init(jax.random.key(3)), seed=3,
                          mesh=make_mesh_by_name("cpu"))
        hist = eng.run(train, 2, use_pallas=use_pallas, interpret=use_pallas)
        runs.append((hist, eng.global_params, eng.fleet_params))

    (h0, g0, f0), (h1, g1, f1) = runs
    for r0, r1 in zip(h0, h1):
        np.testing.assert_array_equal(r0["tau"], r1["tau"])
        np.testing.assert_array_equal(r0["d"], r1["d"])
    _trees_bitwise(g0, g1)
    _trees_bitwise(f0, f1)
