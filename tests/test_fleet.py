"""Fleet-of-fleets engine (``fed/fleet.py``) pins.

  * the F = 1 exactness contract: full participation on the 1-device mesh
    reproduces ``Orchestrator.run`` record for record and parameter for
    parameter (bitwise);
  * sampling-mask semantics under the fleet axis: a sampled-out fleet IS
    an all-offline fleet IS a row of padded slots, the clipped budget
    never leaves the live box, and the policies solve masked rows to
    zeros without going infeasible (property-tested);
  * engine behavior: fleet padding, partial-participation staleness
    bookkeeping, config validation;
  * keyed partitioner draws: draw i depends only on (seed, i, total) —
    pinned to concrete indices, so any iteration-order or global-PRNG
    dependence shows up as a cross-process diff.

The multi-device shard_map path needs >= 8 devices and lives in
``tests/test_fleet_sharded.py`` (the fleet-scale CI step runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import BatchedProblems, apply_active_mask, apply_sampling_mask
from repro.core.solver_batched import batched_policy
from repro.data.pipeline import FederatedPartitioner, synthetic_mnist
from repro.fed.fleet import FleetConfig, FleetEngine, build_fleet_problems
from repro.fed.orchestrator import MELConfig, Orchestrator
from repro.fed.simulation import build_spread_problem
from repro.launch.mesh import make_mesh_by_name
from repro.models import mlp

from tests._prop import given, settings, st, make_batched_problems


@pytest.fixture(scope="module")
def data():
    return synthetic_mnist(1200, n_test=200, seed=0)


def _cpu_mesh():
    return make_mesh_by_name("cpu")


# ---------------------------------------------------------------------------
# the F = 1 exactness contract (ISSUE acceptance)
# ---------------------------------------------------------------------------

def test_f1_full_participation_reproduces_orchestrator_bitwise(data):
    """One fleet, full participation, 1-device mesh: the two-tier engine
    degenerates to the single-fleet paper scheme — same initial solve,
    same shard draws, same training, same aggregation — so every history
    field and every final parameter matches ``Orchestrator.run`` exactly."""
    train, test = data
    prob = build_spread_problem(3, 6.0, total_samples=60)
    params = mlp.init(jax.random.key(1))
    ex, ey = jnp.asarray(test.x), jnp.asarray(test.y)

    orch = Orchestrator(MELConfig(T=6.0, total_samples=60), prob,
                        mlp.loss, params, seed=3)
    hist_o = orch.run(train, 4, eval_fn=lambda p: mlp.accuracy(p, ex, ey))

    eng = FleetEngine(
        FleetConfig(), BatchedProblems.from_problems([prob]),
        mlp.loss, params, seed=3, mesh=_cpu_mesh(),
    )
    hist_f = eng.run(train, 4, eval_fn=mlp.accuracy,
                     eval_batch=(test.x, test.y))

    assert len(hist_o) == len(hist_f) == 4
    for ro, rf in zip(hist_o, hist_f):
        assert rf["fleets"] == rf["sampled_fleets"] == 1
        np.testing.assert_array_equal(rf["tau"][0], ro["tau"])
        np.testing.assert_array_equal(rf["d"][0], ro["d"])
        assert rf["accuracy"] == ro["accuracy"]          # float-exact
        assert float(rf["max_staleness"][0]) == ro["max_staleness"]
        assert float(rf["avg_staleness"][0]) == ro["avg_staleness"]
        assert rf["elapsed_s"] == ro["elapsed_s"]
        assert rf["wall_clock_s"] == ro["wall_clock_s"]
        assert rf["fleet_staleness_max"] == 0            # always fresh
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        eng.global_params, orch.params,
    )


# ---------------------------------------------------------------------------
# sampling-mask semantics (property)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**20), sample_bits=st.integers(0, 2**6 - 1))
def test_sampling_mask_is_offline_is_padded(seed, sample_bits):
    """Row f of ``apply_sampling_mask`` with ``sampled[f]=False`` equals
    ``apply_active_mask`` with every learner offline equals a row of
    ``BatchedProblems`` padded slots; sampled-in rows pass through the
    learner-mask identity; the clipped budget stays in the live box."""
    _, bp = make_batched_problems(seed)
    b, k = bp.c2.shape
    sampled = np.array([(sample_bits >> i) & 1 == 1 for i in range(b)])
    total = np.asarray(bp.total, np.int64)
    lo, hi = np.asarray(bp.d_lo), np.asarray(bp.d_hi)
    valid = np.asarray(bp.valid, bool)

    tot_s, lo_s, hi_s, v_s = (np.asarray(a) for a in apply_sampling_mask(
        total, lo, hi, valid, sampled))

    # budget clipping never leaves the (masked) live box
    assert (tot_s >= lo_s.sum(axis=1)).all()
    assert (tot_s <= hi_s.sum(axis=1)).all()

    for f in range(b):
        if sampled[f]:
            # sampled-in row == the plain active-mask identity on valid
            tot_a, lo_a, hi_a, v_a = (np.asarray(a) for a in
                                      apply_active_mask(
                                          total[f], lo[f], hi[f], valid[f],
                                          valid[f]))
            np.testing.assert_array_equal(lo_s[f], lo_a)
            np.testing.assert_array_equal(hi_s[f], hi_a)
            np.testing.assert_array_equal(v_s[f], v_a)
            assert tot_s[f] == tot_a
        else:
            # sampled-out == all-offline == padded slots
            tot_o, lo_o, hi_o, v_o = (np.asarray(a) for a in
                                      apply_active_mask(
                                          total[f], lo[f], hi[f], valid[f],
                                          np.zeros(k, bool)))
            np.testing.assert_array_equal(lo_s[f], lo_o)
            np.testing.assert_array_equal(hi_s[f], hi_o)
            np.testing.assert_array_equal(v_s[f], v_o)
            assert tot_s[f] == tot_o == 0
            assert (lo_s[f] == 0).all() and (hi_s[f] == 0).all()
            assert not v_s[f].any()


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 2**20))
def test_policy_solves_sampled_out_rows_to_zero(seed):
    """The traced policy on sampling-masked tensors: sampled-out rows are
    feasible with tau = d = 0; sampled rows allocate their full budget
    within bounds."""
    _, bp = make_batched_problems(seed)
    b, _ = bp.c2.shape
    sampled = np.zeros(b, bool)
    sampled[::2] = True
    with enable_x64():
        tot, lo, hi, v = apply_sampling_mask(
            jnp.asarray(bp.total, jnp.int64),
            jnp.asarray(bp.d_lo, jnp.float64),
            jnp.asarray(bp.d_hi, jnp.float64),
            jnp.asarray(bp.valid), jnp.asarray(sampled),
        )
        tau, d, feas = batched_policy("kkt_sai")(
            jnp.asarray(bp.c2, jnp.float64), jnp.asarray(bp.c1, jnp.float64),
            jnp.asarray(bp.c0, jnp.float64), jnp.asarray(bp.T, jnp.float64),
            tot, lo, hi, v,
        )
        tau, d, feas = np.asarray(tau), np.asarray(d), np.asarray(feas)
        tot = np.asarray(tot)
    assert feas.all()
    out = ~sampled
    assert (tau[out] == 0).all() and (d[out] == 0).all()
    np.testing.assert_array_equal(d[sampled].sum(axis=1), tot[sampled])
    assert (d >= np.asarray(lo)).all() and (d <= np.asarray(hi)).all()


# ---------------------------------------------------------------------------
# engine behavior
# ---------------------------------------------------------------------------

def test_fleet_padding_is_padded_slot_semantics():
    bp = build_fleet_problems(3, 4, seed=5)
    padded = FleetEngine._pad_problems(bp, 8)
    assert padded.c2.shape == (8, 4)
    np.testing.assert_array_equal(padded.c2[:3], bp.c2)
    assert not padded.valid[3:].any()
    assert (padded.d_lo[3:] == 0).all() and (padded.d_hi[3:] == 0).all()
    assert (padded.total[3:] == 0).all()


def test_partial_participation_staleness(data):
    """Partial participation: each round samples the configured fleet
    count, unsampled fleets keep their dispatch and accrue version
    staleness, and pull versions advance only on merge."""
    train, _ = data
    eng = FleetEngine(
        FleetConfig(participation=0.5),
        build_fleet_problems(4, 3, T=6.0, total_samples=30, seed=2),
        mlp.loss, mlp.init(jax.random.key(0)), seed=1, mesh=_cpu_mesh(),
    )
    hist = eng.run(train, 4)
    assert [r["sampled_fleets"] for r in hist] == [2, 2, 2, 2]
    assert eng.global_version == 4
    pv = eng.pull_version[eng._real]
    assert pv.max() == 4                     # last round's fleets are fresh
    assert pv.min() < 4                      # someone was left out
    assert max(r["fleet_staleness_max"] for r in hist) >= 1
    # determinism: the sampling stream is keyed by (seed, stream, round)
    eng2 = FleetEngine(
        FleetConfig(participation=0.5),
        build_fleet_problems(4, 3, T=6.0, total_samples=30, seed=2),
        mlp.loss, mlp.init(jax.random.key(0)), seed=1, mesh=_cpu_mesh(),
    )
    for r in range(4):
        np.testing.assert_array_equal(eng2._sample_mask(r),
                                      eng._sample_mask(r))


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="batched_policy"):
        FleetConfig(scheme="slsqp")
    with pytest.raises(ValueError, match="participation"):
        FleetConfig(participation=0.0)
    with pytest.raises(ValueError, match="server_mix"):
        FleetConfig(server_mix=1.5)
    with pytest.raises(ValueError, match="staleness fn"):
        FleetConfig(staleness_fn="nope")


def test_build_fleet_problems_keyed_and_pinned():
    """The population generator is keyed by (seed, F, K) and draws whole
    tensors — identical across processes (pinned values) and across
    repeated builds."""
    bp = build_fleet_problems(3, 4, seed=5)
    bp2 = build_fleet_problems(3, 4, seed=5)
    np.testing.assert_array_equal(bp.c2, bp2.c2)
    np.testing.assert_array_equal(bp.c1, bp2.c1)
    np.testing.assert_allclose(
        bp.c2[0],
        [0.037716867339, 0.030758195571, 0.027533832447, 0.044979222428],
        rtol=0, atol=1e-12,
    )
    assert bp.total.tolist() == [60, 60, 60]
    assert (bp.d_lo == 7.0).all() and (bp.d_hi == 30.0).all()


# ---------------------------------------------------------------------------
# keyed partitioner draws (determinism seam)
# ---------------------------------------------------------------------------

def test_partitioner_draws_keyed_by_seed_and_index(data):
    """``draw_indices`` derives draw i from ``SeedSequence((seed, i))``
    alone: pinned indices hold across processes, and draw i is unchanged
    by the sizes of earlier draws (no iteration-order or global-PRNG
    dependence)."""
    train, _ = data
    p = FederatedPartitioner(train, seed=7)
    np.testing.assert_array_equal(p.draw_indices(5),
                                  [748, 819, 1130, 693, 1075])
    second = p.draw_indices(8)
    np.testing.assert_array_equal(
        second, [919, 1038, 191, 226, 1046, 262, 133, 309])
    # same draw index + total, DIFFERENT first-draw size: identical result
    q = FederatedPartitioner(train, seed=7)
    q.draw_indices(200)
    np.testing.assert_array_equal(q.draw_indices(8), second)
    # distinct seeds give distinct streams
    r = FederatedPartitioner(train, seed=8)
    assert not np.array_equal(r.draw_indices(5), [748, 819, 1130, 693, 1075])
