"""Per-architecture smoke tests: reduced variant of each assigned family
runs one forward/train step on CPU; shapes + finiteness asserted.
Decode parity (prefill-then-decode == teacher-forced forward) is asserted
for every family (MoE archs with a generous capacity factor so GShard
token-dropping does not enter the comparison).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.models.model import Model

KEY = jax.random.key(0)


def make_batch(cfg, b=2, s=24, with_labels=True, key=KEY):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(jax.random.key(9), (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeds"] = (
            jax.random.normal(key, (b, cfg.num_image_tokens, cfg.d_model), jnp.float32) * 0.1
        )
    if cfg.family == "audio":
        batch["encoder_embeds"] = (
            jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_limits(name):
    cfg = get_reduced(name)
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 4
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    cfg = get_config(name)
    assert cfg.name == name
    assert cfg.source
    total, active = cfg.param_counts()
    assert total >= active > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = get_reduced(name)
    model = Model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, "no gradients"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
    # one SGD step changes the loss
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = model.loss(params2, batch)
    assert np.isfinite(float(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_parity(name):
    cfg = get_reduced(name)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # disable token drop
    model = Model(cfg)
    params = model.init(KEY)
    b, s = 2, 12
    extra = cfg.num_image_tokens if cfg.family == "vlm" else 0
    batch = make_batch(cfg, b=b, s=s, with_labels=False)
    max_len = s + extra + 4
    logits, cache, _aux = model.prefill(params, batch, max_len=max_len)
    assert logits.shape == (b, 1, cfg.vocab_size)

    nxt = jax.random.randint(jax.random.key(7), (b, 1), 0, cfg.vocab_size)
    dec, _cache2 = model.decode(params, cache, nxt, jnp.asarray(s + extra, jnp.int32))
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    want, _, _ = model.prefill(params, batch2, max_len=max_len)
    np.testing.assert_allclose(
        np.asarray(dec[:, -1], np.float32), np.asarray(want[:, -1], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_sliding_window_long_decode_cache_is_bounded():
    """h2o-danube long-context mechanism: the KV cache is O(window), not O(S)."""
    cfg = get_reduced("h2o-danube-1.8b")
    model = Model(cfg)
    cache = model.abstract_cache(1, 500_000)
    k_leaf = jax.tree_util.tree_leaves(cache)[0]   # (layers, batch, cache_seq, kv, hd)
    assert k_leaf.shape[2] == cfg.sliding_window


def test_ssm_decode_cache_constant_in_context():
    for name in ("rwkv6-7b", "jamba-v0.1-52b"):
        cfg = get_reduced(name)
        model = Model(cfg)
        small = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(model.abstract_cache(1, 1_000)) )
        big_leaves = jax.tree_util.tree_leaves(model.abstract_cache(1, 500_000))
        big = sum(np.prod(l.shape) for l in big_leaves)
        if name == "rwkv6-7b":
            assert big == small                      # attention-free: exactly O(1)
        else:
            assert big < small * 600                 # only the sparse attn layers scale


def test_moe_router_load_balance_aux_positive():
    from repro.models import ffn as ffn_mod

    cfg = get_reduced("deepseek-moe-16b")
    model = Model(cfg)
    params = model.init(KEY)
    moe_params = params["blocks"][0]["ffn"]
    p0 = jax.tree_util.tree_map(lambda x: x[0], moe_params)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    out, aux = ffn_mod.moe_apply(cfg, p0, x)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, == 1 when balanced


def test_period_layout_jamba():
    from repro.models.decoder import layout_for

    lay = layout_for(get_config("jamba-v0.1-52b"))
    assert lay.p == 8 and lay.n_periods == 4
    kinds = [k for (k, _) in lay.period]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    moes = [m for (_, m) in lay.period]
    assert sum(moes) == 4  # every other layer


def test_whisper_cross_attention_shapes():
    cfg = get_reduced("whisper-small")
    model = Model(cfg)
    cache = model.abstract_cache(2, 32)
    assert cache["cross"]["k"].shape == (cfg.num_layers, 2, cfg.encoder_seq, cfg.num_kv_heads, cfg.resolved_head_dim)
