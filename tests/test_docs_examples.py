"""Docs-consistency gate: every ```python code block in README.md and
docs/*.md is executed, so documented examples cannot rot.

Blocks within one file share a namespace (later snippets may build on
earlier ones, as in a REPL walkthrough). A fence info-string containing
``no-run`` opts a block out (none do today); non-python fences (bash,
text) are ignored."""

from __future__ import annotations

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

_FENCE = re.compile(
    r"^```(?P<info>[^\n]*)\n(?P<body>.*?)^```\s*$",
    re.MULTILINE | re.DOTALL,
)


def python_blocks(path: pathlib.Path) -> "list[tuple[int, str]]":
    """(start_line, source) for every runnable python fence in ``path``."""
    text = path.read_text()
    out = []
    for m in _FENCE.finditer(text):
        info = m.group("info").strip().lower()
        if not info.startswith("python") or "no-run" in info:
            continue
        lineno = text[: m.start()].count("\n") + 2  # first body line
        out.append((lineno, m.group("body")))
    return out


def test_docs_exist():
    """The docs suite this gate guards must actually be present."""
    names = {p.name for p in (ROOT / "docs").glob("*.md")}
    assert {"architecture.md", "allocation.md", "async_engine.md",
            "robustness.md", "fleet_scale.md", "energy.md",
            "multi_model.md", "kernels.md"} <= names


@pytest.mark.parametrize(
    "path", DOC_FILES, ids=[p.relative_to(ROOT).as_posix() for p in DOC_FILES]
)
def test_doc_snippets_run(path):
    blocks = python_blocks(path)
    ns: dict = {"__name__": f"docsnippet_{path.stem}"}
    for lineno, src in blocks:
        code = compile(src, f"{path.relative_to(ROOT)}:{lineno}", "exec")
        exec(code, ns)  # noqa: S102 - that is the point of the gate
    if path.name != "README.md":
        assert blocks, f"{path.name} has no runnable python examples"
