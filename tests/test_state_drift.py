"""State-coupled capacity drift (core.time_model.QueueDrift).

Pins the PR's acceptance contract for state-dependent dynamics:
  * a queue-coupled drift scenario runs end-to-end INSIDE the fused scan
    (no host coefficient path) and reproduces the eager host rollout's
    tau/d history exactly;
  * same seed/config => bitwise-identical rollout (the determinism pin
    mirroring tests/test_aggregation_props.py); different coupling =>
    different trajectory;
  * the in-scan feasibility guard raises (naming the cycle) when the
    backlog degrades capacities past feasibility — on both paths;
  * the async engine threads the same coupled rollout through its
    per-block re-solves (barrier regime matches the orchestrator).
"""

import numpy as np
import pytest

import jax

from repro.core import CapacityDrift, QueueDrift, TimeModel, is_state_coupled
from repro.data.pipeline import synthetic_mnist
from repro.fed.async_engine import AsyncConfig, AsyncFedEngine
from repro.fed.orchestrator import MELConfig, Orchestrator
from repro.fed.simulation import build_problem, run_experiment
from repro.models import mlp

from tests._prop import given, settings, st


@pytest.fixture(scope="module")
def data():
    return synthetic_mnist(3000, n_test=600, seed=0)


def _tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _rollout(drift, prob, cycles):
    from repro.fed.orchestrator import solve_rows_state_coupled

    return solve_rows_state_coupled(
        "kkt_sai", drift, prob, cycles, label="cycle {}"
    )


def test_is_state_coupled_protocol():
    assert is_state_coupled(QueueDrift())
    assert not is_state_coupled(CapacityDrift())
    assert not is_state_coupled(None)


def test_queue_drift_state_dynamics():
    """Fair-share load holds the backlog; overload accumulates; underload
    drains; the clip keeps queues in [0, q_max]; the rate factor decays
    with backlog while the clock factor stays 1 without a base drift."""
    qd = QueueDrift(congestion=0.5, gain=1.0, service=1.0, q_max=4.0)
    import jax.numpy as jnp

    q0 = qd.state_init(3)
    np.testing.assert_array_equal(np.asarray(q0), np.zeros(3, np.float32))
    # d = (2, 1, 1) * 300: loads (1.5, 0.75, 0.75) vs fair share 1
    d = jnp.asarray([600, 300, 300])
    tau = jnp.asarray([5, 5, 5])
    q1 = np.asarray(qd.state_update(0, q0, tau, d))
    assert q1[0] == pytest.approx(0.5) and q1[1] == 0.0 and q1[2] == 0.0
    # repeated overload saturates at q_max
    q = q0
    for c in range(20):
        q = qd.state_update(c, q, tau, d)
    assert np.asarray(q)[0] == pytest.approx(4.0)
    clock, rate = qd.factors_at(0, 3, q)
    np.testing.assert_array_equal(np.asarray(clock), np.ones(3, np.float32))
    np.testing.assert_allclose(np.asarray(rate)[0], 1.0 / (1.0 + 0.5 * 4.0))
    assert np.asarray(rate)[1] == 1.0


def test_queue_drift_rollout_determinism():
    """Same config => bitwise-identical (rows, allocations) rollout;
    a different coupling strength changes the trajectory. Mirrors the
    CapacityDrift seed pins in test_aggregation_props."""
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    a_rows, a_alloc = _rollout(QueueDrift(congestion=1.0, gain=2.0), prob, 5)
    b_rows, b_alloc = _rollout(QueueDrift(congestion=1.0, gain=2.0), prob, 5)
    for x, y in zip(a_rows + a_alloc, b_rows + b_alloc):
        np.testing.assert_array_equal(x, y)
    c_rows, c_alloc = _rollout(QueueDrift(congestion=2.0, gain=2.0), prob, 5)
    assert any(
        not np.array_equal(x, y) for x, y in zip(a_rows + a_alloc,
                                                 c_rows + c_alloc)
    )
    # composing an exogenous base drift keeps determinism seed-keyed
    base = CapacityDrift(seed=7)
    d1 = _rollout(QueueDrift(congestion=1.0, base=base), prob, 4)
    d2 = _rollout(QueueDrift(congestion=1.0, base=base), prob, 4)
    for x, y in zip(d1[0] + d1[1], d2[0] + d2[1]):
        np.testing.assert_array_equal(x, y)


def test_queue_drift_feedback_moves_allocation():
    """The closed loop reacts: learners dispatched above fair share build
    backlog, their rates degrade, and the re-solve sheds samples from
    them over cycles (monotone drift of d away from the loaded learners)."""
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    _, (taus, ds) = _rollout(QueueDrift(congestion=1.0, gain=2.0), prob, 5)
    assert not np.all(ds == ds[0])
    loaded = int(np.argmax(ds[0]))
    assert ds[-1, loaded] < ds[0, loaded]
    # sum constraint holds every cycle
    np.testing.assert_array_equal(ds.sum(axis=1), np.full(5, 1200))


def test_queue_drift_fused_matches_eager(data):
    """ACCEPTANCE: the queue-coupled scenario runs end-to-end inside the
    fused scan — capacities generated from the carried state, policy
    re-solved in-scan, NO host coefficient path — and its tau/d history
    matches the eager host rollout exactly; accuracies agree to float
    tolerance."""
    train, test = data
    qd = QueueDrift(congestion=1.0, gain=2.0)
    kw = dict(k=4, T=15.0, cycles=5, total_samples=1200, seed=3,
              reallocate=True, drift=qd, train=train, test=test)
    eager = run_experiment(**kw)
    fused = run_experiment(**kw, fused=True)
    he, hf = eager["history"], fused["history"]
    assert len(he) == len(hf) == 5
    for re_, rf in zip(he, hf):
        np.testing.assert_array_equal(re_["tau"], rf["tau"])
        np.testing.assert_array_equal(re_["d"], rf["d"])
        assert re_["max_staleness"] == rf["max_staleness"]
    # the coupling actually moved the allocation within the run
    ds = np.stack([h["d"] for h in he])
    assert not np.all(ds == ds[0])
    np.testing.assert_allclose(
        [h["accuracy"] for h in he], [h["accuracy"] for h in hf], atol=5e-3
    )


def test_queue_drift_infeasible_raises_in_scan(data):
    """A coupling strong enough to choke the fleet raises the shared
    infeasibility error naming the first bad cycle — from the IN-SCAN
    guard on the fused path and from the host rollout on the eager path —
    and the fused orchestrator's params stay finite (trained through the
    feasible prefix only)."""
    train, test = data
    qd = QueueDrift(congestion=30.0, gain=8.0, q_max=20.0)
    kw = dict(k=4, T=15.0, cycles=6, total_samples=1200, seed=3,
              reallocate=True, drift=qd, train=train, test=test)
    with pytest.raises(ValueError, match="cannot absorb"):
        run_experiment(**kw)
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    orch = Orchestrator(MELConfig(T=15.0, total_samples=1200), prob,
                        mlp.loss, mlp.init(jax.random.key(0)), seed=3,
                        drift=qd)
    with pytest.raises(ValueError, match="at cycle") as ei:
        orch.run(train, 6, fused=True, reallocate=True)
    assert "cannot absorb" in str(ei.value)
    for leaf in jax.tree_util.tree_leaves(orch.params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_async_engine_threads_queue_drift(data):
    """The async engine rolls the SAME coupled block dynamics through its
    per-block re-solves: the barrier (M = K) regime reproduces the
    orchestrator's eager reallocation history and params bitwise, and the
    event-driven jagged path runs under the coupled schedule with
    per-block allocation movement."""
    train, _ = data
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    params = mlp.init(jax.random.key(3))
    qd = QueueDrift(congestion=1.0, gain=2.0)

    orch = Orchestrator(MELConfig(T=15.0, total_samples=1200), prob,
                        mlp.loss, params, seed=3, drift=qd)
    ho = orch.run(train, 3, reallocate=True)
    eng = AsyncFedEngine(
        AsyncConfig(mode="buffered", barrier=True, reallocate=True), prob,
        mlp.loss, params, seed=3, drift=qd,
    )
    ha = eng.run(train, cycles=3)
    for ro, ra in zip(ho, ha):
        np.testing.assert_array_equal(ro["tau"], ra["tau"])
        np.testing.assert_array_equal(ro["d"], ra["d"])
    _tree_equal(orch.params, eng.params)

    # event-driven: jagged path == eager loop under the coupled schedule
    e1 = AsyncFedEngine(AsyncConfig(mode="fedasync", reallocate=True), prob,
                        mlp.loss, params, seed=3, drift=qd)
    h1 = e1.run(train, 3 * prob.T)
    e2 = AsyncFedEngine(AsyncConfig(mode="fedasync", reallocate=True), prob,
                        mlp.loss, params, seed=3, drift=qd)
    h2 = e2.run_events(train, 3 * prob.T)
    assert len(h1) == len(h2) > 0
    for r1, r2 in zip(h1, h2):
        assert r1["learners"] == r2["learners"]
        np.testing.assert_array_equal(r1["weights"], r2["weights"])
        np.testing.assert_array_equal(r1["d"], r2["d"])


def test_async_engine_rejects_state_drift_without_realloc():
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    with pytest.raises(ValueError, match="reallocate=True"):
        AsyncFedEngine(AsyncConfig(mode="fedasync"), prob, mlp.loss,
                       mlp.init(jax.random.key(0)), drift=QueueDrift())


def test_orchestrator_rejects_state_drift_with_untraced_scheme(data):
    """Schemes without a traced policy (slsqp, sync) cannot see drifted
    capacities: reallocating under a state-coupled drift must raise, not
    silently simulate static capacities."""
    train, _ = data
    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    orch = Orchestrator(MELConfig(T=15.0, total_samples=1200,
                                  scheme="slsqp"), prob, mlp.loss,
                        mlp.init(jax.random.key(0)), drift=QueueDrift())
    with pytest.raises(ValueError, match="traced policy"):
        orch.run(train, 2, reallocate=True)


def test_coefficient_rows_rejects_state_coupled():
    from repro.fed.orchestrator import coefficient_rows

    prob = build_problem(4, 15.0, total_samples=1200, seed=3)
    with pytest.raises(TypeError, match="state-coupled"):
        coefficient_rows(prob, QueueDrift(), 3)


@settings(max_examples=6, deadline=None)
@given(cong=st.floats(0.1, 2.0), gain=st.floats(0.5, 3.0),
       k=st.integers(3, 6))
def test_queue_drift_rollout_properties(cong, gain, k):
    """Property (seed-pinned examples): every rollout keeps rows finite
    and positive, queues within bounds implied by the factors
    (rate factor in (0, 1]), and the sum constraint intact."""
    prob = build_problem(k, 15.0, total_samples=900, seed=1)
    qd = QueueDrift(congestion=cong, gain=gain)
    (c2s, c1s, c0s), (taus, ds) = _rollout(qd, prob, 4)
    tm = prob.time_model
    assert np.isfinite(c2s).all() and np.isfinite(c1s).all()
    np.testing.assert_array_equal(c2s, np.broadcast_to(tm.c2, c2s.shape))
    assert (c1s >= tm.c1[None] - 1e-12).all()   # rate only degrades
    assert (c0s >= tm.c0[None] - 1e-12).all()
    np.testing.assert_array_equal(ds.sum(axis=1), np.full(4, 900))
    assert (taus >= 0).all()
