"""Unit + property tests for the paper's core: the task-allocation solvers.

Property tests (hypothesis) certify the system invariants on random
heterogeneous fleets:
  * every solver output is feasible (sum, bounds, deadline, integrality);
  * the KKT water-filling point satisfies Theorem 1 stationarity;
  * optimized max staleness <= ETA max staleness (the paper's headline);
  * the synchronous baseline is uniform in tau.
"""

import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or fixed-seed fallback

from repro.core import (
    AllocationProblem,
    TimeModel,
    avg_staleness,
    indoor_80211_profile,
    max_staleness,
    mnist_dnn_cost,
    pod_slice_profile,
    solve_eta,
    solve_kkt_sai,
    solve_pgd_jax,
    solve_slsqp,
    solve_synchronous,
)
from repro.core.solver_kkt import (
    solve_relaxed,
    stationarity_residual,
    variable_upper_bounds,
)
from repro.core.staleness import pair_matrix


def make_problem(k=10, T=15.0, d=6000, seed=0, profile="edge"):
    cost = mnist_dnn_cost()
    profs = (
        indoor_80211_profile(k, seed=seed)
        if profile == "edge"
        else pod_slice_profile(k, seed=seed)
    )
    tm = TimeModel.build(
        profs,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    return AllocationProblem(
        time_model=tm,
        T=T,
        total_samples=d,
        d_lower=max(1, d // (4 * k)),
        d_upper=min(d, 3 * d // k),
    )


# ---------------------------------------------------------------------------
# exact paper constants
# ---------------------------------------------------------------------------

def test_paper_constants_exact():
    cost = mnist_dnn_cost()
    assert cost.model_bits == 8_974_080          # Sec. V-A
    assert cost.flops_per_sample == 1_123_736    # Sec. V-A


def test_pair_matrix_matches_paper_eq10():
    c = pair_matrix(4)
    want = np.array([[0, 1], [0, 2], [0, 3], [1, 2], [1, 3], [2, 3]])
    np.testing.assert_array_equal(c, want)
    assert c.shape[0] == 6  # N = C(4,2)


# ---------------------------------------------------------------------------
# solver correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", [solve_kkt_sai, solve_eta, solve_synchronous])
def test_solver_feasible(solver):
    prob = make_problem()
    alloc = solver(prob)
    alloc.validate(prob)


def test_kkt_matches_slsqp_relaxed():
    prob = make_problem(k=8, seed=2)
    a = solve_kkt_sai(prob)
    b = solve_slsqp(prob)
    np.testing.assert_allclose(a.relaxed_d, b.relaxed_d, rtol=1e-4, atol=1e-3)
    assert a.summary(prob)["max_staleness"] == b.summary(prob)["max_staleness"]


def test_pgd_close_to_kkt():
    prob = make_problem(k=8, seed=4)
    a = solve_kkt_sai(prob)
    c = solve_pgd_jax(prob)
    assert c.summary(prob)["max_staleness"] <= a.summary(prob)["max_staleness"] + 1


def test_theorem1_stationarity():
    prob = make_problem(k=12, seed=1)
    tau, d, _, _ = solve_relaxed(prob)
    assert stationarity_residual(prob, d) < 1e-8


def test_relaxed_full_time_utilization():
    """Constraint (7b): at the relaxed optimum every learner works t_k = T."""
    prob = make_problem(k=9, seed=5)
    tau, d, _, _ = solve_relaxed(prob)
    t = prob.time_model.cycle_time(tau, d)
    np.testing.assert_allclose(t, prob.T, rtol=1e-6)


def test_variable_upper_bounds_hold():
    prob = make_problem(k=7, seed=6)
    tau_ub, d_ub = variable_upper_bounds(prob)
    alloc = solve_kkt_sai(prob)
    assert np.all(alloc.tau <= tau_ub + 1e-9)
    assert np.all(alloc.d <= np.ceil(d_ub) + 1e-9)


def test_sync_uniform_tau():
    prob = make_problem(k=10, seed=3)
    alloc = solve_synchronous(prob)
    assert np.all(alloc.tau == alloc.tau[0])
    assert max_staleness(alloc.tau) == 0


def test_infeasible_rejected():
    prob = make_problem(k=6, T=15.0)
    with pytest.raises(ValueError):
        AllocationProblem(
            time_model=prob.time_model, T=15.0, total_samples=100,
            d_lower=50, d_upper=60,
        )


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

fleet = st.integers(min_value=3, max_value=16)
cycle_T = st.sampled_from([5.0, 7.5, 15.0, 30.0])
seeds = st.integers(min_value=0, max_value=10_000)
profile = st.sampled_from(["edge", "pod"])


@settings(max_examples=40, deadline=None)
@given(k=fleet, T=cycle_T, seed=seeds, profile=profile)
def test_property_kkt_feasible_and_beats_eta(k, T, seed, profile):
    try:
        prob = make_problem(k=k, T=T, seed=seed, profile=profile)
        alloc = solve_kkt_sai(prob)
        eta = solve_eta(prob)
    except ValueError:
        return  # infeasible instance: nothing to compare
    alloc.validate(prob)
    eta.validate(prob)
    # headline claim: optimized staleness never exceeds equal-task staleness
    assert max_staleness(alloc.tau) <= max_staleness(eta.tau)
    assert avg_staleness(alloc.tau) <= avg_staleness(eta.tau) + 1e-9


@settings(max_examples=25, deadline=None)
@given(k=fleet, T=cycle_T, seed=seeds)
def test_property_relaxed_is_stationary(k, T, seed):
    try:
        prob = make_problem(k=k, T=T, seed=seed)
        _, d, _, _ = solve_relaxed(prob)
    except ValueError:
        return
    assert stationarity_residual(prob, d) < 1e-6
    assert abs(d.sum() - prob.total_samples) < 1e-3 * prob.total_samples


@settings(max_examples=25, deadline=None)
@given(k=fleet, T=cycle_T, seed=seeds)
def test_property_sync_never_more_updates_than_async(k, T, seed):
    """Async dominates sync in total update count (the mechanism behind the
    paper's accuracy gains)."""
    try:
        prob = make_problem(k=k, T=T, seed=seed)
        a = solve_kkt_sai(prob)
        s = solve_synchronous(prob)
    except ValueError:
        return
    assert int((a.tau * a.d).sum()) >= int((s.tau * s.d).sum())
