"""Golden-trace regression fixtures for the async engine.

Two recorded scenarios under ``tests/data/``:

* ``golden_async_fedasync.json`` — plain fedasync arrivals;
* ``golden_async_cocktail.json`` — buffered M=3 with the full fault
  cocktail (drops + transit delay + stragglers + deadline redispatch +
  quorum/timeout degraded flushes).

Each fixture pins the seeded schedule side of the history BITWISE
(versions, learners, tau, d, staleness, t, f64 weights/keep/energy: all
host-computed f64/int values that round-trip JSON exactly) and the final
aggregated params to float tolerance (XLA:CPU re-fuses contractions across
processes, so trained floats are reproducible only to ~1e-5; see
CHANGES.md PR 3). The replay runs BOTH executors — the eager ``run`` loop
and the jitted ``run_events`` jagged scan — against the same fixture, so
the eager==jagged exactness invariants are guarded against drift in either
path, not just against each other.

Regenerate (after an INTENTIONAL semantics change only):

    PYTHONPATH=src python -m tests.test_golden_trace
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import synthetic_mnist
from repro.fed.async_engine import AsyncConfig, AsyncFedEngine
from repro.fed.simulation import build_problem
from repro.models import mlp

DATA_DIR = Path(__file__).parent / "data"

# all entropy a scenario needs, spelled out so the fixture is re-derivable
SCENARIOS = {
    "fedasync": {
        "config": {"mode": "fedasync"},
        "problem": {"k": 4, "T": 15.0, "total_samples": 1200, "seed": 3},
        "data": {"n": 2000, "n_test": 200, "features": 16, "classes": 4, "seed": 0},
        "layers": [16, 16, 4],
        "init_seed": 2,
        "engine_seed": 2,
        "horizon": 40.0,
    },
    "cocktail": {
        "config": {
            "mode": "buffered", "buffer_size": 3,
            "quorum": 2, "flush_timeout": 6.0,
            "drop_rate": 0.15,
            "delay_rate": 0.2, "delay_mean": 0.5,
            "straggler_rate": 0.2, "straggler_factor": 3.0,
            "deadline": 30.0, "retry_backoff": 1.0,
        },
        "problem": {"k": 5, "T": 15.0, "total_samples": 1500, "seed": 4},
        "data": {"n": 2000, "n_test": 200, "features": 16, "classes": 4, "seed": 0},
        "layers": [16, 16, 4],
        "init_seed": 4,
        "engine_seed": 7,
        "horizon": 60.0,
    },
}

# schedule-side row fields and their JSON codecs (all bitwise on replay)
_INT_FIELDS = ("event", "server_version", "version_staleness_max")
_FLOAT_FIELDS = ("t", "version_staleness_mean", "keep")
_INTLIST_FIELDS = ("learners", "tau", "d", "staleness_list")
_FLOATLIST_FIELDS = ("weights", "energy")


def _scenario_engine(spec):
    train, _ = synthetic_mnist(
        spec["data"]["n"], n_test=spec["data"]["n_test"],
        features=spec["data"]["features"], classes=spec["data"]["classes"],
        seed=spec["data"]["seed"],
    )
    prob = build_problem(
        spec["problem"]["k"], spec["problem"]["T"],
        total_samples=spec["problem"]["total_samples"],
        seed=spec["problem"]["seed"],
    )
    params = mlp.init(jax.random.key(spec["init_seed"]), spec["layers"])
    eng = AsyncFedEngine(AsyncConfig(**spec["config"]), prob, mlp.loss,
                         params, seed=spec["engine_seed"])
    return eng, train


def _run_scenario(spec, *, path):
    eng, train = _scenario_engine(spec)
    if path == "events":
        hist = eng.run_events(train, spec["horizon"])
    else:
        hist = eng.run(train, spec["horizon"])
    return hist, eng.params


def _row_to_json(r):
    out = {}
    for f in _INT_FIELDS:
        out[f] = int(r[f])
    for f in _FLOAT_FIELDS:
        out[f] = float(r[f])
    for f in _INTLIST_FIELDS:
        out[f] = [int(v) for v in np.asarray(r[f])]
    for f in _FLOATLIST_FIELDS:
        out[f] = [float(v) for v in np.asarray(r[f], np.float64)]
    out["mode"] = r["mode"]
    return out


def _params_to_json(params):
    leaves = jax.tree_util.tree_leaves(params)
    return {
        "shapes": [list(l.shape) for l in leaves],
        "leaves": [np.asarray(l, np.float32).ravel().tolist() for l in leaves],
    }


def record(name):
    spec = SCENARIOS[name]
    hist, params = _run_scenario(spec, path="run")
    fixture = {
        "scenario": name,
        "spec": spec,
        "history": [_row_to_json(r) for r in hist],
        "params": _params_to_json(params),
    }
    path = DATA_DIR / f"golden_async_{name}.json"
    path.parent.mkdir(exist_ok=True)
    path.write_text(json.dumps(fixture, indent=1) + "\n")
    return path, len(hist)


def _assert_rows_match(got_rows, want_rows, *, path):
    assert len(got_rows) == len(want_rows), (
        f"[{path}] {len(got_rows)} aggregations vs {len(want_rows)} recorded"
    )
    for i, (g, w) in enumerate(zip(got_rows, want_rows)):
        ctx = f"[{path}] row {i}"
        assert g["mode"] == w["mode"], ctx
        for f in _INT_FIELDS:
            assert int(g[f]) == w[f], f"{ctx}: {f}"
        for f in _FLOAT_FIELDS:
            # host-side f64: JSON round-trips repr exactly -> bitwise
            assert float(g[f]) == w[f], f"{ctx}: {f}"
        for f in _INTLIST_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(g[f], np.int64), np.asarray(w[f], np.int64),
                err_msg=f"{ctx}: {f}")
        for f in _FLOATLIST_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(g[f], np.float64), np.asarray(w[f], np.float64),
                err_msg=f"{ctx}: {f}")


def _assert_params_match(params, want):
    leaves = jax.tree_util.tree_leaves(params)
    assert [list(l.shape) for l in leaves] == want["shapes"]
    for l, (flat, shape) in zip(leaves, zip(want["leaves"], want["shapes"])):
        np.testing.assert_allclose(
            np.asarray(l, np.float32),
            np.asarray(flat, np.float32).reshape(shape),
            rtol=1e-4, atol=1e-4,
        )


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("path", ["run", "events"])
def test_golden_trace_replay(name, path):
    fixture_path = DATA_DIR / f"golden_async_{name}.json"
    fixture = json.loads(fixture_path.read_text())
    assert fixture["spec"] == SCENARIOS[name], (
        f"{fixture_path} was recorded under a different scenario spec; "
        "regenerate with `python -m tests.test_golden_trace` if the "
        "change is intentional"
    )
    hist, params = _run_scenario(SCENARIOS[name], path=path)
    _assert_rows_match([_row_to_json(r) for r in hist], fixture["history"],
                       path=path)
    _assert_params_match(params, fixture["params"])


def test_cocktail_trace_exercises_fault_paths():
    """The recorded cocktail is only a regression guard if the fault
    machinery actually fired: the fixture must contain a degraded/timer
    flush (keep path) and non-trivial staleness."""
    fixture = json.loads(
        (DATA_DIR / "golden_async_cocktail.json").read_text())
    rows = fixture["history"]
    sizes = {len(r["learners"]) for r in rows}
    assert any(s < SCENARIOS["cocktail"]["config"]["buffer_size"]
               for s in sizes), "no under-quorum/degraded flush recorded"
    assert any(r["version_staleness_max"] > 0 for r in rows)


if __name__ == "__main__":
    for name in sorted(SCENARIOS):
        path, n = record(name)
        print(f"wrote {path} ({n} aggregations)")
