"""Substrate unit tests: optimizers, schedules-free LR handling, layers,
data pipeline determinism, time-model algebra."""

import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or fixed-seed fallback

import jax
import jax.numpy as jnp

from repro.core.time_model import ChannelParams, TimeModel, indoor_80211_profile
from repro.data.pipeline import synthetic_mnist, token_batches
from repro.models.layers import layer_norm, rms_norm, rope
from repro.optim.optimizers import adamw, clip_by_global_norm, get_optimizer, momentum, sgd

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_loss(params):
    return jnp.sum((params["w"] - 3.0) ** 2)


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_optimizers_converge_on_quadratic(name):
    opt = get_optimizer(name, 0.1)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(_quad_loss)(params)
        params, state = opt.apply(g, state, params)
    # adamw's weight decay biases the fixed point slightly below 3.0
    tol = 0.2 if name == "adamw" else 1e-2
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0, atol=tol)


def test_adam_matches_reference_first_step():
    opt = adamw(lr=0.001, wd=0.0)
    params = {"w": jnp.ones(3)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    new, _ = opt.apply(g, state, params)
    # first Adam step is -lr * sign(g) (bias-corrected m/sqrt(v) = sign)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0 - 0.001 * np.sign([1.0, -2.0, 0.5]), rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-6)
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0])


def test_optimizer_state_dtype_f32_for_bf16_params():
    opt = adamw(lr=1e-3)
    params = {"w": jnp.ones(3, jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(3, jnp.bfloat16)}
    new, state = opt.apply(g, state, params)
    assert new["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def test_rms_norm_unit_scale():
    x = jax.random.normal(KEY, (4, 64)) * 7.0
    out = rms_norm(x, jnp.ones(64))
    rms = jnp.sqrt(jnp.mean(out.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_layer_norm_zero_mean():
    x = jax.random.normal(KEY, (4, 64)) + 5.0
    out = layer_norm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.asarray(out.mean(-1)), 0.0, atol=1e-4)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(KEY, (1, 8, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    out = rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # dot(q_i, k_j) depends only on i - j: shift both positions by 3
    q, k = x[:, :1], x[:, 1:2]
    d1 = jnp.einsum("bshd,bshd->", rope(q, pos[:, :1]), rope(k, pos[:, 1:2]))
    d2 = jnp.einsum(
        "bshd,bshd->", rope(q, pos[:, :1] + 3), rope(k, pos[:, 1:2] + 3)
    )
    assert float(jnp.abs(d1 - d2)) < 1e-3


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_mnist_deterministic():
    a, _ = synthetic_mnist(500, n_test=10, seed=7)
    b, _ = synthetic_mnist(500, n_test=10, seed=7)
    np.testing.assert_array_equal(a.x, b.x)
    c, _ = synthetic_mnist(500, n_test=10, seed=8)
    assert not np.array_equal(a.x, c.x)


def test_token_batches_shapes_and_learnability():
    gen = token_batches(np.random.default_rng(0), batch=4, seq=33, vocab=97)
    b = next(gen)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert b["tokens"].max() < 97


# ---------------------------------------------------------------------------
# time model algebra (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    k=st.integers(2, 12),
    seed=st.integers(0, 999),
    t=st.floats(5.0, 50.0),
)
def test_tau_d_inverse_maps(k, seed, t):
    from repro.core import mnist_dnn_cost

    cost = mnist_dnn_cost()
    tm = TimeModel.build(
        indoor_80211_profile(k, seed=seed),
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    d = np.linspace(50, 500, k)
    tau = tm.tau_of_d(d, t)
    d_back = tm.d_of_tau(tau, t)
    np.testing.assert_allclose(d_back, d, rtol=1e-9)
    np.testing.assert_allclose(tm.cycle_time(tau, d), t, rtol=1e-9)


def test_channel_rate_monotone_in_gain():
    lo = ChannelParams(gain=1e-9).rate_bps()
    hi = ChannelParams(gain=1e-7).rate_bps()
    assert hi > lo > 0


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_warmup_cosine_shape():
    from repro.optim.schedules import warmup_cosine

    f = warmup_cosine(1.0, warmup_steps=10, total_steps=110, final_frac=0.1)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1.0)
    assert float(f(5)) == pytest.approx(0.5)
    assert float(f(110)) == pytest.approx(0.1, abs=1e-6)
    vals = [float(f(i)) for i in range(10, 111, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))  # monotone decay


def test_warmup_linear_decay_endpoints():
    from repro.optim.schedules import warmup_linear_decay

    f = warmup_linear_decay(2.0, warmup_steps=4, total_steps=20)
    assert float(f(0)) == 0.0
    assert float(f(4)) == pytest.approx(2.0)
    assert float(f(20)) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# batched JAX PGD allocator (fleet-scale scheduling tick)
# ---------------------------------------------------------------------------

def test_pgd_relaxed_batch_vmapped_fleets():
    from repro.core import mnist_dnn_cost
    from repro.core.solver_numeric import pgd_relaxed_batch

    cost = mnist_dnn_cost()
    fleets = []
    for seed in (0, 1, 2, 3):
        tm = TimeModel.build(
            indoor_80211_profile(6, seed=seed),
            model_complexity_flops=cost.flops_per_sample,
            model_size_bits=cost.model_bits,
        )
        fleets.append(tm)
    c2 = jnp.stack([jnp.asarray(t.c2) for t in fleets])
    c1 = jnp.stack([jnp.asarray(t.c1) for t in fleets])
    c0 = jnp.stack([jnp.asarray(t.c0) for t in fleets])
    total = jnp.full((4,), 3000.0)
    d_lo = jnp.full((4,), 100.0)
    d_hi = jnp.full((4,), 1500.0)
    d0 = jnp.full((4, 6), 500.0)
    T = jnp.full((4,), 15.0)
    tau, d = pgd_relaxed_batch(d0, c2, c1, c0, T, d_lo, d_hi, total)
    assert tau.shape == (4, 6) and d.shape == (4, 6)
    np.testing.assert_allclose(np.asarray(d.sum(1)), 3000.0, rtol=1e-3)
    assert np.all(np.asarray(d) >= 100.0 - 1e-3)
    assert np.all(np.asarray(d) <= 1500.0 + 1e-3)
    # relaxed staleness small: spread of tau within each fleet
    spread = np.asarray(tau.max(1) - tau.min(1))
    assert np.all(spread < 3.0)


# ---------------------------------------------------------------------------
# fed runtime lowers on a mesh (learner axis sharded over data)
# ---------------------------------------------------------------------------

def test_local_train_lowers_sharded_over_learners():
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.fed.orchestrator import local_train
    from repro.models import mlp

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    k, dmax, feat = 4, 32, 784
    params = mlp.init(jax.random.key(0))
    x = jax.ShapeDtypeStruct((k, dmax, feat), jnp.float32)
    y = jax.ShapeDtypeStruct((k, dmax), jnp.int32)
    m = jax.ShapeDtypeStruct((k, dmax), jnp.float32)
    tau = jax.ShapeDtypeStruct((k,), jnp.int32)
    lsh = NamedSharding(mesh, P("data"))
    import functools

    fn = functools.partial(local_train, max_tau=4, loss_fn=mlp.loss)
    with mesh:  # Mesh is the context manager (jax.set_mesh is newer-jax only)
        lowered = jax.jit(
            fn,
            in_shardings=(None, lsh, lsh, lsh, lsh, None),
        ).lower(params, x, y, m, tau, jnp.float32(0.1))
        compiled = lowered.compile()
    assert compiled is not None
