"""Property-test compat layer: real hypothesis when installed, otherwise a
fixed-seed degradation so the suite collects and runs without the optional
dependency (declared as the ``test`` extra in pyproject.toml).

The fallback implements just the surface these tests use — ``given`` with
keyword strategies, ``settings`` as a no-op decorator, and the
``integers`` / ``floats`` / ``sampled_from`` strategies — and replays each
property over a deterministic batch of examples drawn from one seeded rng
(no shrinking, no database)."""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def draw(self, rng):
            return self._sample(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._sample(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    st = _Strategies()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # deliberately NOT functools.wraps: pytest must see a bare
            # () signature, not the strategy params (it would treat them
            # as fixtures)
            def wrapper():
                rng = _np.random.default_rng(0)
                for _ in range(_FALLBACK_EXAMPLES):
                    draws = {name: s.draw(rng) for name, s in strategies.items()}
                    fn(**draws)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


# ---------------------------------------------------------------------------
# shared domain strategies
# ---------------------------------------------------------------------------

def make_batched_problems(seed, *, n_problems=3, k_max=8):
    """Deterministic mixed-K batch: ``(problems, BatchedProblems)``.

    Stress surface for the batched engine's mask semantics: fleet sizes are
    mixed (1..k_max, the first fleet pinned at k_max so the padded struct
    shape is stable across draws), ~30% of fleets carry a degenerate
    ``d_lo == d_hi`` box (total pinned to K*d_lo), and the padding itself
    yields zero-capacity slots (d_lo = d_hi = 0, valid=False). Problems are
    time-feasible by construction: T exceeds every learner's c0 + c1*d_u,
    so at tau=0 the fleet absorbs K*d_u >= total samples.
    """
    import numpy as _np

    from repro.core import AllocationProblem, BatchedProblems, TimeModel

    rng = _np.random.default_rng(seed)
    problems = []
    for i in range(n_problems):
        k = k_max if i == 0 else int(rng.integers(1, k_max + 1))
        c2 = rng.uniform(1e-4, 5e-3, k)
        c1 = rng.uniform(1e-5, 1e-3, k)
        c0 = rng.uniform(0.05, 0.5, k)
        if rng.random() < 0.3:          # degenerate box: d is fully pinned
            d_l = d_u = int(rng.integers(5, 40))
            total = k * d_l
        else:
            per = int(rng.integers(20, 120))
            total = k * per
            d_l = max(1, per // 4)
            d_u = min(total, 3 * per)
        T = float(_np.max(c0 + c1 * d_u) * (1.0 + rng.uniform(0.1, 1.0)))
        problems.append(
            AllocationProblem(
                time_model=TimeModel(c2=c2, c1=c1, c0=c0), T=T,
                total_samples=total, d_lower=d_l, d_upper=d_u,
            )
        )
    return problems, BatchedProblems.from_problems(problems)


def batched_problems(**kwargs):
    """Strategy over ``(problems, BatchedProblems)`` pairs — seeds mapped
    through ``make_batched_problems`` so real hypothesis and the fallback
    draw from the identical distribution."""
    return st.integers(0, 2**20).map(
        lambda s: make_batched_problems(int(s), **kwargs)
    )
