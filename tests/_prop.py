"""Property-test compat layer: real hypothesis when installed, otherwise a
fixed-seed degradation so the suite collects and runs without the optional
dependency (declared as the ``test`` extra in pyproject.toml).

The fallback implements just the surface these tests use — ``given`` with
keyword strategies, ``settings`` as a no-op decorator, and the
``integers`` / ``floats`` / ``sampled_from`` strategies — and replays each
property over a deterministic batch of examples drawn from one seeded rng
(no shrinking, no database)."""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def draw(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    st = _Strategies()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # deliberately NOT functools.wraps: pytest must see a bare
            # () signature, not the strategy params (it would treat them
            # as fixtures)
            def wrapper():
                rng = _np.random.default_rng(0)
                for _ in range(_FALLBACK_EXAMPLES):
                    draws = {name: s.draw(rng) for name, s in strategies.items()}
                    fn(**draws)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
