"""Energy-frontier benchmark: budgeted KKT allocation vs energy-blind
schemes across per-learner battery budgets (``--only energy``).

Runs ``fed.simulation.energy_sweep`` — the async engine at equal virtual
time, per-dispatch budgeted re-solves for ``kkt_energy``, the same fleet
with the energy model attached for accounting only under the blind
schemes — at >= 3 budget levels anchored to the blind allocation's own
median per-learner cycle energy, and merges the accuracy / joules /
violation rows into ``BENCH_alloc.json`` under the ``energy`` section.

``kkt_energy`` rows must report zero violations (budget satisfaction is
by construction); the blind rows' violation counts are scored externally
against the same budget and are the frontier's cost axis.

  PYTHONPATH=src python -m benchmarks.run --only energy
"""

from __future__ import annotations

import time

from benchmarks.alloc_bench import _merge_out
from repro.fed.simulation import energy_sweep


def main(quick: bool = False) -> None:
    budget_fracs = (0.5, 0.75, 1.0) if quick else (0.4, 0.6, 0.8, 1.0, 1.25)
    cycles = 4 if quick else 10
    total = 400 if quick else 1200
    # full mode adds the second budgeted scheme (energy-aware PGD) to the
    # frontier; quick/CI keeps the fast analytic trio
    schemes = (("kkt_energy", "kkt_sai", "eta") if quick
               else ("kkt_energy", "pgd", "kkt_sai", "eta"))
    t0 = time.time()
    rows = energy_sweep(
        budget_fracs, k=4, T=8.0, cycles=cycles, total_samples=total, seed=0,
        schemes=schemes,
    )
    elapsed = time.time() - t0
    for r in rows:
        print(
            f"  frac={r['budget_frac']:.2f} {r['scheme']:<11} "
            f"acc={round(r['final_accuracy'], 4)} "
            f"J={r['joules_total']:.1f} p99={r['joules_p99']:.2f} "
            f"viol={r['violations']} aggs={r['aggregations']:>3}"
        )
    aware = [r for r in rows if r["energy_aware"]]
    bad = [r for r in aware if r["violations"]]
    if bad:
        raise AssertionError(
            f"kkt_energy must satisfy its budget by construction: {bad}"
        )
    _merge_out("energy", {
        "mode": "fedasync",
        "cycles": cycles,
        "budget_fracs": list(budget_fracs),
        "sweep": rows,
        "elapsed_s": round(elapsed, 2),
    })


if __name__ == "__main__":
    main()
