"""Allocation-engine benchmark: problems/sec for the per-problem Python
KKT+SAI solver vs the batched engine, plus eager-vs-fused orchestrator
cycle wall-time and the per-cycle reallocation scenario (time-varying
capacities: fleet x cycle re-solves batched vs the Python loop, and the
in-scan reallocating orchestrator vs its eager twin). Emits
machine-readable ``BENCH_alloc.json`` (the perf trajectory seed for the
fleet-scale scheduling path); ``main`` and ``realloc_main`` merge their
sections into the same file.

  PYTHONPATH=src python -m benchmarks.run --only alloc     # alloc + realloc
  PYTHONPATH=src python -m benchmarks.run --only realloc   # realloc rows only
"""

from __future__ import annotations

import datetime
import json
import pathlib
import time

import numpy as np

import jax

from repro.core import (
    AllocationProblem,
    BatchedProblems,
    CapacityDrift,
    TimeModel,
    indoor_80211_profile,
    mnist_dnn_cost,
    solve_kkt_batched,
    solve_kkt_sai,
)

OUT_PATH = pathlib.Path("BENCH_alloc.json")


def _wrap_section(payload, device, written_at) -> dict:
    return {"device": device, "written_at": written_at, "data": payload}


def _merge_out(section: str, payload) -> None:
    """Merge ``payload`` into ``BENCH_alloc.json`` under ``section``.

    Each section records the backend that ACTUALLY produced it
    (``jax.default_backend()``) and a UTC timestamp — a single top-level
    ``"device": "cpu"`` would misattribute sections merged in from a GPU/TPU
    run of one bench into a file seeded on CPU. Legacy files with the old
    top-level device key are migrated in place on first merge (their
    sections inherit that device, with a null timestamp)."""
    data: dict = {"bench": "alloc"}
    if OUT_PATH.exists():
        old = json.loads(OUT_PATH.read_text())
        legacy_device = old.pop("device", None)
        data.update(old)
        if legacy_device is not None:
            for name, sec in data.items():
                if name == "bench":
                    continue
                if not (isinstance(sec, dict) and "data" in sec
                        and "device" in sec):
                    data[name] = _wrap_section(sec, legacy_device, None)
    data[section] = _wrap_section(
        payload,
        jax.default_backend(),
        datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
    )
    OUT_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"# wrote {OUT_PATH} [{section}] ({data[section]['device']})")


def _make_problem(k: int, seed: int, total: int = 6000) -> AllocationProblem:
    cost = mnist_dnn_cost()
    tm = TimeModel.build(
        indoor_80211_profile(k, seed=seed),
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    return AllocationProblem(
        time_model=tm, T=15.0, total_samples=total,
        d_lower=max(1, total // (4 * k)), d_upper=min(total, 3 * total // k),
    )


def bench_alloc(b: int, k: int, *, loop_sample: int) -> dict:
    probs = [_make_problem(k, seed) for seed in range(b)]
    bp = BatchedProblems.from_problems(probs)

    n_loop = min(loop_sample, b)
    t0 = time.time()
    for p in probs[:n_loop]:
        solve_kkt_sai(p)
    loop_s = (time.time() - t0) / n_loop * b

    solve_kkt_batched(bp)            # compile + warmup
    t0 = time.time()
    ba = solve_kkt_batched(bp)
    batched_s = time.time() - t0
    assert bool(ba.feasible.all())

    return {
        "B": b,
        "K": k,
        "python_loop_s": round(loop_s, 4),
        "python_loop_sampled": n_loop,
        "batched_s": round(batched_s, 5),
        "problems_per_sec_loop": round(b / loop_s, 1),
        "problems_per_sec_batched": round(b / batched_s, 1),
        "speedup": round(loop_s / batched_s, 1),
    }


def bench_orchestrator(*, k: int = 6, t_cycle: float = 5.0, cycles: int = 8,
                       total: int = 900) -> dict:
    """Cycle wall-time of Orchestrator.run eager vs fused (data synthesis,
    problem build and jit warmup excluded from the timed region)."""
    import jax

    from repro.data.pipeline import synthetic_mnist
    from repro.fed.orchestrator import MELConfig, Orchestrator
    from repro.fed.simulation import build_problem
    from repro.models import mlp

    train, test = synthetic_mnist(max(total * 2, 6000), seed=0)
    prob = build_problem(k, t_cycle, total_samples=total, seed=0)
    mel = MELConfig(T=t_cycle, total_samples=total)
    eval_batch = (test.x[:2000], test.y[:2000])

    def make_run(fused: bool):
        # a fresh orchestrator per run (construction excluded from timing)
        orch = Orchestrator(mel, prob, mlp.loss, mlp.init(jax.random.key(0)), seed=0)
        if fused:
            return lambda: orch.run(train, cycles, fused=True,
                                    eval_fn=mlp.accuracy, eval_batch=eval_batch)
        import functools

        eval_fn = functools.partial(mlp.accuracy, x=jax.numpy.asarray(eval_batch[0]),
                                    y=jax.numpy.asarray(eval_batch[1]))
        return lambda: orch.run(train, cycles, eval_fn=lambda p: eval_fn(p))

    make_run(True)()                 # compile + warmup both paths
    make_run(False)()
    run_eager = make_run(False)
    run_fused = make_run(True)
    t0 = time.time()
    run_eager()
    eager_s = time.time() - t0
    t0 = time.time()
    run_fused()
    fused_s = time.time() - t0
    return {
        "K": k,
        "cycles": cycles,
        "eager_s": round(eager_s, 3),
        "fused_s": round(fused_s, 3),
        "eager_cycle_ms": round(eager_s / cycles * 1e3, 1),
        "fused_cycle_ms": round(fused_s / cycles * 1e3, 1),
        "speedup": round(eager_s / fused_s, 2),
    }


def bench_realloc_alloc(n_fleets: int, k: int, cycles: int, *,
                        loop_sample: int, total: int = 6000) -> dict:
    """Adaptive re-solve throughput under drift: every (fleet, cycle)
    capacity state is its own KKT problem — the Python loop re-solves them
    one by one, the batched engine pads all n_fleets * cycles states into
    one struct and solves them as ONE XLA call."""
    base = [_make_problem(k, seed, total=total) for seed in range(n_fleets)]
    drift = CapacityDrift(seed=0)
    probs = []
    for p in base:
        c2s, c1s, c0s = drift.coefficient_path(p.time_model, cycles)
        for c in range(cycles):
            probs.append(AllocationProblem(
                time_model=TimeModel(c2=c2s[c], c1=c1s[c], c0=c0s[c]),
                T=p.T, total_samples=p.total_samples,
                d_lower=p.d_lower, d_upper=p.d_upper,
            ))
    bp = BatchedProblems.from_problems(probs)
    b = len(probs)

    n_loop = min(loop_sample, b)
    t0 = time.time()
    for p in probs[:n_loop]:
        solve_kkt_sai(p)
    loop_s = (time.time() - t0) / n_loop * b

    solve_kkt_batched(bp)            # compile + warmup
    t0 = time.time()
    ba = solve_kkt_batched(bp)
    batched_s = time.time() - t0
    assert bool(ba.feasible.all())

    return {
        "fleets": n_fleets,
        "K": k,
        "cycles": cycles,
        "resolves": b,
        "python_loop_s": round(loop_s, 4),
        "python_loop_sampled": n_loop,
        "batched_s": round(batched_s, 5),
        "resolves_per_sec_loop": round(b / loop_s, 1),
        "resolves_per_sec_batched": round(b / batched_s, 1),
        "speedup": round(loop_s / batched_s, 1),
    }


def bench_realloc_orchestrator(*, k: int = 6, t_cycle: float = 5.0,
                               cycles: int = 8, total: int = 900) -> dict:
    """Wall-time of a full reallocating run: eager (one host round-trip +
    one host re-solve per cycle) vs fused (per-cycle KKT re-solve traced
    INSIDE the scan — a single XLA program for the whole run).

    Caveats for reading the CPU number: the warmup run hides that the eager
    path re-jits local_train for every distinct per-cycle max(tau) a fresh
    drift path produces, and CPU is compute-bound (ROADMAP): the fused
    variant pays d_upper-wide shard padding where eager pads to the cycle's
    actual max d. The in-scan path's win — zero per-cycle host staging and
    zero recompiles — shows up on accelerator runtimes."""
    from repro.fed.simulation import run_experiment

    drift = CapacityDrift(seed=0)
    kw = dict(k=k, T=t_cycle, cycles=cycles, total_samples=total, seed=0,
              reallocate=True, drift=drift)
    run_experiment(**kw, fused=True)     # compile + warmup both paths
    run_experiment(**kw)
    t0 = time.time()
    run_experiment(**kw)
    eager_s = time.time() - t0
    t0 = time.time()
    run_experiment(**kw, fused=True)
    fused_s = time.time() - t0
    return {
        "K": k,
        "cycles": cycles,
        "eager_s": round(eager_s, 3),
        "fused_s": round(fused_s, 3),
        "eager_cycle_ms": round(eager_s / cycles * 1e3, 1),
        "fused_cycle_ms": round(fused_s / cycles * 1e3, 1),
        "speedup": round(eager_s / fused_s, 2),
    }


def main(quick: bool = False) -> None:
    shapes = [(64, 10), (1024, 10)] if quick else [(64, 10), (64, 50), (1024, 10), (1024, 50)]
    loop_sample = 128 if quick else 1024

    print("B,K,prob_per_s_loop,prob_per_s_batched,speedup")
    alloc_rows = []
    for b, k in shapes:
        row = bench_alloc(b, k, loop_sample=loop_sample)
        alloc_rows.append(row)
        print(f"{row['B']},{row['K']},{row['problems_per_sec_loop']},"
              f"{row['problems_per_sec_batched']},{row['speedup']}")

    orch = bench_orchestrator(cycles=4 if quick else 8)
    print(f"orchestrator eager {orch['eager_cycle_ms']}ms/cycle vs "
          f"fused {orch['fused_cycle_ms']}ms/cycle ({orch['speedup']}x)")

    _merge_out("alloc", alloc_rows)
    _merge_out("orchestrator", orch)


def realloc_main(quick: bool = False) -> None:
    shapes = [(16, 10, 8)] if quick else [(16, 10, 8), (64, 10, 16), (64, 50, 16)]
    loop_sample = 64 if quick else 512

    print("fleets,K,cycles,resolves_per_s_loop,resolves_per_s_batched,speedup")
    rows = []
    for f, k, c in shapes:
        row = bench_realloc_alloc(f, k, c, loop_sample=loop_sample)
        rows.append(row)
        print(f"{row['fleets']},{row['K']},{row['cycles']},"
              f"{row['resolves_per_sec_loop']},"
              f"{row['resolves_per_sec_batched']},{row['speedup']}")

    orch = bench_realloc_orchestrator(cycles=4 if quick else 8)
    print(f"realloc orchestrator eager {orch['eager_cycle_ms']}ms/cycle vs "
          f"in-scan {orch['fused_cycle_ms']}ms/cycle ({orch['speedup']}x)")

    _merge_out("realloc", {"alloc": rows, "orchestrator": orch})


if __name__ == "__main__":
    main()
    realloc_main()
