"""Fleet-of-fleets scale benchmark: simulated learners per virtual-time
unit sustained by the two-tier ``FleetEngine`` (``fed/fleet.py``).

Two row families, merged into ``BENCH_alloc.json`` under ``fleet_scale``:

  * ``train`` — full engine rounds (vmapped per-fleet train + two-tier
    staleness-discounted merge + the next dispatch's masked policy solve,
    all one XLA program) at F x K = 512 and 10^4 learners on a compact
    MLP. Every fleet trains during every virtual round of length T, so
    ``learners_per_vtu`` is exactly F x K.
  * ``solve`` — the dispatch tier alone: ONE sharded ``batched_policy``
    call allocating (tau, d) for 10^6 learners, the population the
    engine's allocation path sustains per round.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
fleet-scale CI step does) to put the rows on the real (2, 4) ``"test"``
shard_map mesh; elsewhere they fall back to the 1-device ``"cpu"`` mesh.

  PYTHONPATH=src python -m benchmarks.run --only fleet
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from benchmarks.alloc_bench import _merge_out
from repro.fed.fleet import _fleet_solve, build_fleet_problems
from repro.fed.simulation import fleet_scale_sweep
from repro.launch.mesh import host_mesh
from repro.sharding.rules import fleet_partition_axes


def solve_only_row(f: int, k: int = 8, *, mesh=None, scheme: str = "kkt_sai",
                   T: float = 6.0, total_samples: int = 60,
                   seed: int = 0) -> dict:
    """Time the sharded fleet dispatch solve on an (F, K) population —
    compile on a warmup call, then one timed solve."""
    mesh = host_mesh() if mesh is None else mesh
    bp = build_fleet_problems(f, k, T=T, total_samples=total_samples,
                              seed=seed)
    axes = fleet_partition_axes(f, mesh)
    with enable_x64():
        args = (
            jnp.asarray(bp.c2, jnp.float64), jnp.asarray(bp.c1, jnp.float64),
            jnp.asarray(bp.c0, jnp.float64), jnp.asarray(bp.T, jnp.float64),
            jnp.asarray(bp.total, jnp.int64),
            jnp.asarray(bp.d_lo, jnp.float64),
            jnp.asarray(bp.d_hi, jnp.float64),
            jnp.asarray(bp.valid), jnp.ones(f, bool),
        )
        kw = dict(scheme=scheme, mesh=mesh, fleet_axes=axes)
        jax.block_until_ready(_fleet_solve(*args, **kw))   # compile + warmup
        t0 = time.time()
        tau, d, feas = jax.block_until_ready(_fleet_solve(*args, **kw))
        solve_s = time.time() - t0
    assert bool(np.asarray(feas).all())
    assert bool((np.asarray(d).sum(axis=1) == total_samples).all())
    return {
        "F": f,
        "K": k,
        "learners": f * k,
        "learners_per_vtu": f * k,
        "solve_s": round(solve_s, 4),
        "learners_per_s": round(f * k / max(solve_s, 1e-9), 1),
        "fleet_axes": list(axes),
    }


def main(*, quick: bool = True) -> None:
    mesh = host_mesh()
    n_dev = int(np.prod(list(mesh.shape.values())))
    print(f"# mesh: {dict(mesh.shape)} ({n_dev} devices, "
          f"backend={jax.default_backend()})")

    # full engine rounds: 512 learners, then the 10^4 acceptance point
    counts = (64, 1250) if quick else (64, 1250, 5000)
    rows = fleet_scale_sweep(
        counts, k=8, rounds=2 if quick else 3, participation=0.5, mesh=mesh,
    )
    for r in rows:
        print(f"train F={r['F']:>6} K={r['K']} learners={r['learners']:>6} "
              f"lpvtu={r['learners_per_vtu']:>6} wall={r['wall_s']:>7.3f}s "
              f"acc={r['final_accuracy']:.3f}")

    # dispatch tier alone at population scale: 10^6 learners in one solve
    solve_rows = [solve_only_row(125_000, 8, mesh=mesh)]
    for r in solve_rows:
        print(f"solve F={r['F']:>6} K={r['K']} learners={r['learners']:>7} "
              f"solve={r['solve_s']:.3f}s ({r['learners_per_s']:.0f} "
              f"learners/s)")

    _merge_out("fleet_scale", {
        "mesh_devices": n_dev,
        "mesh_axes": dict(mesh.shape),
        "train": rows,
        "solve": solve_rows,
    })


if __name__ == "__main__":
    main()
