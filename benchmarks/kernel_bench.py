"""Micro-benchmarks of the kernel hot spots (jnp reference path, CPU):
wall time per call for flash attention, WKV6, fed-agg, SwiGLU.

Prints CSV: name,us_per_call,derived
(the Pallas kernels target TPU; on this CPU container we time the jnp
reference and verify the Pallas interpret path agrees — the derived column
is achieved GFLOP/s of the reference.)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.key(0)

    b, s, h, kv, d = 2, 1024, 8, 4, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(key, (b, s, kv, d), jnp.float32)
    v = jax.random.normal(key, (b, s, kv, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True, chunk=256))
    us = _time(fa, q, k, v)
    flops = 4 * b * h * s * s * d / 2
    rows.append(("flash_attention_1k", us, f"{flops/us*1e-3:.1f}GFLOPs"))

    r_ = jax.random.normal(key, (b, 512, 4, 64), jnp.float32) * 0.5
    w_ = jax.nn.sigmoid(jax.random.normal(key, (b, 512, 4, 64))) * 0.5 + 0.45
    u_ = jax.random.normal(key, (4, 64)) * 0.1
    wkv = jax.jit(lambda r, k, v, w, u: ops.wkv6(r, k, v, w, u)[0])
    us = _time(wkv, r_, r_, r_, w_, u_)
    rows.append(("wkv6_512", us, f"state={4*64*64*4}B"))

    stacked = jax.random.normal(key, (10, 1_000_000), jnp.float32)
    wts = jax.nn.softmax(jax.random.normal(key, (10,)))
    agg = jax.jit(ops.fed_agg)
    us = _time(agg, stacked, wts)
    rows.append(("fed_agg_10x1M", us, f"{10*4e6/us*1e-3:.1f}GB/s"))

    x = jax.random.normal(key, (512, 512), jnp.float32)
    wg = jax.random.normal(key, (512, 2048)) * 0.02
    wd = jax.random.normal(key, (2048, 512)) * 0.02
    sg = jax.jit(lambda x: ops.swiglu_fused(x, wg, wg, wd))
    us = _time(sg, x)
    rows.append(("swiglu_512x2048", us, f"{3*2*512*512*2048/us*1e-3:.1f}GFLOPs"))
    return rows


def main(quick: bool = False):
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
