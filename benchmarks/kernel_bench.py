"""Micro-benchmarks of the kernel hot spots (jnp reference path, CPU):
wall time per call for flash attention, WKV6, fed-agg, SwiGLU.

Prints CSV: name,us_per_call,derived
(the Pallas kernels target TPU; on this CPU container we time the jnp
reference and verify the Pallas interpret path agrees — the derived column
is achieved GFLOP/s of the reference.)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.key(0)

    b, s, h, kv, d = 2, 1024, 8, 4, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(key, (b, s, kv, d), jnp.float32)
    v = jax.random.normal(key, (b, s, kv, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True, chunk=256))
    us = _time(fa, q, k, v)
    flops = 4 * b * h * s * s * d / 2
    rows.append(("flash_attention_1k", us, f"{flops/us*1e-3:.1f}GFLOPs"))

    r_ = jax.random.normal(key, (b, 512, 4, 64), jnp.float32) * 0.5
    w_ = jax.nn.sigmoid(jax.random.normal(key, (b, 512, 4, 64))) * 0.5 + 0.45
    u_ = jax.random.normal(key, (4, 64)) * 0.1
    wkv = jax.jit(lambda r, k, v, w, u: ops.wkv6(r, k, v, w, u)[0])
    us = _time(wkv, r_, r_, r_, w_, u_)
    rows.append(("wkv6_512", us, f"state={4*64*64*4}B"))

    stacked = jax.random.normal(key, (10, 1_000_000), jnp.float32)
    wts = jax.nn.softmax(jax.random.normal(key, (10,)))
    agg = jax.jit(ops.fed_agg)
    us = _time(agg, stacked, wts)
    rows.append(("fed_agg_10x1M", us, f"{10*4e6/us*1e-3:.1f}GB/s"))

    x = jax.random.normal(key, (512, 512), jnp.float32)
    wg = jax.random.normal(key, (512, 2048)) * 0.02
    wd = jax.random.normal(key, (2048, 512)) * 0.02
    sg = jax.jit(lambda x: ops.swiglu_fused(x, wg, wg, wd))
    us = _time(sg, x)
    rows.append(("swiglu_512x2048", us, f"{3*2*512*512*2048/us*1e-3:.1f}GFLOPs"))
    return rows


def main(quick: bool = False):
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()


# ---------------------------------------------------------------------------
# megakernel: the fused train+aggregate step (ops.train_agg_step)
# ---------------------------------------------------------------------------

def _megakernel_case(k: int, n: int, tau_hi: int, layers, seed: int):
    """f32 fixtures in the exact shapes the async scan feeds the kernel."""
    import numpy as np

    from repro.models import mlp

    rng = np.random.default_rng(seed)
    stack = [mlp.init(jax.random.key(int(s)), layers)
             for s in rng.integers(2**31, size=k)]
    disp = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *stack)
    x = jnp.asarray(rng.standard_normal((k, n, layers[0])), jnp.float32)
    y = jnp.asarray(rng.integers(0, layers[-1], (k, n)), jnp.int32)
    m = jnp.asarray(rng.integers(0, 2, (k, n)), jnp.float32)
    tau = jnp.asarray(rng.integers(1, tau_hi + 1, (k,)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, (k,)), jnp.float32)
    return disp, x, y, m, tau, w


def _megakernel_parity(layers=(16, 16, 4), seed=0) -> None:
    """Fixed-seed gate: the Pallas megakernel (interpret) must match the
    unfused local_train_stacked + fed_agg composition BITWISE before any
    timing row is merged. Raises on the first differing bit."""
    import numpy as np

    from repro.models import mlp

    disp, x, y, m, tau, w = _megakernel_case(4, 16, 3, list(layers), seed)
    lr = jnp.float32(0.05)
    want, _ = ops.train_agg_step(disp, x, y, m, tau, w, lr, loss_fn=mlp.loss,
                                 max_tau=int(tau.max()))
    got, _ = ops.train_agg_step(disp, x, y, m, tau, w, lr, loss_fn=mlp.loss,
                                use_pallas=True, interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError(
                "megakernel parity gate failed: fused != unfused bitwise"
            )


def megakernel_rows(quick: bool = True):
    """Per-step wall time, fused vs unfused. The fused row is timed only
    on a real accelerator backend — interpret mode is a correctness
    vehicle, not a performance path, and is EXCLUDED from timing."""
    from repro.models import mlp

    cases = [("mlp_paper_k4_n64_tau16", 4, 64, 16, mlp.PAPER_LAYERS)]
    if not quick:
        cases.append(("mlp_paper_k10_n128_tau16", 10, 128, 16,
                      mlp.PAPER_LAYERS))
    backend = jax.default_backend()
    lr = jnp.float32(0.05)
    rows = []
    for name, k, n, tau_hi, layers in cases:
        operands = _megakernel_case(k, n, tau_hi, layers, seed=0)
        max_tau = int(operands[4].max())

        unfused = jax.jit(lambda d_, x_, y_, m_, t_, w_: ops.train_agg_step(
            d_, x_, y_, m_, t_, w_, lr, loss_fn=mlp.loss, max_tau=max_tau)[0])
        rows.append({"case": name, "path": "unfused", "backend": backend,
                     "us_per_step": round(_time(unfused, *operands), 1)})

        if backend != "cpu":
            fused = jax.jit(lambda d_, x_, y_, m_, t_, w_: ops.train_agg_step(
                d_, x_, y_, m_, t_, w_, lr, loss_fn=mlp.loss,
                use_pallas=True)[0])
            rows.append({"case": name, "path": "pallas", "backend": backend,
                         "us_per_step": round(_time(fused, *operands), 1)})
        else:
            rows.append({"case": name, "path": "pallas", "backend": backend,
                         "us_per_step": None,
                         "note": "interpret-only on CPU; excluded from timing"})
    return rows


def megakernel_main(quick: bool = False):
    """`--only megakernel`: bitwise parity gate first, then the per-step
    fused-vs-unfused table merged under BENCH_alloc.json[megakernel]."""
    from benchmarks.alloc_bench import _merge_out

    _megakernel_parity()
    print("parity: fused == unfused bitwise on fixed seed", flush=True)
    rows = megakernel_rows(quick=quick)
    for r in rows:
        print(f"{r['case']},{r['path']},{r['us_per_step']}")
    _merge_out("megakernel", {"parity_bitwise": True, "rows": rows})
