"""Paper Fig. 3: validation accuracy vs global update cycles for
K in {10, 15, 20}, T = 15 s — proposed async optimized allocation vs the
synchronous scheme [9] vs asynchronous ETA [10].

Prints CSV: K,scheme,cycle,accuracy,max_staleness
"""

from __future__ import annotations

from repro.data.pipeline import synthetic_mnist
from repro.fed.simulation import run_experiment

# ETA runs plain FedAvg: ref [10]'s aggregation cannot rescue allocations
# whose staleness the allocator never controlled (see EXPERIMENTS.md §Fig3
# for the ablation with staleness-aware ETA as well)
SCHEMES = (("kkt_sai", "staleness"), ("sync", "fedavg"), ("eta", "fedavg"))


def run(ks=(10, 15, 20), cycles: int = 10, seed: int = 0, total_samples: int = 6000):
    train, test = synthetic_mnist(max(total_samples * 2, 12_000), seed=seed)
    out = []
    for k in ks:
        for scheme, agg in SCHEMES:
            res = run_experiment(
                k=k, T=15.0, cycles=cycles, scheme=scheme, aggregation=agg,
                total_samples=total_samples, seed=seed, train=train, test=test,
            )
            out.append(res)
    return out


def main(quick: bool = False):
    ks = (10,) if quick else (10, 15, 20)
    cycles = 4 if quick else 10
    print("K,scheme,cycle,accuracy,max_staleness")
    for res in run(ks=ks, cycles=cycles):
        for h in res["history"]:
            print(f"{res['K']},{res['scheme']},{h['cycle']},{h['accuracy']:.4f},{h['max_staleness']}")


if __name__ == "__main__":
    main()
