"""Event-driven async federation benchmark (``--only async``).

Two sections, merged into ``BENCH_alloc.json``:

  * ``modes`` — the paper's cycle-gated scheme vs FedAsync vs buffered
    aggregation at EQUAL virtual time under ``CapacityDrift`` (final
    accuracy, version-staleness profile, aggregation counts) on the
    MNIST-constants 802.11 fleet;
  * ``engine`` — wall-time of the eager per-event loop vs the TWO
    device-resident scan paths on the same schedule: the event-indexed
    (jagged) ``run_events`` (exact on every schedule, one scan step per
    flush group) and the legacy fixed-grid ``run_bucketed`` (needs a grid
    that resolves individual arrivals). Measured on a spread-period fleet
    (where the grid exists at all — near-tie fleets have no exact grid,
    see the ``jagged_only`` row) — the scan paths trade masked dense
    per-step compute for zero per-event host round-trips, so their CPU
    numbers are a lower bound on the accelerator win, like the fused
    orchestrator's.

  PYTHONPATH=src python -m benchmarks.run --only async
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.alloc_bench import _merge_out
from repro.core import CapacityDrift


def bench_modes(*, ks, T: float, cycles: int, total: int, seed: int = 0) -> list[dict]:
    from repro.fed.simulation import async_mode_sweep

    drift = CapacityDrift(clock_jitter=0.15, fading_sigma_db=2.5, seed=seed)
    rows = async_mode_sweep(
        ks, T, cycles=cycles, total_samples=total, drift=drift, seed=seed,
        reallocate=True,
    )
    for r in rows:
        r.pop("accuracy_trace", None)
    return rows


def bench_engine(*, horizon_cycles: int = 6, seed: int = 0) -> dict:
    """Eager event loop vs jagged (run_events) vs legacy grid
    (run_bucketed): same schedule, same aggregations on all three."""
    import jax
    import numpy as np

    from repro.data.pipeline import FederatedPartitioner, synthetic_mnist
    from repro.fed.async_engine import AsyncConfig, AsyncFedEngine
    from repro.fed.simulation import build_spread_problem
    from repro.models import mlp

    prob = build_spread_problem(k=4, total_samples=80)
    horizon = horizon_cycles * prob.T
    train, _ = synthetic_mnist(4000, n_test=10, seed=seed)
    cfg = AsyncConfig(mode="fedasync", alpha=0.6)
    params = mlp.init(jax.random.key(seed))

    def eager():
        eng = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
        return eng, eng.run(train, horizon)

    # smallest exact grid for the benchmarked grid path, read off a probe
    # engine's schedule (same seed -> same schedule; probes are discarded)
    probe = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
    part = FederatedPartitioner(train, seed=int(probe.rng.integers(2**31)))
    sched = probe._build_schedule(part, horizon, 100_000)
    ts = sorted(a.t for a in sched.arrivals if a.flush_id >= 0)
    gaps = [b - a for a, b in zip(ts, ts[1:])]
    nb = int(np.ceil(horizon / min(gaps))) + 1

    def bucketed():
        eng = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
        return eng, eng.run_bucketed(train, horizon, nb)

    def jagged():
        eng = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
        return eng, eng.run_events(train, horizon)

    _, h_warm = eager()       # compile + warmup all paths
    bucketed()
    _, h_j_warm = jagged()
    t0 = time.time()
    _, h_e = eager()
    eager_s = time.time() - t0
    t0 = time.time()
    _, h_b = bucketed()
    bucket_s = time.time() - t0
    t0 = time.time()
    _, h_j = jagged()
    jagged_s = time.time() - t0
    assert len(h_e) == len(h_b) == len(h_j) == len(h_warm)
    n = len(h_e)
    return {
        "K": prob.num_learners,
        "events": n,
        "num_buckets": nb,
        "num_segments": len(h_j),   # fedasync: one scan step per arrival
        "eager_s": round(eager_s, 3),
        "bucketed_s": round(bucket_s, 3),
        "jagged_s": round(jagged_s, 3),
        "eager_events_per_s": round(n / eager_s, 1),
        "bucketed_events_per_s": round(n / bucket_s, 1),
        "jagged_events_per_s": round(n / jagged_s, 1),
        "speedup_grid": round(eager_s / bucket_s, 2),
        "speedup_jagged": round(eager_s / jagged_s, 2),
    }


def bench_engine_near_tie(*, horizon_cycles: int = 4, seed: int = 0) -> dict:
    """The regime the grid cannot serve: a KKT near-tie fleet (capacity
    spread ~1e-7) where an exact uniform grid would need millions of
    buckets. Only the eager loop and the jagged scan can replay it —
    the ``jagged_only`` row records that plus their relative speed."""
    import numpy as np

    import jax

    from repro.core import AllocationProblem, TimeModel
    from repro.data.pipeline import synthetic_mnist
    from repro.fed.async_engine import AsyncConfig, AsyncFedEngine
    from repro.models import mlp

    eps = np.array([0.0, 1e-7, 2.3e-7, 3.1e-7])
    tm = TimeModel(c2=0.04 * (1 + eps), c1=np.full(4, 0.004),
                   c0=np.full(4, 0.4))
    prob = AllocationProblem(time_model=tm, T=6.0, total_samples=80,
                             d_lower=10, d_upper=40)
    horizon = horizon_cycles * prob.T
    train, _ = synthetic_mnist(4000, n_test=10, seed=seed)
    cfg = AsyncConfig(mode="fedasync", alpha=0.6)
    params = mlp.init(jax.random.key(seed))

    def eager():
        eng = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
        return eng.run(train, horizon)

    def jagged():
        eng = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
        return eng.run_events(train, horizon)

    eager()                   # compile + warmup
    jagged()
    t0 = time.time()
    h_e = eager()
    eager_s = time.time() - t0
    t0 = time.time()
    h_j = jagged()
    jagged_s = time.time() - t0
    assert len(h_e) == len(h_j)
    n = len(h_e)
    return {
        "K": prob.num_learners,
        "events": n,
        "grid": "none (near-tie schedule: exact grid exceeds the cap)",
        "eager_s": round(eager_s, 3),
        "jagged_s": round(jagged_s, 3),
        "eager_events_per_s": round(n / eager_s, 1),
        "jagged_events_per_s": round(n / jagged_s, 1),
        "speedup_jagged": round(eager_s / jagged_s, 2),
    }


def main(quick: bool = False) -> None:
    ks = [5] if quick else [5, 8]
    cycles = 3 if quick else 6
    total = 600 if quick else 1500

    rows = bench_modes(ks=ks, T=5.0, cycles=cycles, total=total)
    print("K,mode,final_acc,aggregations,stal_mean,stal_max")
    for r in rows:
        if "error" in r:
            print(f"{r['K']},{r['mode']},ERROR: {r['error']}")
            continue
        print(f"{r['K']},{r['mode']},{r['final_accuracy']:.3f},"
              f"{r['aggregations']},{r['staleness_mean']:.2f},"
              f"{r['staleness_max']}")

    eng = bench_engine(horizon_cycles=4 if quick else 8)
    print(f"engine eager {eng['eager_events_per_s']} ev/s vs grid "
          f"{eng['bucketed_events_per_s']} ev/s vs jagged "
          f"{eng['jagged_events_per_s']} ev/s over {eng['events']} events "
          f"(grid {eng['speedup_grid']}x H={eng['num_buckets']}, "
          f"jagged {eng['speedup_jagged']}x S={eng['num_segments']})")

    nt = bench_engine_near_tie(horizon_cycles=3 if quick else 4)
    print(f"near-tie fleet (no exact grid): eager "
          f"{nt['eager_events_per_s']} ev/s vs jagged "
          f"{nt['jagged_events_per_s']} ev/s over {nt['events']} events "
          f"({nt['speedup_jagged']}x)")

    _merge_out("async", {"modes": rows, "engine": eng, "jagged_only": nt})


if __name__ == "__main__":
    main()
