"""Event-driven async federation benchmark (``--only async``).

Two sections, merged into ``BENCH_alloc.json``:

  * ``modes`` — the paper's cycle-gated scheme vs FedAsync vs buffered
    aggregation at EQUAL virtual time under ``CapacityDrift`` (final
    accuracy, version-staleness profile, aggregation counts) on the
    MNIST-constants 802.11 fleet;
  * ``engine`` — wall-time of the eager per-event loop vs the bucketed
    ``lax.scan`` fast path on a spread-period fleet (the event schedule is
    identical; the bucketed path trades masked dense per-bucket compute for
    zero per-event host round-trips, so its CPU number is a lower bound on
    the accelerator win, like the fused orchestrator's).

  PYTHONPATH=src python -m benchmarks.run --only async
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.alloc_bench import _merge_out
from repro.core import CapacityDrift


def bench_modes(*, ks, T: float, cycles: int, total: int, seed: int = 0) -> list[dict]:
    from repro.fed.simulation import async_mode_sweep

    drift = CapacityDrift(clock_jitter=0.15, fading_sigma_db=2.5, seed=seed)
    rows = async_mode_sweep(
        ks, T, cycles=cycles, total_samples=total, drift=drift, seed=seed,
        reallocate=True,
    )
    for r in rows:
        r.pop("accuracy_trace", None)
    return rows


def bench_engine(*, horizon_cycles: int = 6, seed: int = 0) -> dict:
    """Eager event loop vs bucketed scan: same schedule, same aggregations."""
    import jax

    from repro.data.pipeline import synthetic_mnist
    from repro.fed.async_engine import AsyncConfig, AsyncFedEngine
    from repro.fed.simulation import build_spread_problem
    from repro.models import mlp

    prob = build_spread_problem(k=4, total_samples=80)
    horizon = horizon_cycles * prob.T
    train, _ = synthetic_mnist(4000, n_test=10, seed=seed)
    cfg = AsyncConfig(mode="fedasync", alpha=0.6)
    params = mlp.init(jax.random.key(seed))

    def eager():
        eng = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
        return eng, eng.run(train, horizon)

    probe = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
    nb = probe.suggest_num_buckets(train, horizon)

    def bucketed():
        eng = AsyncFedEngine(cfg, prob, mlp.loss, params, seed=seed)
        return eng, eng.run_bucketed(train, horizon, nb)

    _, h_warm = eager()       # compile + warmup both paths
    bucketed()
    t0 = time.time()
    _, h_e = eager()
    eager_s = time.time() - t0
    t0 = time.time()
    _, h_b = bucketed()
    bucket_s = time.time() - t0
    assert len(h_e) == len(h_b) == len(h_warm)
    n = len(h_e)
    return {
        "K": prob.num_learners,
        "events": n,
        "num_buckets": nb,
        "eager_s": round(eager_s, 3),
        "bucketed_s": round(bucket_s, 3),
        "eager_events_per_s": round(n / eager_s, 1),
        "bucketed_events_per_s": round(n / bucket_s, 1),
        "speedup": round(eager_s / bucket_s, 2),
    }


def main(quick: bool = False) -> None:
    ks = [5] if quick else [5, 8]
    cycles = 3 if quick else 6
    total = 600 if quick else 1500

    rows = bench_modes(ks=ks, T=5.0, cycles=cycles, total=total)
    print("K,mode,final_acc,aggregations,stal_mean,stal_max")
    for r in rows:
        if "error" in r:
            print(f"{r['K']},{r['mode']},ERROR: {r['error']}")
            continue
        print(f"{r['K']},{r['mode']},{r['final_accuracy']:.3f},"
              f"{r['aggregations']},{r['staleness_mean']:.2f},"
              f"{r['staleness_max']}")

    eng = bench_engine(horizon_cycles=4 if quick else 8)
    print(f"engine eager {eng['eager_events_per_s']} ev/s vs bucketed "
          f"{eng['bucketed_events_per_s']} ev/s over {eng['events']} events "
          f"({eng['speedup']}x, H={eng['num_buckets']})")

    _merge_out("async", {"modes": rows, "engine": eng})


if __name__ == "__main__":
    main()
