"""Solver comparison table (paper Sec. IV-V: the analytical SAI solution vs
numerical solvers on the relaxed QCLP): objective value, relaxed-solution
agreement, wall time.

Prints CSV: K,T,solver,max_staleness,avg_staleness,relaxed_gap,wall_ms
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import solve_kkt_sai, solve_pgd_jax, solve_slsqp
from repro.fed.simulation import build_problem

SOLVERS = {"kkt_sai": solve_kkt_sai, "slsqp": solve_slsqp, "pgd_jax": solve_pgd_jax}


def run(ks=(5, 10, 20), ts=(7.5, 15.0), seed: int = 0):
    rows = []
    for t in ts:
        for k in ks:
            prob = build_problem(k, t, seed=seed)
            ref = None
            for name, solver in SOLVERS.items():
                t0 = time.time()
                try:
                    alloc = solver(prob)
                except ValueError as e:
                    rows.append({"K": k, "T": t, "solver": name, "error": str(e)})
                    continue
                wall = (time.time() - t0) * 1e3
                if ref is None:
                    ref = alloc.relaxed_d
                gap = float(np.max(np.abs(alloc.relaxed_d - ref))) if alloc.relaxed_d is not None else float("nan")
                s = alloc.summary(prob)
                rows.append({
                    "K": k, "T": t, "solver": name,
                    "max_staleness": s["max_staleness"],
                    "avg_staleness": s["avg_staleness"],
                    "relaxed_gap": gap,
                    "wall_ms": wall,
                })
    return rows


def main(quick: bool = False):
    ks = (5, 10) if quick else (5, 10, 20)
    print("K,T,solver,max_staleness,avg_staleness,relaxed_gap,wall_ms")
    for r in run(ks=ks):
        if "error" in r:
            print(f"{r['K']},{r['T']},{r['solver']},inf,inf,nan,nan")
        else:
            print(
                f"{r['K']},{r['T']},{r['solver']},{r['max_staleness']},"
                f"{r['avg_staleness']:.3f},{r['relaxed_gap']:.2f},{r['wall_ms']:.1f}"
            )


if __name__ == "__main__":
    main()
