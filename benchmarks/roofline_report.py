"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json and prints CSV:
arch,shape,mesh,rules,dominant,compute_s,memory_s,collective_s,
model_flops_ratio,bytes_per_device,collective_bytes
"""

from __future__ import annotations

import json
import pathlib


def load(art_dir="artifacts/dryrun"):
    recs = []
    for p in sorted(pathlib.Path(art_dir).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def main(quick: bool = False, art_dir="artifacts/dryrun"):
    recs = load(art_dir)
    print(
        "arch,shape,mesh,rules,dominant,compute_s,memory_s,collective_s,"
        "useful_flops_ratio,bytes_per_device,collective_bytes"
    )
    for r in recs:
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        print(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['rules']},{t['dominant']},"
            f"{t['compute_s']:.4e},{t['memory_s']:.4e},{t['collective_s']:.4e},"
            f"{(ratio if ratio is not None else float('nan')):.3f},"
            f"{r['bytes_per_device']:.3e},{t['collective_bytes']:.3e}"
        )
    if not recs:
        print("# no artifacts found - run: python -m repro.launch.dryrun --all")


if __name__ == "__main__":
    main()
