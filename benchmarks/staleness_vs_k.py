"""Paper Fig. 2: maximum and average staleness vs K for T in {7.5, 15} s,
for the optimized asynchronous scheme (numerical solver and SAI) vs ETA.

Prints CSV: T,K,scheme,max_staleness,avg_staleness,total_updates
"""

from __future__ import annotations

from repro.fed.simulation import staleness_sweep


def run(ks=(4, 6, 8, 10, 12, 14, 16, 18, 20), ts=(7.5, 15.0), seed: int = 0,
        total_samples: int = 60_000):
    """total_samples defaults to the paper's full MNIST d = 60,000."""
    rows = []
    for t in ts:
        rows += staleness_sweep(
            list(ks), t, schemes=("kkt_sai", "slsqp", "eta"), seed=seed,
            total_samples=total_samples,
        )
    return rows


def main(quick: bool = False):
    ks = (5, 10, 20) if quick else (4, 6, 8, 10, 12, 14, 16, 18, 20)
    print("T,K,scheme,max_staleness,avg_staleness,total_updates")
    for r in run(ks=ks):
        if "error" in r:
            print(f"{r['T']},{r['K']},{r['scheme']},inf,inf,0")
        else:
            print(
                f"{r['T']},{r['K']},{r['scheme']},{r['max_staleness']},"
                f"{r['avg_staleness']:.3f},{r['total_updates']}"
            )


if __name__ == "__main__":
    main()
