"""Benchmark entrypoint: one section per paper table/figure + system extras.

  PYTHONPATH=src python -m benchmarks.run [--full]

Sections:
  fig2      staleness vs K (paper Fig. 2)
  fig3      accuracy vs global cycles (paper Fig. 3)
  solvers   analytic SAI vs numerical solvers (Sec. IV/V)
  alloc     batched allocation engine vs per-problem Python loop (BENCH_alloc.json)
  realloc   per-cycle reallocation under drift: batched re-solves + the
            in-scan reallocating orchestrator (merges into BENCH_alloc.json)
  async     event-driven async federation: cycle-gated vs FedAsync vs
            buffered under drift + eager-vs-bucketed engine wall-time
            (merges into BENCH_alloc.json)
  churn     adaptive KKT vs static/equal allocation under client churn +
            fault injection at rising dropout rates (merges into
            BENCH_alloc.json)
  fleet     fleet-of-fleets scale: FleetEngine rounds at 10^4 learners +
            the sharded dispatch solve at 10^6 learners (merges into
            BENCH_alloc.json)
  kernels   hot-spot micro-benchmarks
  roofline  per (arch x shape x mesh) roofline terms from dry-run artifacts
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    accuracy_vs_cycles,
    alloc_bench,
    async_bench,
    churn_bench,
    fleet_scale,
    kernel_bench,
    roofline_report,
    solver_table,
    staleness_vs_k,
)

SECTIONS = [
    ("fig2_staleness_vs_k", staleness_vs_k.main),
    ("solver_table", solver_table.main),
    ("alloc_bench", alloc_bench.main),
    ("realloc_bench", alloc_bench.realloc_main),
    ("async_bench", async_bench.main),
    ("churn_bench", churn_bench.main),
    ("fleet_scale", fleet_scale.main),
    ("kernel_bench", kernel_bench.main),
    ("roofline_report", roofline_report.main),
    ("fig3_accuracy_vs_cycles", accuracy_vs_cycles.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    for name, fn in SECTIONS:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        fn(quick=quick)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
