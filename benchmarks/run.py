"""Benchmark entrypoint: one section per paper table/figure + system extras.

  PYTHONPATH=src python -m benchmarks.run [--full]

Sections:
  fig2      staleness vs K (paper Fig. 2)
  fig3      accuracy vs global cycles (paper Fig. 3)
  solvers   analytic SAI vs numerical solvers (Sec. IV/V)
  alloc     batched allocation engine vs per-problem Python loop (BENCH_alloc.json)
  realloc   per-cycle reallocation under drift: batched re-solves + the
            in-scan reallocating orchestrator (merges into BENCH_alloc.json)
  async     event-driven async federation: cycle-gated vs FedAsync vs
            buffered under drift + eager-vs-bucketed engine wall-time
            (merges into BENCH_alloc.json)
  churn     adaptive KKT vs static/equal allocation under client churn +
            fault injection at rising dropout rates (merges into
            BENCH_alloc.json)
  energy    accuracy-vs-energy frontier: budgeted kkt_energy vs the
            energy-blind schemes across battery budgets (merges into
            BENCH_alloc.json)
  multimodel multi-tenant scheduler: deficit-driven cross-model allocation
            vs the equal split on the laggard's time-to-accuracy (merges
            into BENCH_alloc.json)
  fleet     fleet-of-fleets scale: FleetEngine rounds at 10^4 learners +
            the sharded dispatch solve at 10^6 learners (merges into
            BENCH_alloc.json)
  kernels   hot-spot micro-benchmarks
  roofline  per (arch x shape x mesh) roofline terms from dry-run artifacts
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    accuracy_vs_cycles,
    alloc_bench,
    async_bench,
    churn_bench,
    energy_bench,
    fleet_scale,
    kernel_bench,
    multimodel_bench,
    roofline_report,
    solver_table,
    staleness_vs_k,
)

SECTIONS = [
    ("fig2_staleness_vs_k", staleness_vs_k.main),
    ("solver_table", solver_table.main),
    ("alloc_bench", alloc_bench.main),
    ("realloc_bench", alloc_bench.realloc_main),
    ("async_bench", async_bench.main),
    ("churn_bench", churn_bench.main),
    ("energy_bench", energy_bench.main),
    ("multimodel_bench", multimodel_bench.main),
    ("fleet_scale", fleet_scale.main),
    ("kernel_bench", kernel_bench.main),
    ("megakernel_bench", kernel_bench.megakernel_main),
    ("roofline_report", roofline_report.main),
    ("fig3_accuracy_vs_cycles", accuracy_vs_cycles.main),
]


def _count_rows(payload) -> int:
    """Row count of one merged section: list payloads count directly,
    dict payloads count their largest list value (sweep rows)."""
    if isinstance(payload, list):
        return len(payload)
    if isinstance(payload, dict):
        return max(
            (_count_rows(v) for v in payload.values() if isinstance(v, (list, dict))),
            default=1,
        )
    return 1


def _section_summary(before: dict) -> str | None:
    """One line per section the last bench merged into BENCH_alloc.json:
    rows, producing device, written_at — compared against the file state
    BEFORE the bench ran, so only freshly (re)written sections print."""
    import json

    if not alloc_bench.OUT_PATH.exists():
        return None
    data = json.loads(alloc_bench.OUT_PATH.read_text())
    lines = []
    for name, sec in data.items():
        if name == "bench" or sec == before.get(name):
            continue
        if not (isinstance(sec, dict) and "data" in sec):
            continue
        lines.append(
            f"# {name}: {_count_rows(sec['data'])} rows, "
            f"device={sec.get('device')}, written_at={sec.get('written_at')}"
        )
    return "\n".join(lines) if lines else None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    import json

    for name, fn in SECTIONS:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        before = (json.loads(alloc_bench.OUT_PATH.read_text())
                  if alloc_bench.OUT_PATH.exists() else {})
        t0 = time.time()
        fn(quick=quick)
        summary = _section_summary(before)
        if summary:
            print(summary, flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
