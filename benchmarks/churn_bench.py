"""Churn-robustness benchmark: adaptive KKT vs static/equal allocation as
client dropout and upload faults rise.

Runs ``fed.simulation.churn_sweep`` — Markov on/off availability plus a
compound fault schedule (dropped/delayed uploads, stragglers,
deadline-retry redispatch, quorum-degraded buffered flushes) — through
the exact event-indexed scan path at >= 3 dropout rates, and merges the
rows into ``BENCH_alloc.json`` under the ``churn`` section.

  PYTHONPATH=src python -m benchmarks.run --only churn
"""

from __future__ import annotations

import time

from benchmarks.alloc_bench import _merge_out
from repro.fed.simulation import build_spread_problem, churn_sweep


def main(quick: bool = False) -> None:
    drop_rates = (0.0, 0.2, 0.4) if quick else (0.0, 0.1, 0.2, 0.3, 0.4)
    cycles = 10 if quick else 16
    prob = build_spread_problem(k=4, total_samples=80)
    t0 = time.time()
    rows = churn_sweep(drop_rates, cycles=cycles, problem=prob, seed=0)
    elapsed = time.time() - t0
    for r in rows:
        f = r["faults"]
        print(
            f"  rate={r['drop_rate']:.1f} {r['policy']:<8} "
            f"acc={r['final_accuracy'] if r['final_accuracy'] is None else round(r['final_accuracy'], 4)} "
            f"aggs={r['aggregations']:>3} stale(mean/p90/max)="
            f"{r['staleness_mean']:.2f}/{r['staleness_p90']:.1f}/{r['staleness_max']} "
            f"drops={f['drops']} retries={f['retries']} "
            f"degraded={f['quorum_degradations']}"
        )
    _merge_out("churn", {
        "mode": "buffered",
        "cycles": cycles,
        "drop_rates": list(drop_rates),
        "sweep": rows,
        "elapsed_s": round(elapsed, 2),
    })


if __name__ == "__main__":
    main()
