"""Multi-tenant scheduler benchmark: deficit-driven cross-model
allocation vs the equal split (``--only multimodel``).

Runs ``fed.simulation.multi_model_sweep`` — S tenant models time-sharing
one fleet through ``fed.multimodel.MultiModelEngine``, the heavy LAGGARD
tenant carrying 3x the per-round samples — under both split policies at
equal virtual time, and merges the per-model accuracy traces plus the
laggard time-to-accuracy comparison into ``BENCH_alloc.json`` under the
``multimodel`` section.

The headline number is the laggard's time-to-accuracy: the deficit split
must reach the common target no later than the equal split (FedAST-style
behind-ness steering each learner's time budget toward the tenant that
trails in server versions). Full mode enforces that invariant; quick/CI
mode records the rows without the assertion (short horizons make the
crossing noisy).

  PYTHONPATH=src python -m benchmarks.run --only multimodel
"""

from __future__ import annotations

import time

from benchmarks.alloc_bench import _merge_out
from repro.fed.simulation import laggard_time_to_accuracy, multi_model_sweep


def main(quick: bool = False) -> None:
    totals = (120, 120, 360) if quick else (200, 200, 600)
    cycles = 5 if quick else 10
    t0 = time.time()
    rows = multi_model_sweep(
        totals, k=4, T=8.0, cycles=cycles, seed=0,
        splits=("deficit", "equal"),
    )
    elapsed = time.time() - t0
    tta, target = laggard_time_to_accuracy(rows)
    for r in rows:
        print(
            f"  split={r['split']:<8} versions={r['versions']} "
            f"acc={r['final_accuracy']} "
            f"laggard_tta@{round(target, 3)}={tta[r['split']]}"
        )
    if not quick:
        t_def, t_eq = tta.get("deficit"), tta.get("equal")
        if t_def is None or (t_eq is not None and t_def > t_eq):
            raise AssertionError(
                "the deficit split must reach the laggard accuracy target "
                f"no later than the equal split: deficit={t_def}, "
                f"equal={t_eq} (target={target})"
            )
    _merge_out("multimodel", {
        "S": rows[0]["S"],
        "cycles": cycles,
        "totals": list(totals),
        "laggard_tta_target": round(target, 4),
        "laggard_tta": tta,
        "sweep": rows,
        "elapsed_s": round(elapsed, 2),
    })


if __name__ == "__main__":
    main()
