"""Training launcher.

Runs real steps on the available devices (CPU-sized configs) or, with
``--dryrun``, only lowers+compiles for the production mesh. For the
federated MEL path use ``examples/train_mnist_fed.py`` — this launcher is
the *dense-pod* trainer the allocator schedules across pods.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 20 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.compat import set_mesh
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import token_batches
from repro.launch.mesh import make_mesh_by_name
from repro.launch.steps import build_train
from repro.models.model import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="cpu")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    mesh = make_mesh_by_name(args.mesh)

    step, (pshard, oshard, batch_sh), out_sh, _ = build_train(model, mesh)
    from repro.optim.optimizers import get_optimizer

    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    params = model.init(jax.random.key(args.seed))
    opt_state = opt.init(params)

    rng = np.random.default_rng(args.seed)
    gen = token_batches(rng, args.batch, args.seq + 1, cfg.vocab_size)

    def with_extras(b):
        if cfg.family == "vlm":
            b = dict(b)
            b["tokens"] = b["tokens"][:, : args.seq - cfg.num_image_tokens]
            b["labels"] = b["labels"][:, : args.seq - cfg.num_image_tokens]
            b["image_embeds"] = rng.standard_normal(
                (args.batch, cfg.num_image_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        if cfg.family == "audio":
            b = dict(b)
            b["encoder_embeds"] = rng.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32) * 0.02
        return b

    jitted = jax.jit(step)
    with set_mesh(mesh):
        for i in range(args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in with_extras(next(gen)).items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if i % args.log_every == 0:
                print(
                    f"step {i:4d} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} {time.time()-t0:.2f}s",
                    flush=True,
                )
    if args.save:
        ckpt.save(args.save, params, step=args.steps)
        print(f"saved params -> {args.save}")


if __name__ == "__main__":
    main()
