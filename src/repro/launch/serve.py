"""Serving launcher: batched prefill + decode loop on real devices.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.compat import set_mesh
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.launch.mesh import make_mesh_by_name
from repro.models.model import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="cpu")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    mesh = make_mesh_by_name(args.mesh)
    params = model.init(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    extra = 0
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_image_tokens, cfg.d_model)), jnp.float32
        ) * 0.02
        extra = cfg.num_image_tokens
    if cfg.family == "audio":
        batch["encoder_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.float32
        ) * 0.02

    max_len = s + extra + args.gen
    prefill = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=max_len))
    decode = jax.jit(model.decode)

    with set_mesh(mesh):
        t0 = time.time()
        logits, cache, _aux = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        print(f"prefill({b}x{s}) {time.time()-t0:.2f}s")
        out_tokens = [tok]
        cache_len = jnp.asarray(s + extra, jnp.int32)
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, cache = decode(params, cache, tok, cache_len + i)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
        dt = time.time() - t0
        toks = jnp.concatenate(out_tokens, axis=1)
        print(f"decoded {args.gen-1} steps in {dt:.2f}s "
              f"({(args.gen-1)*b/max(dt,1e-9):.1f} tok/s)")
        print("sample:", np.asarray(toks[0])[:16].tolist())


if __name__ == "__main__":
    main()
