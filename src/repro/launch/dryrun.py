import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, SPMD-partitions, and compiles on the production mesh —
and extract the roofline terms from the compiled artifact.

The two lines above MUST run before any jax import (jax locks the device
count at first init); the env override exists so the test-suite subprocess
can request 8 fake devices instead of 512.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>[__<rules>].json and
feed EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse
import json
import pathlib
import time

import jax

from repro.compat import cost_analysis_dict, set_mesh

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.launch.mesh import make_mesh_by_name
from repro.launch.steps import build_decode, build_prefill, build_train
from repro.models.model import Model
from repro.roofline.analysis import HW, model_flops_per_step, roofline_terms
from repro.roofline.hlo_cost import analyze_hlo
from repro.sharding.rules import EXPERT_PARALLEL_RULES, SERVE_RULES, TRAIN_RULES

RULE_SETS = {
    "train": TRAIN_RULES,
    "serve": SERVE_RULES,
    "expert_parallel": EXPERT_PARALLEL_RULES,
}


def should_skip(arch: str, shape_name: str) -> str | None:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return (
            "full-attention architecture: 500k-token decode is outside the "
            "published family's attention form (see DESIGN.md §5)"
        )
    return None


def run_one(arch: str, shape_name: str, mesh_name: str, rules_name: str | None = None,
            out_dir: str = "artifacts/dryrun", verbose: bool = True,
            overrides: dict | None = None, tag: str = "") -> dict:
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(v) if cur is not None else v
        cfg = dataclasses.replace(cfg, **typed)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_mesh_by_name(mesh_name)
    model = Model(cfg)
    rules_name = rules_name or ("train" if shape.kind == "train" else "serve")
    rules = RULE_SETS[rules_name]

    t0 = time.time()
    if shape.kind == "train":
        step, (pshard, oshard, batch_sh), out_sh, (aparams, aopt) = build_train(model, mesh, rules)
        specs = model.input_specs(shape)
        bshard = batch_sh(specs)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard), out_shardings=out_sh)
        with set_mesh(mesh):
            lowered = jitted.lower(aparams, aopt, specs)
    elif shape.kind == "prefill":
        step, (pshard, batch_sh), aparams = build_prefill(model, mesh, shape, rules)
        specs = model.input_specs(shape)
        bshard = batch_sh(specs)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        with set_mesh(mesh):
            lowered = jitted.lower(aparams, specs)
    else:
        step, (pshard, cshard, tshard, lshard), (aparams, acache) = build_decode(model, mesh, shape, rules)
        specs = model.input_specs(shape)
        jitted = jax.jit(step, in_shardings=(pshard, cshard, tshard, lshard))
        with set_mesh(mesh):
            lowered = jitted.lower(aparams, specs["cache"], specs["token"], specs["cache_len"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # ---- analysis -------------------------------------------------------
    # HloCostAnalysis counts while bodies once; keep it for reference but use
    # the loop-aware analyzer (repro.roofline.hlo_cost) for the roofline.
    cost = cost_analysis_dict(compiled)
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover - backend specific
        mem["error"] = str(e)

    hlo = compiled.as_text()
    loop_aware = analyze_hlo(hlo)
    flops = loop_aware.flops
    bytes_accessed = loop_aware.bytes
    colls = loop_aware.collectives
    n_chips = mesh.devices.size
    terms = roofline_terms(flops, bytes_accessed, colls)
    mf = model_flops_per_step(cfg, shape, n_chips)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mesh_shape": dict(mesh.shape),
        "rules": rules_name,
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collectives": colls,
        "xla_cost_analysis": {"flops": xla_flops, "bytes_accessed": xla_bytes},
        "memory": mem,
        "roofline": terms,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": (mf / flops) if flops else None,
        "hlo_lines": hlo.count("\n"),
        "params_total": cfg.param_counts()[0],
        "params_active": cfg.param_counts()[1],
        "overrides": overrides or {},
        "tag": tag,
    }

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    suffix = f"__{rules_name}" if rules_name not in ("train", "serve") else ""
    if tag:
        suffix += f"__{tag}"
    path = out / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    path.write_text(json.dumps(record, indent=1))

    if verbose:
        r = terms
        print(
            f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:9s} {rules_name:15s} "
            f"compile={t_compile:6.1f}s flops/dev={flops:.3e} bytes/dev={bytes_accessed:.3e} "
            f"coll={r['collective_bytes']:.3e}B dom={r['dominant']:10s} "
            f"comp={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms",
            flush=True,
        )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both", "test", "multitest"])
    ap.add_argument("--rules", default=None, choices=[None, *RULE_SETS], nargs="?")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    help="config override key=value (repeatable), e.g. --set wkv_unroll=16")
    ap.add_argument("--tag", default="", help="artifact suffix for variant runs")
    args = ap.parse_args()
    overrides = dict(s.split("=", 1) for s in args.sets)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_NAMES if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]

    failures = []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                why = should_skip(arch, shape_name)
                if why:
                    print(f"[dryrun] {arch:18s} {shape_name:12s} SKIP: {why}", flush=True)
                    continue
                try:
                    run_one(arch, shape_name, mesh_name, args.rules, args.out,
                            overrides=overrides, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, mesh_name, repr(e)))
                    print(f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:9s} FAIL {e!r}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("[dryrun] all requested combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
