"""Mesh construction for the production pod(s), tests, and host-CPU runs.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run entrypoint sets XLA_FLAGS before importing jax; nothing
else in the codebase ever asks for more devices than exist.

Host-CPU fake-device path: XLA can split the host CPU into N fake devices
with ``--xla_force_host_platform_device_count=N`` (must be in XLA_FLAGS
*before* jax initializes — i.e. set in the environment of a fresh process,
as the fleet-scale CI step and the sharding subprocess tests do). With 8
fake devices the ``"test"`` spec is a real (2, 4) data×model mesh and
``shard_map`` partitioning is exercised for real; ``host_mesh()`` picks the
largest spec the current process can serve so the same code runs 1-device
eager CI and 8-device sharded CI unchanged.
"""

from __future__ import annotations

import jax

from repro import compat

__all__ = [
    "make_production_mesh",
    "make_mesh_by_name",
    "MESH_SPECS",
    "device_count_for",
    "host_mesh",
    "host_device_flags",
]

#: the XLA flag that splits the host CPU into fake devices (set it in
#: XLA_FLAGS before jax import; see module docstring)
XLA_HOST_DEVICES_FLAG = "--xla_force_host_platform_device_count"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


# name -> (shape, axes); "test" variants run inside CI with 8/16 fake devices
MESH_SPECS = {
    "pod": ((16, 16), ("data", "model")),
    "multipod": ((2, 16, 16), ("pod", "data", "model")),
    "test": ((2, 4), ("data", "model")),
    "multitest": ((2, 2, 4), ("pod", "data", "model")),
    "cpu": ((1, 1), ("data", "model")),
}


def device_count_for(name: str) -> int:
    shape, _ = MESH_SPECS[name]
    n = 1
    for s in shape:
        n *= s
    return n


def make_mesh_by_name(name: str):
    shape, axes = MESH_SPECS[name]
    return compat.make_mesh(shape, axes)


def host_device_flags(n: int = 8) -> str:
    """The XLA_FLAGS value that gives a fresh process ``n`` fake host-CPU
    devices (append to any existing flags, space-separated)."""
    return f"{XLA_HOST_DEVICES_FLAG}={n}"


def host_mesh(prefer: str = "test"):
    """The largest named mesh this process can actually build: ``prefer``
    (default ``"test"``, 8 devices) when enough devices exist — real ones
    or fake host-CPU devices forced via ``host_device_flags`` — else the
    1-device ``"cpu"`` spec. THE mesh entry point for the fleet engine and
    its CI step: the same call is a genuine (2, 4) ``shard_map`` partition
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and a
    trivial 1-device mesh everywhere else."""
    name = prefer if len(jax.devices()) >= device_count_for(prefer) else "cpu"
    return make_mesh_by_name(name)
