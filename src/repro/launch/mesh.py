"""Mesh construction for the production pod(s) and for tests.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run entrypoint sets XLA_FLAGS before importing jax; nothing
else in the codebase ever asks for more devices than exist.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_by_name", "MESH_SPECS", "device_count_for"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# name -> (shape, axes); "test" variants run inside CI with 8/16 fake devices
MESH_SPECS = {
    "pod": ((16, 16), ("data", "model")),
    "multipod": ((2, 16, 16), ("pod", "data", "model")),
    "test": ((2, 4), ("data", "model")),
    "multitest": ((2, 2, 4), ("pod", "data", "model")),
    "cpu": ((1, 1), ("data", "model")),
}


def device_count_for(name: str) -> int:
    shape, _ = MESH_SPECS[name]
    n = 1
    for s in shape:
        n *= s
    return n


def make_mesh_by_name(name: str):
    shape, axes = MESH_SPECS[name]
    return jax.make_mesh(shape, axes)
