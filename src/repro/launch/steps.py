"""Step functions (train / prefill / decode) + their sharding trees.

Shared by the real launcher (``train.py`` / ``serve.py``) and the multi-pod
dry-run (``dryrun.py``).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.models.model import Model
from repro.optim.optimizers import clip_by_global_norm, get_optimizer
from repro.sharding.rules import (
    SERVE_RULES,
    TRAIN_RULES,
    input_shardings,
    resolve_spec,
    tree_shardings,
)

__all__ = ["opt_state_axes", "build_train", "build_prefill", "build_decode"]


def opt_state_axes(opt_name: str, param_axes):
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return param_axes
    return {"m": param_axes, "v": param_axes, "t": ()}


def _replicated(mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def build_train(model: Model, mesh, rules=None, *, grad_clip: float = 1.0):
    """Returns (step_fn, in_shardings, out_shardings, abstract_inputs_fn)."""
    cfg = model.cfg
    rules = rules or TRAIN_RULES
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, gn = clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gn}

    aparams = model.abstract_params()
    aopt = jax.eval_shape(opt.init, aparams)
    pshard = tree_shardings(model.param_axes(), aparams, mesh, rules)
    oshard = tree_shardings(opt_state_axes(cfg.optimizer, model.param_axes()), aopt, mesh, rules)

    def batch_shardings(input_specs):
        return input_shardings(input_specs, mesh, rules)

    metrics_shard = {"loss": NamedSharding(mesh, P()), "grad_norm": NamedSharding(mesh, P())}
    return step, (pshard, oshard, batch_shardings), (pshard, oshard, metrics_shard), (aparams, aopt)


def build_prefill(model: Model, mesh, shape: InputShape, rules=None):
    cfg = model.cfg
    rules = rules or SERVE_RULES

    def step(params, batch):
        return model.prefill(params, batch, max_len=shape.seq_len)

    aparams = model.abstract_params()
    pshard = tree_shardings(model.param_axes(), aparams, mesh, rules)

    def batch_shardings(input_specs):
        return input_shardings(input_specs, mesh, rules)

    return step, (pshard, batch_shardings), aparams


def build_decode(model: Model, mesh, shape: InputShape, rules=None):
    cfg = model.cfg
    rules = rules or SERVE_RULES

    def step(params, cache, token, cache_len):
        return model.decode(params, cache, token, cache_len)

    aparams = model.abstract_params()
    pshard = tree_shardings(model.param_axes(), aparams, mesh, rules)
    b = shape.global_batch
    cache_axes = model.cache_axes(b, shape.seq_len)
    acache = model.abstract_cache(b, shape.seq_len)
    cshard = tree_shardings(cache_axes, acache, mesh, rules)
    tshard = NamedSharding(mesh, resolve_spec(("batch", None), (b, 1), mesh, rules))
    lshard = NamedSharding(mesh, P())
    return step, (pshard, cshard, tshard, lshard), (aparams, acache)
