"""Data pipeline: synthetic datasets + per-learner partitioning.

The container is offline, so MNIST itself is synthesized: a mixture of
class-conditional Gaussians over 784 features with class-dependent means
structured like low-frequency image patterns. It is linearly non-separable
enough that the paper's [784,300,124,60,10] DNN shows a genuine learning
curve, which is all Figs. 2-3 need (the paper's claims are about *relative*
convergence of allocation schemes, not absolute MNIST accuracy).

``FederatedPartitioner`` slices a dataset into per-learner shards of the
allocator's d_k sizes each global cycle (task-parallelization scenario:
the orchestrator re-samples the batches it ships every cycle).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Dataset", "synthetic_mnist", "token_batches", "FederatedPartitioner"]


@dataclasses.dataclass(frozen=True)
class Dataset:
    x: np.ndarray          # (N, F) float32
    y: np.ndarray          # (N,)   int32

    @property
    def size(self) -> int:
        return int(self.x.shape[0])

    def subset(self, idx: np.ndarray) -> "Dataset":
        return Dataset(self.x[idx], self.y[idx])


def synthetic_mnist(
    n: int = 60_000,
    *,
    n_test: int = 10_000,
    features: int = 784,
    classes: int = 10,
    seed: int = 0,
    noise: float = 2.5,
) -> tuple[Dataset, Dataset]:
    """Class-structured Gaussian mixture that mimics MNIST's shape/scale."""
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(features))
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float32) / side
    means = []
    for c in range(classes):
        fx, fy = 1 + c % 3, 1 + (c // 3) % 3
        phase = c * 0.7
        img = np.sin(2 * np.pi * fx * xx + phase) * np.cos(2 * np.pi * fy * yy + 0.3 * c)
        img += 0.5 * np.sin(2 * np.pi * (xx + yy) * (1 + 0.5 * c))
        means.append(img.reshape(-1))
    means = np.stack(means)                         # (C, F)

    def make(count, seed_off):
        r = np.random.default_rng(seed + seed_off)
        y = r.integers(0, classes, size=count).astype(np.int32)
        x = means[y] + noise * r.standard_normal((count, features)).astype(np.float32)
        return Dataset(x.astype(np.float32), y)

    return make(n, 1), make(n_test, 2)


def token_batches(rng: np.random.Generator, batch: int, seq: int, vocab: int):
    """Endless synthetic LM batches with a learnable bigram structure."""
    perm = rng.permutation(vocab)
    while True:
        first = rng.integers(0, vocab, size=(batch, 1))
        toks = [first]
        for _ in range(seq - 1):
            prev = toks[-1]
            nxt = np.where(
                rng.random((batch, 1)) < 0.7, perm[prev] % vocab,
                rng.integers(0, vocab, size=(batch, 1)),
            )
            toks.append(nxt)
        tokens = np.concatenate(toks, axis=1).astype(np.int32)
        yield {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class FederatedPartitioner:
    """Re-samples per-learner batches of the allocated sizes each cycle."""

    def __init__(self, dataset: Dataset, seed: int = 0):
        self.dataset = dataset
        self.seed = int(seed)
        self.draws = 0   # index of the next draw (the fold-in key)

    def draw_indices(self, total: int) -> np.ndarray:
        """One cycle's sample indices (total,).

        Every call is keyed by the explicit fold-in pair ``(seed, draw
        index)`` — a fresh generator per draw, no state carried between
        calls — so draw ``i`` depends only on ``(seed, i, total)``: not on
        the sizes of earlier draws, not on iteration order elsewhere, not
        on any global PRNG, and not on the process running it. Any split
        of the same total (``draw``) and a flat pre-staged draw (the fused
        reallocation path, which splits by traced d inside the scan) see
        identical samples, and the draw sequence is bit-stable across
        processes (``SeedSequence`` hashing is part of numpy's spec)."""
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, self.draws))
        )
        self.draws += 1
        return rng.choice(self.dataset.size, size=int(total), replace=False)

    def draw(self, d: np.ndarray) -> list[Dataset]:
        """d: (K,) integer batch sizes, sum <= dataset size. Disjoint shards."""
        idx = self.draw_indices(int(np.sum(d)))
        out, off = [], 0
        for dk in d:
            out.append(self.dataset.subset(idx[off : off + int(dk)]))
            off += int(dk)
        return out
