"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv2 frontend is the allowed STUB: ``input_specs``
feeds precomputed frame embeddings (B, encoder_seq, d) — sinusoidal
positions already folded in. Everything downstream (encoder transformer,
decoder with self + cross attention, tied logits) is real.

Whisper uses LayerNorm (with bias) and GELU MLPs; attention is absolute-
position (no RoPE). Decoder self-attention caches like any decoder;
cross-attention K/V are computed once from the encoder output at prefill
and kept in the cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, ffn
from repro.models.layers import layer_norm
from repro.models.params import ParamSpec

__all__ = ["build_specs", "init_cache_specs", "forward", "decode_step", "encode"]


def _ln_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    dt = cfg.pdtype()
    return {
        "w": ParamSpec((d,), ("embed",), init="ones", dtype=dt),
        "b": ParamSpec((d,), ("embed",), init="zeros", dtype=dt),
    }


def _enc_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "ln1": _ln_specs(cfg),
        "attn": attention.specs(cfg),
        "ln2": _ln_specs(cfg),
        "mlp": ffn.dense_specs(cfg),
    }


def _dec_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "ln1": _ln_specs(cfg),
        "self_attn": attention.specs(cfg),
        "ln_cross": _ln_specs(cfg),
        "cross_attn": attention.specs(cfg),
        "ln2": _ln_specs(cfg),
        "mlp": ffn.dense_specs(cfg),
    }


def _stack(tree, n):
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, init=s.init, scale=s.scale, dtype=s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def build_specs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    dt = cfg.pdtype()
    return {
        "encoder": {
            "layers": _stack(_enc_layer_specs(cfg), cfg.num_encoder_layers),
            "ln_post": _ln_specs(cfg),
        },
        "embed": ParamSpec((v, d), ("vocab", "embed"), dtype=dt, scale=0.02),
        # large enough for the decode_32k dry-run shape (whisper itself caps
        # at 448; the backbone is exercised at the assigned shapes)
        "pos_embed": ParamSpec((32768, d), (None, "embed"), dtype=dt, scale=0.01),
        "decoder": {
            "layers": _stack(_dec_layer_specs(cfg), cfg.num_layers),
            "ln_post": _ln_specs(cfg),
        },
    }


def init_cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    l = cfg.num_layers
    cd = cfg.cdtype()
    self_kv = attention.init_cache_specs(cfg, batch, seq_len)
    return {
        "self": _stack(self_kv, l),
        "cross": {
            "k": ParamSpec((l, batch, cfg.encoder_seq, kv, hd), ("layers", "batch", None, "kv_heads", "head_dim"), init="zeros", dtype=cd),
            "v": ParamSpec((l, batch, cfg.encoder_seq, kv, hd), ("layers", "batch", None, "kv_heads", "head_dim"), init="zeros", dtype=cd),
        },
    }


def _ln(x, p, eps):
    return layer_norm(x, p["w"], p["b"], eps)


def encode(params, cfg: ArchConfig, encoder_embeds, *, use_pallas: bool = False):
    """encoder_embeds: (B, S_enc, d) stubbed frontend output."""
    cd = cfg.cdtype()
    x = encoder_embeds.astype(cd)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        y, _ = attention.apply(
            cfg, lp["attn"], h, positions=positions, mode="train",
            causal=False, use_rope=False, use_pallas=use_pallas,
        )
        x = x + y
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        x = x + ffn.dense_apply(cfg, lp["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return _ln(x, params["encoder"]["ln_post"], cfg.norm_eps)


def _cross_kv(cfg, lp, enc_out):
    cd = cfg.cdtype()
    k = jnp.einsum("bsd,dke->bske", enc_out, lp["cross_attn"]["wk"].astype(cd))
    v = jnp.einsum("bsd,dke->bske", enc_out, lp["cross_attn"]["wv"].astype(cd))
    return k, v


def forward(
    params,
    cfg: ArchConfig,
    *,
    tokens,
    encoder_embeds=None,
    enc_out=None,
    mode: str = "train",
    cache=None,
    cache_len=None,
    use_pallas: bool = False,
    max_len: int | None = None,
):
    """train: (hidden, aux). prefill: (last logits, cache, aux). decode:
    (logits, cache) — decode uses cached cross-KV, not the encoder."""
    cd = cfg.cdtype()
    if mode != "decode" and enc_out is None:
        enc_out = encode(params, cfg, encoder_embeds, use_pallas=use_pallas)

    b, s = tokens.shape
    if mode == "decode":
        positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(cd)

    if mode in ("train", "prefill"):
        def body(x, lp):
            h = _ln(x, lp["ln1"], cfg.norm_eps)
            y, self_c = attention.apply(
                cfg, lp["self_attn"], h, positions=positions, mode=mode,
                causal=True, use_rope=False, use_pallas=use_pallas,
                max_len=max_len,
            )
            x = x + y
            h = _ln(x, lp["ln_cross"], cfg.norm_eps)
            kv = _cross_kv(cfg, lp, enc_out)
            y, _ = attention.apply(
                cfg, lp["cross_attn"], h, positions=positions, mode="train",
                kv_override=kv, use_rope=False, use_pallas=use_pallas,
            )
            x = x + y
            h = _ln(x, lp["ln2"], cfg.norm_eps)
            x = x + ffn.dense_apply(cfg, lp["mlp"], h)
            ys = (self_c, kv) if mode == "prefill" else None
            return x, ys

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)
        x, ys = jax.lax.scan(body, x, params["decoder"]["layers"])
        x = _ln(x, params["decoder"]["ln_post"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if mode == "train":
            return x, aux
        self_c, (ck, cv) = ys
        logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embed"].astype(cd))
        return logits, {"self": self_c, "cross": {"k": ck, "v": cv}}, aux

    # -- decode -----------------------------------------------------------
    assert cache is not None and cache_len is not None

    def body(carry, xs):
        x = carry
        lp, self_c, cross_k, cross_v = xs
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        y, self_c_new = attention.apply(
            cfg, lp["self_attn"], h, positions=positions, mode="decode",
            cache=self_c, cache_len=cache_len, use_rope=False,
        )
        x = x + y
        h = _ln(x, lp["ln_cross"], cfg.norm_eps)
        y, _ = attention.apply(
            cfg, lp["cross_attn"], h, positions=positions, mode="decode",
            cache=None, cache_len=cache_len, kv_override=(cross_k, cross_v),
            use_rope=False,
        )
        x = x + y
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        x = x + ffn.dense_apply(cfg, lp["mlp"], h)
        return x, self_c_new

    x = x  # (B, 1, d)
    x, new_self = jax.lax.scan(
        body, x, (params["decoder"]["layers"], cache["self"], cache["cross"]["k"], cache["cross"]["v"])
    )
    x = _ln(x, params["decoder"]["ln_post"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cd))
    return logits, {"self": new_self, "cross": cache["cross"]}


def decode_step(params, cfg, cache, token, cache_len, **kw):
    return forward(
        params, cfg, tokens=token, mode="decode", cache=cache, cache_len=cache_len, **kw
    )
