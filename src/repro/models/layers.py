"""Shared neural building blocks (pure jnp, functional).

The chunked flash attention here is the reference implementation the Pallas
kernel in ``repro.kernels.flash_attention`` is validated against; model code
calls it through ``repro.kernels.ops`` so the TPU path can swap in the
kernel with ``use_pallas=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "layer_norm",
    "rope",
    "flash_attention",
    "decode_attention",
    "swiglu",
    "gelu_mlp",
]


def rms_norm(x, weight, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float = 500000.0):
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]   # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x1f * sin + x2f * cos], axis=-1)
    return out.astype(x.dtype)


def _chunk_mask(q_pos, k_pos, *, causal: bool, window: int | None):
    """(Sq, Ck) boolean mask: True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    chunk: int = 512,
    q_offset: int = 0,
    p_bf16: bool = False,
    q_block: int = 0,
):
    """Memory-efficient attention via an online-softmax scan over KV chunks.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H = KV * G (GQA).
    Never materializes the (Sq, Skv) score matrix — working set is
    O(Sq * chunk) per head group, which is what makes 32k-token prefill
    lowerable at full precision.

    Perf knobs (§Perf; defaults = accuracy-first baseline):
      p_bf16  — cast probabilities to bf16 for the PV contraction after the
                f32 online-softmax statistics: halves the dominant
                (B,Sq,KV,G,C) HBM traffic at <1e-2 output error.
      q_block — when causal and Sq == Skv, process q in blocks of this size
                and scan only kv chunks at or below the block's diagonal:
                prunes the ~Sq*Skv/2 above-diagonal score traffic the
                masked scan otherwise pays for.
    """
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape

    if q_block and causal and window is None and sq == skv and sq % q_block == 0 and q_block % chunk == 0:
        outs = []
        for qi in range(sq // q_block):
            hi = (qi + 1) * q_block
            outs.append(
                flash_attention(
                    q[:, qi * q_block : hi], k[:, :hi], v[:, :hi],
                    causal=True, window=None, chunk=chunk,
                    q_offset=qi * q_block, p_bf16=p_bf16, q_block=0,
                )
            )
        return jnp.concatenate(outs, axis=1)

    g = h // kv
    chunk = min(chunk, skv)
    while skv % chunk:          # largest divisor of skv not exceeding chunk
        chunk -= 1
    nc = skv // chunk

    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, nc, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, kv, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, ci = inputs
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", qg, kb.astype(jnp.float32)
        ) * scale  # (B,Sq,KV,G,C)
        mask = _chunk_mask(q_pos, k_pos, causal=causal, window=window)
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + p.sum(axis=-1)
        if p_bf16:
            p = p.astype(jnp.bfloat16)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p, vb.astype(p.dtype)).astype(jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, kv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int | None = None):
    """Single-token attention against a (possibly over-allocated) KV cache.

    q: (B, 1, H, D); caches: (B, S, KV, D); cache_len: scalar or (B,)
    number of valid cache entries (the new token's KV must already be
    written at position cache_len - 1).
    """
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(s)
    cl = jnp.asarray(cache_len)
    cl = cl.reshape(-1, 1) if cl.ndim else cl[None, None]
    valid = pos[None, :] < cl                      # (B|1, S)
    if window is not None:
        valid &= pos[None, :] >= cl - window
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: down( silu(x @ gate) * (x @ up) )."""
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down.astype(x.dtype))


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jnp.einsum("...d,df->...f", x, w_in.astype(x.dtype)) + b_in.astype(x.dtype)
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, w_out.astype(x.dtype)) + b_out.astype(x.dtype)
