"""Decoder trunk shared by all 10 architectures.

Layer heterogeneity (Jamba's mamba/attention interleave, DeepSeek's leading
dense layer, MoE-every-n) is handled by a **period-grouped scan**: the layer
pattern repeats with period ``p``; params for each of the ``p`` period
positions are stacked over ``n_periods`` and the trunk is a single
``lax.scan`` over periods with the ``p`` heterogeneous layers unrolled
inside the body. Compile time is therefore O(p), not O(num_layers) — this
is what keeps the 80-layer InternVL2 dry-run tractable.

Params tree:
  embed            (V, d)
  prefix           list of layer dicts (the non-periodic leading layers)
  blocks           list over period positions, each leaf stacked (n_periods, ...)
  final_norm       (d,)
  lm_head          (d, V)  (absent when tied)

Caches mirror the same structure (see ``init_cache_specs``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, ffn, mamba, rwkv6
from repro.models.layers import rms_norm
from repro.models.params import ParamSpec

__all__ = [
    "Layout",
    "layout_for",
    "build_specs",
    "init_cache_specs",
    "forward",
    "decode_step",
    "lm_logits",
    "lm_loss",
]


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static description of the trunk layer pattern."""

    prefix: tuple[tuple[str, bool], ...]    # (mixer_kind, is_moe) per leading layer
    period: tuple[tuple[str, bool], ...]    # pattern of one period
    n_periods: int

    @property
    def p(self) -> int:
        return len(self.period)


def layout_for(cfg: ArchConfig) -> Layout:
    kinds = cfg.layer_kinds()
    moes = cfg.layer_is_moe()
    layers = list(zip(kinds, moes))
    n_prefix = cfg.moe_first_dense
    body = layers[n_prefix:]
    # smallest period that tiles the body
    p = 1
    while p <= len(body):
        if len(body) % p == 0 and body == body[:p] * (len(body) // p):
            break
        p += 1
    assert len(body) % p == 0, (cfg.name, p, len(body))
    return Layout(
        prefix=tuple(layers[:n_prefix]),
        period=tuple(body[:p]),
        n_periods=len(body) // p,
    )


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def _layer_specs(cfg: ArchConfig, kind: str, is_moe: bool) -> dict:
    d = cfg.d_model
    dt = cfg.pdtype()
    mixer = {
        "attn": attention.specs,
        "mamba": mamba.specs,
        "rwkv6": rwkv6.specs,
    }[kind](cfg)
    if kind == "rwkv6":
        ffn_specs = rwkv6.cmix_specs(cfg)
    elif is_moe:
        ffn_specs = ffn.moe_specs(cfg)
    else:
        ffn_specs = ffn.dense_specs(cfg)
    return {
        "mixer_norm": ParamSpec((d,), ("embed",), init="ones", dtype=dt),
        "mixer": mixer,
        "ffn_norm": ParamSpec((d,), ("embed",), init="ones", dtype=dt),
        "ffn": ffn_specs,
    }


def _stack(spec_tree, n: int):
    def add_axis(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, init=s.init, scale=s.scale, dtype=s.dtype)

    return jax.tree_util.tree_map(add_axis, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def build_specs(cfg: ArchConfig) -> dict:
    lay = layout_for(cfg)
    d, v = cfg.d_model, cfg.vocab_size
    dt = cfg.pdtype()
    out: dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), dtype=dt, scale=0.02),
        "prefix": [_layer_specs(cfg, k, m) for (k, m) in lay.prefix],
        "blocks": [
            _stack(_layer_specs(cfg, k, m), lay.n_periods) for (k, m) in lay.period
        ],
        "final_norm": ParamSpec((d,), ("embed",), init="ones", dtype=dt),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamSpec((d, v), ("embed", "vocab"), dtype=dt, scale=0.02)
    return out


def _layer_cache_specs(cfg: ArchConfig, kind: str, batch: int, seq_len: int) -> dict:
    cache = {
        "attn": attention.init_cache_specs,
        "mamba": mamba.init_cache_specs,
        "rwkv6": rwkv6.init_cache_specs,
    }[kind](cfg, batch, seq_len)
    if kind == "rwkv6":
        return {"mixer": cache, "ffn": rwkv6.cmix_cache_specs(cfg, batch, seq_len)}
    return {"mixer": cache, "ffn": None}


def init_cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    lay = layout_for(cfg)
    return {
        "prefix": [
            _layer_cache_specs(cfg, k, batch, seq_len) for (k, _) in lay.prefix
        ],
        "blocks": [
            _stack(_layer_cache_specs(cfg, k, batch, seq_len), lay.n_periods)
            for (k, _) in lay.period
        ],
    }


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _apply_layer(
    cfg: ArchConfig,
    p,
    x,
    *,
    kind: str,
    is_moe: bool,
    mode: str,
    positions,
    cache,
    cache_len,
    use_pallas: bool = False,
    max_len: int | None = None,
):
    """Pre-norm residual layer. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    mc = cache["mixer"] if cache is not None else None
    if kind == "attn":
        y, mc_new = attention.apply(
            cfg, p["mixer"], h, positions=positions, mode=mode,
            cache=mc, cache_len=cache_len, use_pallas=use_pallas,
            max_len=max_len,
        )
    elif kind == "mamba":
        y, mc_new = mamba.apply(cfg, p["mixer"], h, mode=mode, cache=mc, use_pallas=use_pallas)
    else:
        y, mc_new = rwkv6.apply(cfg, p["mixer"], h, mode=mode, cache=mc, use_pallas=use_pallas)
    x = x + y

    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    fc_new = None
    if kind == "rwkv6":
        fc = cache["ffn"] if cache is not None else None
        y, fc_new = rwkv6.cmix_apply(cfg, p["ffn"], h, mode=mode, cache=fc)
    elif is_moe:
        y, aux = ffn.moe_apply(cfg, p["ffn"], h, train=(mode == "train"))
    else:
        y = ffn.dense_apply(cfg, p["ffn"], h)
    x = x + y
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"mixer": mc_new, "ffn": fc_new}
    return x, new_cache, aux


def forward(
    params,
    cfg: ArchConfig,
    *,
    tokens=None,
    embeds=None,
    mode: str = "train",
    cache=None,
    cache_len=None,
    use_pallas: bool = False,
    max_len: int | None = None,
):
    """Run the trunk.

    train:   returns (logits, aux_loss)
    prefill: returns (logits, cache, aux_loss)
    decode:  tokens (B,1); returns (logits, cache)
    """
    lay = layout_for(cfg)
    cd = cfg.cdtype()

    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    else:
        x = embeds.astype(cd)
    b, s, _ = x.shape

    if mode == "decode":
        assert cache_len is not None
        positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    aux_total = jnp.zeros((), jnp.float32)
    new_prefix_caches = []
    for i, (kind, is_moe) in enumerate(lay.prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, c_new, aux = _apply_layer(
            cfg, params["prefix"][i], x, kind=kind, is_moe=is_moe, mode=mode,
            positions=positions, cache=c, cache_len=cache_len, use_pallas=use_pallas,
            max_len=max_len,
        )
        aux_total += aux
        new_prefix_caches.append(c_new)

    def period_body(carry, xs):
        x, aux_total = carry
        block_params, block_caches = xs
        new_caches = []
        for j, (kind, is_moe) in enumerate(lay.period):
            c = block_caches[j] if block_caches is not None else None
            x, c_new, aux = _apply_layer(
                cfg, block_params[j], x, kind=kind, is_moe=is_moe, mode=mode,
                positions=positions, cache=c, cache_len=cache_len,
                use_pallas=use_pallas, max_len=max_len,
            )
            aux_total += aux
            new_caches.append(c_new)
        y = new_caches if mode in ("prefill", "decode") else None
        return (x, aux_total), y

    body = period_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(period_body)

    block_caches = cache["blocks"] if cache is not None else None
    (x, aux_total), new_block_caches = jax.lax.scan(
        body, (x, aux_total), (params["blocks"], block_caches)
    )

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    if mode == "train":
        # hidden states, not logits: the loss materializes the (B, S, V)
        # logits only chunk-by-chunk (see lm_loss) to bound live memory.
        return x, aux_total
    new_cache = {"prefix": new_prefix_caches, "blocks": new_block_caches}
    if mode == "prefill":
        # only the last position's logits are needed to start decoding
        return lm_logits(params, cfg, x[:, -1:]), new_cache, aux_total
    return lm_logits(params, cfg, x), new_cache


def lm_logits(params, cfg: ArchConfig, hidden):
    cd = cfg.cdtype()
    head = params.get("lm_head")
    if head is None:
        return jnp.einsum("bsd,vd->bsv", hidden, params["embed"].astype(cd))
    return jnp.einsum("bsd,dv->bsv", hidden, head.astype(cd))


def lm_loss(params, cfg: ArchConfig, hidden, labels, mask=None, *, chunk: int = 512):
    """Chunked next-token cross entropy: logits for each sequence chunk are
    (re)computed inside a rematerialized scan so the full (B, S, V) tensor
    never lives in memory — necessary for 128k-200k vocabularies."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones_like(labels, dtype=jnp.float32)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h, l, m = xs
        logits = lm_logits(params, cfg, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (total, count), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
    return total / jnp.maximum(count, 1.0)


def decode_step(params, cfg: ArchConfig, cache, token, cache_len, *, embeds=None, use_pallas: bool = False):
    """One decode step: token (B, 1) int32, cache_len scalar int32."""
    return forward(
        params, cfg, tokens=token, embeds=embeds, mode="decode",
        cache=cache, cache_len=cache_len, use_pallas=use_pallas,
    )
