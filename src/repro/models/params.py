"""Parameter-spec system: shapes + logical sharding axes in one place.

Every model module builds a nested dict of ``ParamSpec``s. From that single
source of truth we derive:

* ``init_params``   — materialized arrays (used by smoke tests / real runs)
* ``abstract_params`` — ShapeDtypeStructs (used by the dry-run; no allocation)
* ``logical_axes``  — pytree of logical-axis-name tuples, consumed by
  ``repro.sharding.rules`` to produce NamedShardings.

Logical axis vocabulary (resolved by the sharding rules):
  "batch", "seq", "embed", "heads", "kv_heads", "head_dim", "mlp",
  "experts", "moe_mlp", "vocab", "layers", "state", "conv", None
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_params", "abstract_params", "logical_axes", "param_count"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"           # normal | zeros | ones | decay
    scale: float | None = None     # stddev override (default fan-in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map_specs(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_spec)


def init_params(specs, key: jax.Array):
    """Materialize a spec tree into arrays. Deterministic per-leaf keys are
    derived by folding the leaf path hash into ``key``."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    paths = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)[0]

    arrays = []
    for (path, spec) in paths:
        # crc32, NOT hash(): str hashes are salted per-process
        # (PYTHONHASHSEED), which silently broke cross-process
        # reproducibility of every init draw
        h = zlib.crc32(jax.tree_util.keystr(path).encode()) % (2**31 - 1)
        k = jax.random.fold_in(key, h)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        elif spec.init == "decay":
            # small negative values -> exp(-exp(w)) decay close to 1
            arr = jnp.full(spec.shape, -2.0, spec.dtype)
        elif spec.init == "s4d":
            # S4D-real: A_log[d, n] = log(1..N) per state column
            n = spec.shape[-1]
            arr = jnp.broadcast_to(
                jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), spec.shape
            ).astype(spec.dtype)
        elif spec.init == "dt_bias":
            # softplus^{-1}(dt) for dt ~ 0.001..0.1 -> around -4.6
            arr = jnp.full(spec.shape, -4.6, spec.dtype)
        else:
            fan_in = spec.shape[0] if len(spec.shape) == 1 else int(np.prod(spec.shape[:-1]))
            scale = spec.scale if spec.scale is not None else 1.0 / max(np.sqrt(fan_in), 1.0)
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(spec.dtype)
        arrays.append(arr)
    del leaves
    return jax.tree_util.tree_unflatten(treedef, arrays)


def abstract_params(specs):
    """ShapeDtypeStruct twin of the spec tree — zero allocation."""
    return _map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def logical_axes(specs):
    """Pytree of logical-axis tuples matching the params pytree."""
    return _map_specs(lambda s: s.axes, specs)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
