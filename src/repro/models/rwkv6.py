"""RWKV-6 "Finch" mixer: data-dependent decay linear attention
[arXiv:2404.05892]. Attention-free: decode state is O(H * hd^2), constant
in context length — which is why rwkv6 runs the 500k-token decode shape.

Time-mix (the "attention"):       per head, state S in R^{hd x hd}
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with token-shift ddlerp inputs and data-dependent decay
    w_t = exp(-exp(w0 + tanh(x_w @ A_w) @ B_w)).

Channel-mix (the "FFN"):  k = relu(W_k x_k)^2, out = sigmoid(W_r x_r) * W_v k.

The train-time recurrence is a `lax.scan` over time carrying S in f32; the
Pallas kernel in ``repro.kernels.wkv6`` implements the chunked TPU version
and is validated against ``wkv_scan`` below.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec

__all__ = [
    "specs",
    "cmix_specs",
    "apply",
    "cmix_apply",
    "init_cache_specs",
    "cmix_cache_specs",
    "wkv_scan",
]

_MIX_TARGETS = 5  # r, k, v, w, g


def specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    lm, ld = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    dt = cfg.pdtype()
    return {
        "mu_x": ParamSpec((d,), ("embed",), init="zeros", dtype=dt),
        "mu": ParamSpec((_MIX_TARGETS, d), (None, "embed"), init="zeros", dtype=dt),
        "tm_w1": ParamSpec((d, _MIX_TARGETS * lm), ("embed", None), dtype=dt, scale=0.01),
        "tm_w2": ParamSpec((_MIX_TARGETS, lm, d), (None, None, "embed"), dtype=dt, scale=0.01),
        "wr": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wk": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wv": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wg": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), dtype=dt),
        "w0": ParamSpec((h, hd), ("heads", "head_dim"), init="decay", dtype=jnp.float32),
        "dw1": ParamSpec((d, ld), ("embed", None), dtype=dt, scale=0.01),
        "dw2": ParamSpec((ld, d), (None, "embed"), dtype=dt, scale=0.01),
        "u": ParamSpec((h, hd), ("heads", "head_dim"), dtype=jnp.float32, scale=0.1),
        "ln_x": ParamSpec((d,), ("embed",), init="ones", dtype=jnp.float32),
    }


def cmix_specs(cfg: ArchConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype()
    return {
        "mu_k": ParamSpec((d,), ("embed",), init="zeros", dtype=dt),
        "mu_r": ParamSpec((d,), ("embed",), init="zeros", dtype=dt),
        "ck": ParamSpec((d, ff), ("embed", "mlp"), dtype=dt),
        "cv": ParamSpec((ff, d), ("mlp", "embed"), dtype=dt),
        "cr": ParamSpec((d, d), ("embed", None), dtype=dt),
    }


def init_cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    del seq_len
    d = cfg.d_model
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "shift": ParamSpec((batch, d), ("batch", "embed"), init="zeros", dtype=cfg.cdtype()),
        "wkv": ParamSpec((batch, h, hd, hd), ("batch", "heads", None, None), init="zeros", dtype=jnp.float32),
    }


def cmix_cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    del seq_len
    return {
        "shift": ParamSpec((batch, cfg.d_model), ("batch", "embed"), init="zeros", dtype=cfg.cdtype()),
    }


def wkv_scan(r, k, v, w, u, s0=None, *, unroll: int = 1):
    """Reference WKV-6 recurrence. r,k,v,w: (B, S, H, hd); u: (H, hd).
    Returns (y (B,S,H,hd) f32, final state (B,H,hd,hd) f32).

    ``unroll`` executes that many recurrence steps per scan iteration: the
    carried (B,H,hd,hd) state then round-trips HBM once per ``unroll``
    steps instead of once per token — the dominant HBM term of RWKV
    training drops by ~unroll (see EXPERIMENTS.md §Perf). Bit-identical
    math; the Pallas kernel removes the round-trip entirely on TPU."""
    b, s, h, hd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]              # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, state + u[..., :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    s_last, ys = jax.lax.scan(step, s0, xs, unroll=min(unroll, s))
    return ys.transpose(1, 0, 2, 3), s_last


def wkv_chunked(r, k, v, w, u, s0=None, *, chunk: int = 64):
    """Chunked matmul formulation of the WKV-6 recurrence (beyond-paper
    optimization; exact same math as ``wkv_scan``).

    Within a chunk of length C, with a_t = sum_{u<t} log w_u (chunk-local
    prefix, a_0 = 0) and A_T = sum over the whole chunk:

        y_t = (r_t * exp(a_t)) . S_chunk_start                 [cross term]
            + sum_{s<t} ( sum_d r_t[d] k_s[d] exp(a_t[d]-a_{s+1}[d]) ) v_s
            + (r_t * u * k_t) . v_t                            [bonus]
        S'  = diag(exp(A_T)) S + sum_s (k_s * exp(A_T - a_{s+1})) v_s^T

    Every exponent is a sum of log-decays over a *forward* interval, hence
    <= 0: no overflow is possible (unlike the exp(a)/exp(-a) factorized
    form). The scan now carries S once per CHUNK, so the dominant HBM term
    of RWKV training drops ~chunk-fold, and the per-chunk work is
    (C x C x hd) contractions instead of 4096 rank-1 updates.
    """
    b, s, h, hd = r.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def to_chunks(t):
        return t.astype(jnp.float32).reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)
    lw = jnp.log(jnp.maximum(to_chunks(w), 1e-30))           # (nc,B,C,H,hd), <= 0
    uf = u.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # s < t

    def body(state, inp):
        rb, kb, vb, lwb = inp                                 # (B,C,H,hd)
        a = jnp.cumsum(lwb, axis=1) - lwb                     # a_t = sum_{u<t}
        a_total = a[:, -1] + lwb[:, -1]                       # (B,H,hd) = A_T
        # cross: y_t += (r_t * exp(a_t)) . S
        r_dec = rb * jnp.exp(a)
        y = jnp.einsum("bthi,bhij->bthj", r_dec, state)
        # intra: exponent a_t - a_{s+1} <= 0 for s < t
        a_next = a + lwb                                      # a_{s+1}
        expo = a[:, :, None] - a_next[:, None, :]             # (B,t,s,H,hd)
        coef = jnp.exp(jnp.minimum(expo, 0.0)) * tri[None, :, :, None, None]
        att = jnp.einsum("bthd,bshd,btshd->bths", rb, kb, coef)
        y = y + jnp.einsum("bths,bshj->bthj", att, vb)
        # bonus diagonal
        y = y + jnp.einsum("bthd,bthd,bthj->bthj", rb * uf[None, None], kb, vb)
        # state update
        k_dec = kb * jnp.exp(a_total[:, None] - a_next)       # (B,C,H,hd), exp<=1
        state = jnp.exp(a_total)[..., None] * state + jnp.einsum(
            "bshi,bshj->bhij", k_dec, vb
        )
        return state, y

    s_last, ys = jax.lax.scan(body, s0, (rc, kc, vc, lw))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return y, s_last


def _ddlerp(p, x, x_prev):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    dx = x_prev - x
    inner = x + dx * p["mu_x"].astype(x.dtype)
    lora = jnp.einsum("bsd,de->bse", jnp.tanh(inner), p["tm_w1"].astype(x.dtype))
    lora = lora.reshape(*x.shape[:-1], _MIX_TARGETS, -1)
    lora = jnp.einsum("bste,ted->bstd", lora, p["tm_w2"].astype(x.dtype))
    mix = p["mu"].astype(x.dtype) + lora                        # (B,S,5,d)
    return x[..., None, :] + dx[..., None, :] * mix             # (B,S,5,d)


def _decay(cfg, p, xw):
    """xw: (B,S,d) -> per-channel decay in (0,1): (B,S,H,hd) f32."""
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    lo = jnp.einsum("bsd,dl->bsl", jnp.tanh(xw), p["dw1"].astype(xw.dtype))
    lo = jnp.einsum("bsl,ld->bsd", lo, p["dw2"].astype(xw.dtype))
    raw = p["w0"].reshape(-1) + lo.astype(jnp.float32)
    return jnp.exp(-jnp.exp(raw)).reshape(*xw.shape[:-1], h, hd)


def apply(cfg: ArchConfig, p, x, *, mode: str = "train", cache=None, use_pallas: bool = False):
    """Time-mix. x: (B, S, d) normed input. Returns (y, new_cache|None)."""
    from repro.kernels import ops as kops

    cd = cfg.cdtype()
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim

    if mode in ("train", "prefill"):
        x_prev = jnp.concatenate([jnp.zeros((b, 1, d), x.dtype), x[:, :-1]], axis=1)
        s0 = None
    else:
        assert cache is not None
        x_prev = cache["shift"][:, None].astype(x.dtype)
        s0 = cache["wkv"]

    mixed = _ddlerp(p, x, x_prev)                               # (B,S,5,d)
    xr, xk, xv, xw, xg = (mixed[:, :, i] for i in range(_MIX_TARGETS))
    r = jnp.einsum("bsd,dhe->bshe", xr, p["wr"].astype(cd))
    k = jnp.einsum("bsd,dhe->bshe", xk, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhe->bshe", xv, p["wv"].astype(cd))
    g = jnp.einsum("bsd,dhe->bshe", xg, p["wg"].astype(cd))
    w = _decay(cfg, p, xw)

    backend = cfg.wkv_backend if mode in ("train", "prefill") else "scan"
    y, s_last = kops.wkv6(r, k, v, w, p["u"], s0=s0, use_pallas=use_pallas,
                          unroll=cfg.wkv_unroll, backend=backend,
                          chunk=cfg.wkv_chunk)

    # per-head group norm then gate
    y = y.reshape(b, s, h, hd)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(b, s, d) * p["ln_x"]
    y = y.astype(cd) * jax.nn.silu(g.reshape(b, s, d))
    out = jnp.einsum("bshe,hed->bsd", y.reshape(b, s, h, hd), p["wo"].astype(cd))

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"shift": x[:, -1].astype(cd), "wkv": s_last}
    return out, new_cache


def cmix_apply(cfg: ArchConfig, p, x, *, mode: str = "train", cache=None):
    """Channel-mix. x: (B, S, d) normed input."""
    cd = cfg.cdtype()
    b, s, d = x.shape
    if mode in ("train", "prefill"):
        x_prev = jnp.concatenate([jnp.zeros((b, 1, d), x.dtype), x[:, :-1]], axis=1)
    else:
        assert cache is not None
        x_prev = cache["shift"][:, None].astype(x.dtype)
    xk = x + (x_prev - x) * p["mu_k"].astype(cd)
    xr = x + (x_prev - x) * p["mu_r"].astype(cd)
    k = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(cd))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["cv"].astype(cd))
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cr"].astype(cd))) * kv
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"shift": x[:, -1].astype(cd)}
    return out, new_cache
