"""Mamba (S6) mixer for the Jamba hybrid architecture [arXiv:2403.19887].

Selective state-space layer: input-dependent (dt, B, C) with diagonal A.
Train/prefill runs a time scan carrying h in f32 (the TPU adaptation of the
paper's CUDA "hardware-aware" fused scan: the carried state lives in
registers/VMEM instead of being materialized to HBM — in JAX terms we never
materialize the (B, S, d_inner, N) state tensor, only the (B, S, d_inner)
outputs). Decode is a single recurrence step on cached (conv, ssm) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec

__all__ = ["specs", "apply", "init_cache_specs"]


def specs(cfg: ArchConfig) -> dict:
    d, di, n, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    dtr = cfg.resolved_dt_rank
    dt = cfg.pdtype()
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mlp"), dtype=dt),
        "conv_w": ParamSpec((dc, di), ("conv", "mlp"), dtype=dt, scale=0.5),
        "conv_b": ParamSpec((di,), ("mlp",), init="zeros", dtype=dt),
        "x_proj": ParamSpec((di, dtr + 2 * n), ("mlp", None), dtype=dt),
        "dt_w": ParamSpec((dtr, di), (None, "mlp"), dtype=dt),
        "dt_b": ParamSpec((di,), ("mlp",), init="dt_bias", dtype=dt),
        "a_log": ParamSpec((di, n), ("mlp", "state"), init="s4d", dtype=jnp.float32),
        "d_skip": ParamSpec((di,), ("mlp",), init="ones", dtype=jnp.float32),
        "out_proj": ParamSpec((di, d), ("mlp", "embed"), dtype=dt),
    }


def init_cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    del seq_len  # state size is O(1) in context length
    di, n, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    return {
        "conv": ParamSpec((batch, dc - 1, di), ("batch", None, "mlp"), init="zeros", dtype=cfg.cdtype()),
        "ssm": ParamSpec((batch, di, n), ("batch", "mlp", "state"), init="zeros", dtype=jnp.float32),
    }


def _split_xdbc(cfg, p, x_conv):
    """x_conv (B,S,di) -> dt (B,S,di), B (B,S,N), C (B,S,N)."""
    dtr, n = cfg.resolved_dt_rank, cfg.d_state
    cd = cfg.cdtype()
    xdbc = jnp.einsum("bsd,de->bse", x_conv, p["x_proj"].astype(cd))
    dt_raw, b_ssm, c_ssm = jnp.split(xdbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_raw, p["dt_w"].astype(cd)).astype(jnp.float32)
        + p["dt_b"].astype(jnp.float32)
    )
    return dt, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def apply(cfg: ArchConfig, p, x, *, mode: str = "train", cache=None, use_pallas: bool = False):
    """x: (B, S, d). Returns (y, new_cache|None)."""
    cd = cfg.cdtype()
    di, dc = cfg.d_inner, cfg.d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    x_in, z = jnp.split(xz, [di], axis=-1)

    if mode in ("train", "prefill"):
        b, s, _ = x_in.shape
        pad = jnp.zeros((b, dc - 1, di), x_in.dtype)
        x_pad = jnp.concatenate([pad, x_in], axis=1)          # (B, S+dc-1, di)
        conv = sum(
            x_pad[:, i : i + s] * p["conv_w"][i].astype(cd) for i in range(dc)
        ) + p["conv_b"].astype(cd)
        x_conv = jax.nn.silu(conv)
        dt, b_ssm, c_ssm = _split_xdbc(cfg, p, x_conv)
        a = -jnp.exp(p["a_log"])                               # (di, N)

        def step(h, inp):
            dt_t, b_t, c_t, x_t = inp                          # (B,di),(B,N),(B,N),(B,di)
            da = jnp.exp(dt_t[:, :, None] * a[None])           # (B,di,N)
            h = h * da + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y

        if use_pallas:
            from repro.kernels import ops as kops

            ys_bsd, h_last = kops.mamba_scan(
                dt, x_conv.astype(jnp.float32), b_ssm, c_ssm, a, use_pallas=True
            )
            y = ys_bsd + x_conv.astype(jnp.float32) * p["d_skip"]
        else:
            h0 = jnp.zeros((b, di, cfg.d_state), jnp.float32)
            xs = (
                dt.transpose(1, 0, 2),
                b_ssm.transpose(1, 0, 2),
                c_ssm.transpose(1, 0, 2),
                x_conv.astype(jnp.float32).transpose(1, 0, 2),
            )
            h_last, ys = jax.lax.scan(step, h0, xs, unroll=min(cfg.mamba_unroll, s))
            y = ys.transpose(1, 0, 2) + x_conv.astype(jnp.float32) * p["d_skip"]
        y = (y.astype(cd) * jax.nn.silu(z))
        out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(cd))
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "conv": x_in[:, -(dc - 1) :].astype(cd),
                "ssm": h_last,
            }
        return out, new_cache

    # -- decode ---------------------------------------------------------
    assert cache is not None
    x_t = x_in[:, 0]                                           # (B, di)
    conv_state = cache["conv"]                                 # (B, dc-1, di)
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, dc, di)
    conv = jnp.einsum("bcd,cd->bd", window.astype(cd), p["conv_w"].astype(cd)) + p["conv_b"].astype(cd)
    x_conv = jax.nn.silu(conv)[:, None]                        # (B,1,di)
    dt, b_ssm, c_ssm = _split_xdbc(cfg, p, x_conv)
    a = -jnp.exp(p["a_log"])
    dt_t, b_t, c_t = dt[:, 0], b_ssm[:, 0], c_ssm[:, 0]
    h = cache["ssm"]
    da = jnp.exp(dt_t[:, :, None] * a[None])
    h = h * da + (dt_t * x_conv[:, 0].astype(jnp.float32))[:, :, None] * b_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t) + x_conv[:, 0].astype(jnp.float32) * p["d_skip"]
    y = (y[:, None].astype(cd) * jax.nn.silu(z))
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(cd))
    return out, {"conv": window[:, 1:].astype(conv_state.dtype), "ssm": h}
