"""Attention mixer: GQA + RoPE + optional sliding window, train/prefill/decode.

Decode uses a (possibly rolling) KV cache: for sliding-window models the
cache has exactly ``window`` slots and new KVs overwrite the oldest — this
is what makes 500k-token decode O(window) for h2o-danube.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import decode_attention, rope
from repro.models.params import ParamSpec

__all__ = ["specs", "apply", "init_cache_specs"]


def specs(cfg: ArchConfig, *, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = cfg.pdtype()
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), dtype=dt),
    }


def cache_seq_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    s = cache_seq_len(cfg, seq_len)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, s, kv, hd)
    axes = ("batch", "cache_seq", "kv_heads", "head_dim")
    dt = cfg.cdtype()
    return {
        "k": ParamSpec(shape, axes, init="zeros", dtype=dt),
        "v": ParamSpec(shape, axes, init="zeros", dtype=dt),
    }


def _project_qkv(cfg: ArchConfig, p, x, positions, *, use_rope: bool = True):
    cd = cfg.cdtype()
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dke->bske", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dke->bske", x, p["wv"].astype(cd))
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply(
    cfg: ArchConfig,
    p,
    x,
    *,
    positions,
    mode: str = "train",
    cache=None,
    cache_len=None,
    causal: bool = True,
    use_rope: bool = True,
    kv_override=None,
    use_pallas: bool = False,
    max_len: int | None = None,
):
    """Run the attention mixer.

    mode: "train" | "prefill" (returns cache) | "decode" (cache required).
    kv_override: (k, v) from an encoder for cross-attention (pre-projected).
    """
    from repro.kernels import ops as kops

    cd = cfg.cdtype()
    if mode in ("train", "prefill"):
        if kv_override is not None:
            q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(cd))
            k, v = kv_override
            out = kops.flash_attention(
                q, k, v, causal=False, window=None, chunk=cfg.attn_chunk,
                use_pallas=use_pallas,
            )
            new_cache = None
        else:
            q, k, v = _project_qkv(cfg, p, x, positions, use_rope=use_rope)
            out = kops.flash_attention(
                q, k, v, causal=causal, window=cfg.sliding_window,
                chunk=cfg.attn_chunk, use_pallas=use_pallas,
                p_bf16=cfg.attn_p_bf16, q_block=cfg.attn_q_block,
            )
            new_cache = None
            if mode == "prefill":
                # build a cache laid out so that token t lives in slot
                # t % s_cache — the invariant decode's rolling write relies on
                s = k.shape[1]
                s_cache = cache_seq_len(cfg, max(max_len or s, s))
                if s_cache >= s:
                    pad = ((0, 0), (0, s_cache - s), (0, 0), (0, 0))
                    new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
                else:
                    roll = s % s_cache
                    new_cache = {
                        "k": jnp.roll(k[:, -s_cache:], roll, axis=1),
                        "v": jnp.roll(v[:, -s_cache:], roll, axis=1),
                    }
        y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(cd))
        return y, new_cache

    # -- decode: single token ------------------------------------------------
    assert mode == "decode" and cache_len is not None
    if kv_override is not None:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(cd))
        k, v = kv_override
        enc_len = jnp.full((), k.shape[1])
        out = decode_attention(q, k, v, enc_len)
        y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(cd))
        return y, cache

    assert cache is not None
    q, k_new, v_new = _project_qkv(cfg, p, x, positions, use_rope=use_rope)
    s_cache = cache["k"].shape[1]
    write_pos = (cache_len % s_cache).astype(jnp.int32)
    k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), write_pos, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), write_pos, axis=1)
    valid = jnp.minimum(cache_len + 1, s_cache)
    out = decode_attention(q, k_cache, v_cache, valid)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(cd))
    return y, {"k": k_cache, "v": v_cache}
