"""Public model API: one object per architecture config.

``Model`` wraps the family-specific modules behind a uniform interface the
launcher, federated runtime, dry-run, and tests all consume:

    m = Model(cfg)
    params = m.init(jax.random.key(0))
    loss   = m.loss(params, batch)                     # train
    logits, cache = m.prefill(params, batch)           # serving
    logits, cache = m.decode(params, cache, token, cache_len, extras)

Batch layouts (all int32 tokens):
  dense/moe/ssm/hybrid: {tokens(B,S), labels(B,S)}
  vlm:   {tokens(B,S_text), labels(B,S_text), image_embeds(B,N_img,d)}
  audio: {tokens(B,S), labels(B,S), encoder_embeds(B,S_enc,d)}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import decoder, encdec
from repro.models.params import abstract_params, init_params, logical_axes, param_count

__all__ = ["Model"]

MOE_AUX_WEIGHT = 0.01


class Model:
    def __init__(self, cfg: ArchConfig, *, use_pallas: bool = False):
        self.cfg = cfg
        self.use_pallas = use_pallas
        if cfg.family == "audio":
            self.specs = encdec.build_specs(cfg)
        else:
            self.specs = decoder.build_specs(cfg)

    # -- params ------------------------------------------------------------
    def init(self, key):
        return init_params(self.specs, key)

    def abstract_params(self):
        return abstract_params(self.specs)

    def param_axes(self):
        return logical_axes(self.specs)

    def param_count(self) -> int:
        return param_count(self.specs)

    # -- caches ------------------------------------------------------------
    def cache_specs(self, batch: int, seq_len: int):
        if self.cfg.family == "audio":
            return encdec.init_cache_specs(self.cfg, batch, seq_len)
        return decoder.init_cache_specs(self.cfg, batch, seq_len)

    def cache_axes(self, batch: int, seq_len: int):
        return logical_axes(self.cache_specs(batch, seq_len))

    def abstract_cache(self, batch: int, seq_len: int):
        return abstract_params(self.cache_specs(batch, seq_len))

    def init_cache(self, batch: int, seq_len: int):
        return init_params(self.cache_specs(batch, seq_len), jax.random.key(0))

    # -- embedding path for multimodal stubs --------------------------------
    def _train_embeds(self, params, batch):
        cfg = self.cfg
        cd = cfg.cdtype()
        if cfg.family == "vlm":
            tok = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cd)
            return jnp.concatenate([batch["image_embeds"].astype(cd), tok], axis=1)
        return None

    # -- train ---------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            hidden, aux = encdec.forward(
                params, cfg, tokens=batch["tokens"],
                encoder_embeds=batch["encoder_embeds"], mode="train",
                use_pallas=self.use_pallas,
            )
            return decoder.lm_loss(params, cfg, hidden, batch["labels"], chunk=cfg.loss_chunk)

        embeds = self._train_embeds(params, batch)
        hidden, aux = decoder.forward(
            params, cfg,
            tokens=None if embeds is not None else batch["tokens"],
            embeds=embeds, mode="train", use_pallas=self.use_pallas,
        )
        if cfg.family == "vlm":
            hidden = hidden[:, cfg.num_image_tokens :]
        loss = decoder.lm_loss(params, cfg, hidden, batch["labels"], chunk=cfg.loss_chunk)
        if cfg.num_experts:
            loss = loss + MOE_AUX_WEIGHT * aux
        return loss

    # -- serving ---------------------------------------------------------------
    def prefill(self, params, batch, *, max_len: int | None = None):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.forward(
                params, cfg, tokens=batch["tokens"],
                encoder_embeds=batch["encoder_embeds"], mode="prefill",
                use_pallas=self.use_pallas, max_len=max_len,
            )
        embeds = self._train_embeds(params, batch)
        return decoder.forward(
            params, cfg,
            tokens=None if embeds is not None else batch["tokens"],
            embeds=embeds, mode="prefill", use_pallas=self.use_pallas,
            max_len=max_len,
        )

    def decode(self, params, cache, token, cache_len, extras=None):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.decode_step(params, cfg, cache, token, cache_len)
        return decoder.decode_step(
            params, cfg, cache, token, cache_len, use_pallas=self.use_pallas
        )

    # -- dry-run input specs ---------------------------------------------------
    def input_specs(self, shape: InputShape) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape
        (weak-type-correct, shardable, no device allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        cd = cfg.cdtype()

        def tok(bb, ss):
            return jax.ShapeDtypeStruct((bb, ss), i32)

        if shape.kind in ("train", "prefill"):
            if cfg.family == "vlm":
                st = s - cfg.num_image_tokens
                out = {
                    "tokens": tok(b, st),
                    "image_embeds": jax.ShapeDtypeStruct((b, cfg.num_image_tokens, cfg.d_model), cd),
                }
                if shape.kind == "train":
                    out["labels"] = tok(b, st)
                return out
            if cfg.family == "audio":
                out = {
                    "tokens": tok(b, s),
                    "encoder_embeds": jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), cd),
                }
                if shape.kind == "train":
                    out["labels"] = tok(b, s)
                return out
            out = {"tokens": tok(b, s)}
            if shape.kind == "train":
                out["labels"] = tok(b, s)
            return out

        # decode: one token against a seq_len cache
        return {
            "token": tok(b, 1),
            "cache": self.abstract_cache(b, s),
            "cache_len": jax.ShapeDtypeStruct((), i32),
        }
