"""The paper's own learning model: a fully-connected DNN for MNIST-class
data with layout [784, 300, 124, 60, 10] (Sec. V-A). This is the model the
federated MEL simulation trains; the allocator's C_m/S_m constants for it
come from ``repro.core.complexity.mnist_dnn_cost``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, init_params

__all__ = ["PAPER_LAYERS", "build_specs", "init", "forward", "loss", "accuracy"]

PAPER_LAYERS = [784, 300, 124, 60, 10]


def build_specs(layers=None):
    layers = layers or PAPER_LAYERS
    out = []
    for fan_in, fan_out in zip(layers[:-1], layers[1:]):
        out.append(
            {
                "w": ParamSpec((fan_in, fan_out), ("embed", "mlp"), scale=float(2.0 / fan_in) ** 0.5),
                "b": ParamSpec((fan_out,), ("mlp",), init="zeros"),
            }
        )
    return out


def init(key, layers=None):
    return init_params(build_specs(layers), key)


def forward(params, x):
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def loss(params, batch):
    logits = forward(params, batch["x"])
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    if "mask" in batch:
        m = batch["mask"].astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def accuracy(params, x, y):
    return jnp.mean(jnp.argmax(forward(params, x), axis=-1) == y)
