"""FFN blocks: dense SwiGLU / GELU MLP and Mixture-of-Experts.

MoE uses a *grouped sort-based dispatch*: tokens are grouped per sequence
(group axis sharded with batch over the data axes), and within each group
top-k assignments are sorted by expert id and scattered into a fixed
(E, C) capacity buffer. All data-dependent scatter/gather stays *local to
the group*, so under pjit no cross-shard data-dependent communication is
generated — expert weights are tensor-parallel over ``moe_mlp`` and the
only collective is the standard TP all-reduce of the down-projection.
Overflow beyond capacity is dropped (GShard/Switch semantics).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import gelu_mlp, swiglu
from repro.models.params import ParamSpec

__all__ = ["dense_specs", "dense_apply", "moe_specs", "moe_apply"]


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

def dense_specs(cfg: ArchConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype()
    if cfg.act == "gelu":
        return {
            "w_in": ParamSpec((d, ff), ("embed", "mlp"), dtype=dt),
            "b_in": ParamSpec((ff,), ("mlp",), init="zeros", dtype=dt),
            "w_out": ParamSpec((ff, d), ("mlp", "embed"), dtype=dt),
            "b_out": ParamSpec((d,), ("embed",), init="zeros", dtype=dt),
        }
    return {
        "w_gate": ParamSpec((d, ff), ("embed", "mlp"), dtype=dt),
        "w_up": ParamSpec((d, ff), ("embed", "mlp"), dtype=dt),
        "w_down": ParamSpec((ff, d), ("mlp", "embed"), dtype=dt),
    }


def dense_apply(cfg: ArchConfig, p, x):
    if cfg.act == "gelu":
        return gelu_mlp(x, p["w_in"], p["b_in"], p["w_out"], p["b_out"])
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_specs(cfg: ArchConfig) -> dict:
    d, e, mff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = cfg.pdtype()
    out = {
        "router": ParamSpec((d, e), ("embed", "experts"), dtype=dt, scale=0.02),
        "w_gate": ParamSpec((e, d, mff), ("experts", "embed", "moe_mlp"), dtype=dt),
        "w_up": ParamSpec((e, d, mff), ("experts", "embed", "moe_mlp"), dtype=dt),
        "w_down": ParamSpec((e, mff, d), ("experts", "moe_mlp", "embed"), dtype=dt),
    }
    if cfg.num_shared_experts:
        sff = cfg.num_shared_experts * mff
        out["shared"] = {
            "w_gate": ParamSpec((d, sff), ("embed", "mlp"), dtype=dt),
            "w_up": ParamSpec((d, sff), ("embed", "mlp"), dtype=dt),
            "w_down": ParamSpec((sff, d), ("mlp", "embed"), dtype=dt),
        }
    return out


def _capacity(tokens_per_group: int, top_k: int, num_experts: int, cf: float) -> int:
    c = math.ceil(tokens_per_group * top_k * cf / num_experts)
    return max(int(c), 1)


def _group_dispatch(xg, gates_g, idx_g, p, cfg: ArchConfig, capacity: int):
    """MoE for ONE group. xg: (T, d); gates/idx: (T, k). Returns (T, d)."""
    t, d = xg.shape
    k = idx_g.shape[-1]
    e = cfg.num_experts
    cd = cfg.cdtype()

    flat_e = idx_g.reshape(-1)                       # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)            # (T*k,)
    flat_g = gates_g.reshape(-1)

    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[se]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, se * capacity + pos_in_e, e * capacity)  # OOB -> drop

    buf = jnp.zeros((e * capacity, d), cd).at[slot].set(xg[st].astype(cd), mode="drop")
    buf = buf.reshape(e, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(cd))
    y_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"].astype(cd))

    y_tok = y_buf.reshape(e * capacity, d)
    y_sorted = jnp.take(y_tok, jnp.minimum(slot, e * capacity - 1), axis=0)
    y_sorted = y_sorted * (sg * keep).astype(cd)[:, None]
    return jnp.zeros((t, d), cd).at[st].add(y_sorted)


def _routed_vmap(x, gates, idx, p, cfg: ArchConfig, capacity: int):
    return jax.vmap(
        lambda xg, gg, ig: _group_dispatch(xg, gg, ig, p, cfg, capacity)
    )(x, gates, idx)


def moe_apply(cfg: ArchConfig, p, x, *, train: bool = False):
    """x: (B, S, d) -> (B, S, d), plus load-balance aux loss (f32 scalar).

    The data-dependent sort/scatter dispatch is wrapped in a ``shard_map``
    manual over the batch mesh axes (model axis stays auto for the expert
    TP einsums): under plain pjit, GSPMD cannot keep the scatter sharded
    and replicates the dispatch buffers on every device (~135 GB/chip for
    deepseek prefill_32k) then all-reduces them. With the batch axes manual
    the dispatch is provably local per shard and the only collective left
    is the TP all-reduce of the down-projection. See EXPERIMENTS.md §Perf.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    cd = cfg.cdtype()
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(cd)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch eq. 4): E * sum_e f_e * p_e
    onehot = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32)  # (B,S,k,E)
    frac_tokens = onehot.sum(2).mean((0, 1))
    frac_prob = probs.mean((0, 1))
    aux = cfg.num_experts * jnp.sum(frac_tokens * frac_prob)

    capacity = _capacity(s, cfg.top_k, cfg.num_experts, cfg.capacity_factor)
    gates = gates.astype(cd)

    from repro.compat import current_mesh, shard_map as _shard_map_compat

    am = current_mesh()
    batch_axes = tuple(a for a in ("pod", "data") if a in am.axis_names)
    n_shards = 1
    for a in batch_axes:
        n_shards *= am.shape[a]
    # train gating: shard_map inside a rematerialized scan bwd currently
    # aborts XLA's SPMD partitioner (CloneAllReduce "Invalid binary
    # instruction opcode copy", XLA bug b/433785288); the serving paths
    # (prefill/decode) are proven and keep the fix. See EXPERIMENTS §Perf.
    if cfg.moe_shard_map and not train and batch_axes and b % n_shards == 0:
        spec = P(batch_axes, None, None)
        routed = _shard_map_compat(
            lambda xg, gg, ig, pp: _routed_vmap(xg, gg, ig, pp, cfg, capacity),
            mesh=am,
            in_specs=(spec, spec, spec, P()),
            out_specs=spec,
            axis_names=set(batch_axes),
            check_vma=False,
        )(x, gates, idx, {k: p[k] for k in ("w_gate", "w_up", "w_down")})
    else:
        routed = _routed_vmap(x, gates, idx, p, cfg, capacity)

    out = routed
    if cfg.num_shared_experts:
        sp = p["shared"]
        out = out + swiglu(x, sp["w_gate"], sp["w_up"], sp["w_down"])
    return out.astype(x.dtype), aux
