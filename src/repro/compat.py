"""jax version compat: the mesh/shard_map surface moved between jax 0.4.x
and newer releases. Every call site in repro goes through these wrappers so
the codebase runs on both (the container pins 0.4.x; newer jax keeps the
first branch).

  set_mesh(mesh)       jax.set_mesh(mesh) | the Mesh object itself (its own
                       context manager on 0.4.x)
  current_mesh()       jax.sharding.get_abstract_mesh() | the thread's
                       physical mesh
  shard_map(...)       jax.shard_map(..., axis_names=, check_vma=) |
                       jax.experimental.shard_map.shard_map(..., auto=,
                       check_rep=)
  cost_analysis_dict() compiled.cost_analysis() as a dict (0.4.x wraps it
                       in a single-element list)
"""

from __future__ import annotations

import jax

__all__ = ["set_mesh", "current_mesh", "shard_map", "make_mesh",
           "cost_analysis_dict"]


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` where it exists (0.4.35+ / 0.5+), else a manual
    ``Mesh`` over the first prod(shape) devices — same device order as
    ``make_mesh``'s default."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(shape), tuple(axis_names))
    import numpy as np

    n = 1
    for s in shape:
        n *= s
    devs = np.asarray(jax.devices()[:n]).reshape(tuple(shape))
    return jax.sharding.Mesh(devs, tuple(axis_names))


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax<=0.4: Mesh is its own context manager


def current_mesh():
    """The ambient mesh (empty mesh when none is installed)."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src.mesh import thread_resources

    return thread_resources.env.physical_mesh


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """``axis_names`` = manual axes (None = all); non-manual axes stay auto."""
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    manual = frozenset(axis_names) if axis_names is not None else frozenset(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a dict ({} when absent)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # jax<=0.4 returns [dict]
        cost = cost[0] if cost else {}
    return cost
