"""Pytree checkpointing to .npz (orbax-free, offline-friendly).

Leaves are flattened with their key paths as archive names; restore
rebuilds into the provided template tree (so dtypes/structure are always
validated against what the model expects).
"""

from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

__all__ = ["save", "restore", "save_metadata", "load_metadata"]

_SEP = "::"


def _path_str(path) -> str:
    return _SEP.join(str(jax.tree_util.keystr((k,))) for k in path)


def save(path: str | pathlib.Path, tree, *, step: int | None = None) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    np.savez(path, **arrays)
    if step is not None:
        save_metadata(path.with_suffix(".json"), {"step": step})


def restore(path: str | pathlib.Path, template):
    path = pathlib.Path(path)
    with np.load(path, allow_pickle=False) as z:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, tmpl in flat:
            key = _path_str(p)
            if key not in z:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = z[key]
            if tuple(arr.shape) != tuple(np.shape(tmpl)):
                raise ValueError(f"{key}: shape {arr.shape} != template {np.shape(tmpl)}")
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves)


def save_metadata(path, meta: dict) -> None:
    pathlib.Path(path).write_text(json.dumps(meta))


def load_metadata(path) -> dict:
    return json.loads(pathlib.Path(path).read_text())
