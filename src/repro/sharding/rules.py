"""Logical-axis -> mesh-axis resolution (MaxText-style, with divisibility
fallback).

Each param/cache/input leaf carries a tuple of logical axis names (from its
``ParamSpec``). A *rule set* maps every logical name to an ordered list of
candidate mesh-axis assignments; the resolver picks, per leaf dimension, the
first candidate whose mesh-axis product divides the dimension size and whose
axes are not already used by another dimension of the same leaf. Anything
unresolvable is replicated — e.g. whisper's 12 heads on a 16-way model axis
fall back to replication automatically instead of failing to lower.

Baseline TRAIN rules = FSDP("embed"->data) + TP("heads"/"mlp"/"vocab"->model)
+ DP("batch"->pod,data). Params/optimizer state are therefore fully sharded
(ZeRO-3-like) and grads reduce over the data axes.

SERVE rules keep weights model-sharded only (weight-stationary decode: no
per-step weight all-gathers) and shard long KV caches over the data axis
(sequence-parallel flash-decode; XLA inserts the cross-shard softmax
reductions).
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "TRAIN_RULES",
    "SERVE_RULES",
    "FLEET_RULES",
    "resolve_spec",
    "tree_shardings",
    "input_shardings",
    "fleet_partition_axes",
]

# logical axis -> ordered candidates; each candidate is a tuple of mesh axes
TRAIN_RULES: dict[str, list[tuple[str, ...]]] = {
    "batch": [("pod", "data"), ("data",), ("pod",)],
    "seq": [],
    "cache_seq": [("data",)],
    "embed": [("data",)],            # FSDP / ZeRO param+optimizer sharding
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [],
    "mlp": [("model",)],
    "moe_mlp": [("model",)],
    "experts": [],                   # baseline: experts replicated, TP inside
    "state": [],
    "conv": [],
    "layers": [],
}

SERVE_RULES: dict[str, list[tuple[str, ...]]] = {
    **TRAIN_RULES,
    "embed": [],                     # weight-stationary decode
}

# beyond-paper variant used in §Perf hillclimbing: expert-parallel MoE
EXPERT_PARALLEL_RULES: dict[str, list[tuple[str, ...]]] = {
    **TRAIN_RULES,
    "experts": [("model",)],
    "moe_mlp": [],
}

# fleet-of-fleets federation (fed/fleet.py): the leading "fleet" axis of
# every (F, K, ...) fleet tensor spreads over ALL mesh axes when F divides
# the full device count (edge fleets are embarrassingly parallel until the
# global merge), degrading to the data axis alone, then to replication.
# "learner" (the K axis) stays per-device: one fleet's solve/train is the
# unit of work.
FLEET_RULES: dict[str, list[tuple[str, ...]]] = {
    "fleet": [("pod", "data", "model"), ("data", "model"), ("data",)],
    "learner": [],
    "sample": [],
    "feature": [],
}


def fleet_partition_axes(f: int, mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes the fleet dimension of an ``(F, ...)`` tensor is
    actually split over under ``FLEET_RULES`` — i.e. the axes a global
    merge must ``psum`` across. Empty tuple = fleet axis replicated (the
    1-device test mesh, or an F no candidate divides)."""
    spec = resolve_spec(("fleet",), (f,), mesh, FLEET_RULES)
    entry = spec[0] if len(spec) else None
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def resolve_spec(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict[str, list[tuple[str, ...]]],
) -> P:
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, axes):
        assignment = None
        if name is not None:
            for cand in rules.get(name, []):
                if any(a not in mesh.shape for a in cand):
                    continue
                size = 1
                for a in cand:
                    size *= mesh.shape[a]
                if dim % size == 0 and not (set(cand) & used):
                    assignment = cand
                    used.update(cand)
                    break
        if assignment is None:
            out.append(None)
        elif len(assignment) == 1:
            out.append(assignment[0])
        else:
            out.append(assignment)
    return P(*out)


def tree_shardings(axes_tree, abstract_tree, mesh: Mesh, rules) -> object:
    """Map (logical-axes tree, ShapeDtypeStruct tree) -> NamedSharding tree."""

    def one(axes, aval):
        if axes is None or aval is None:   # empty subtree (e.g. cache["ffn"])
            return None
        return NamedSharding(mesh, resolve_spec(axes, aval.shape, mesh, rules))

    return jax.tree_util.tree_map(
        one, axes_tree, abstract_tree, is_leaf=lambda x: isinstance(x, tuple) or x is None
    )


# logical axes for model *inputs* by name
_INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "image_embeds": ("batch", "seq", None),
    "encoder_embeds": ("batch", "seq", None),
    "token": ("batch", None),
    "cache_len": (),
}


def input_shardings(input_specs: dict, mesh: Mesh, rules, cache_axes=None) -> dict:
    out = {}
    for name, spec in input_specs.items():
        if name == "cache":
            assert cache_axes is not None
            out[name] = tree_shardings(cache_axes, spec, mesh, rules)
        else:
            axes = _INPUT_AXES[name]
            out[name] = NamedSharding(mesh, resolve_spec(axes, spec.shape, mesh, rules))
    return out
