"""Asynchronous MEL orchestrator (paper Sec. II + V).

One global cycle of wall-clock budget ``T``:
  1. allocate (tau_k, d_k) with the chosen scheme (KKT+SAI / numeric / ETA /
     synchronous),
  2. dispatch the global model + per-learner batches,
  3. every learner runs tau_k local updates — implemented as a **masked
     lax.scan to max(tau)**, vmapped over the learner axis, so the whole
     heterogeneous fleet is one XLA program (and the learner axis can be
     sharded over the mesh's data axes for pod-scale fleets),
  4. staleness-aware aggregation (ref [10]) of the returned models.

The simulated wall-clock of a cycle is T by construction (constraint 7b of
the paper: every learner works the full cycle).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    Allocation,
    AllocationProblem,
    aggregate,
    fedavg_weights,
    solve_eta,
    solve_kkt_sai,
    solve_pgd_jax,
    solve_slsqp,
    solve_synchronous,
    staleness_weights,
)
from repro.core.staleness import avg_staleness, max_staleness
from repro.data.pipeline import Dataset, FederatedPartitioner

__all__ = ["MELConfig", "Orchestrator", "local_train"]

SCHEMES: dict[str, Callable[[AllocationProblem], Allocation]] = {
    "kkt_sai": solve_kkt_sai,
    "slsqp": solve_slsqp,
    "pgd": solve_pgd_jax,
    "eta": solve_eta,
    "sync": solve_synchronous,
}


@dataclasses.dataclass(frozen=True)
class MELConfig:
    T: float = 15.0
    total_samples: int = 6000          # d dispatched per cycle
    d_lower_frac: float = 0.25         # d_l = frac * d/K
    d_upper_frac: float = 3.0          # d_u = frac * d/K
    lr: float = 0.1
    scheme: str = "kkt_sai"
    aggregation: str = "staleness"     # staleness | fedavg
    staleness_gamma: float = 1.0


@functools.partial(jax.jit, static_argnames=("max_tau", "loss_fn"))
def local_train(global_params, x, y, mask, tau, lr, *, max_tau: int, loss_fn):
    """Run tau_k local GD updates on each of K learners, vectorized.

    x: (K, d_max, F); y, mask: (K, d_max); tau: (K,) int32.
    Returns stacked per-learner params (leading K axis).
    """

    def one_learner(params, xk, yk, mk, tau_k):
        batch = {"x": xk, "y": yk, "mask": mk}

        def step(p, i):
            def do(p):
                g = jax.grad(loss_fn)(p, batch)
                return jax.tree_util.tree_map(lambda pi, gi: pi - lr * gi, p, g)

            return jax.lax.cond(i < tau_k, do, lambda p: p, p), None

        p, _ = jax.lax.scan(step, params, jnp.arange(max_tau))
        return p

    k = x.shape[0]
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (k,) + p.shape), global_params
    )
    return jax.vmap(one_learner)(stacked, x, y, mask, tau)


class Orchestrator:
    def __init__(
        self,
        mel: MELConfig,
        problem: AllocationProblem,
        loss_fn,
        init_params,
        *,
        seed: int = 0,
    ):
        self.mel = mel
        self.problem = problem
        self.loss_fn = loss_fn
        self.params = init_params
        self.rng = np.random.default_rng(seed)
        self.allocation = SCHEMES[mel.scheme](problem)

    # -- one global cycle ---------------------------------------------------
    def run_cycle(self, shards: list[Dataset]) -> dict:
        alloc = self.allocation
        tau = np.asarray(alloc.tau)
        d = np.asarray(alloc.d)
        k = len(shards)
        d_max = int(d.max())
        feat = shards[0].x.shape[1]

        x = np.zeros((k, d_max, feat), np.float32)
        y = np.zeros((k, d_max), np.int32)
        m = np.zeros((k, d_max), np.float32)
        for i, sh in enumerate(shards):
            n = sh.size
            x[i, :n], y[i, :n], m[i, :n] = sh.x, sh.y, 1.0

        max_tau = max(int(tau.max()), 1)
        locals_ = local_train(
            self.params, jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
            jnp.asarray(tau), jnp.asarray(self.mel.lr, jnp.float32),
            max_tau=max_tau, loss_fn=self.loss_fn,
        )
        if self.mel.aggregation == "staleness":
            w = staleness_weights(tau, d, gamma=self.mel.staleness_gamma)
        else:
            w = fedavg_weights(d)
        self.params = aggregate(locals_, jnp.asarray(w))
        return {
            "max_staleness": max_staleness(tau),
            "avg_staleness": avg_staleness(tau),
            "tau": tau.copy(),
            "d": d.copy(),
            "wall_clock_s": self.mel.T,
        }

    # -- full run -------------------------------------------------------------
    def run(self, train: Dataset, cycles: int, *, eval_fn=None, reallocate: bool = False) -> list[dict]:
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        history = []
        for c in range(cycles):
            if reallocate and c:
                self.allocation = SCHEMES[self.mel.scheme](self.problem)
            shards = part.draw(self.allocation.d)
            rec = self.run_cycle(shards)
            rec["cycle"] = c
            rec["elapsed_s"] = (c + 1) * self.mel.T
            if eval_fn is not None:
                rec["accuracy"] = float(eval_fn(self.params))
            history.append(rec)
        return history
