"""Asynchronous MEL orchestrator (paper Sec. II + V).

One global cycle of wall-clock budget ``T``:
  1. allocate (tau_k, d_k) with the chosen scheme (KKT+SAI / numeric / ETA /
     synchronous),
  2. dispatch the global model + per-learner batches,
  3. every learner runs tau_k local updates — implemented as a **masked
     lax.scan to max(tau)**, vmapped over the learner axis, so the whole
     heterogeneous fleet is one XLA program (and the learner axis can be
     sharded over the mesh's data axes for pod-scale fleets),
  4. staleness-aware aggregation (ref [10]) of the returned models.

The simulated wall-clock of a cycle is T by construction (constraint 7b of
the paper: every learner works the full cycle).

Two execution paths:

  * ``run`` / ``run_cycle`` — eager: one host round-trip per global cycle
    (NumPy shard staging -> jit local_train -> aggregate). Supports
    per-cycle re-allocation and arbitrary host eval callbacks.
  * ``run_fused`` (or ``run(..., fused=True)``) — fast path: shards for
    ALL cycles are drawn up front, padded into one (C, K, d_max, F)
    device-resident tensor, and allocate -> local_train ->
    staleness-weighted aggregation runs as a single jitted ``lax.scan``
    over global cycles with the carried params buffer donated. The
    aggregation contraction goes through ``kernels.ops.fed_agg``
    (Pallas on TPU via ``use_pallas=True``). Trades C× shard memory for
    zero per-cycle host staging.

Adaptive in-scan reallocation: with ``reallocate=True`` (both paths) and a
``CapacityDrift`` model, the allocation program is re-solved EVERY cycle
on that cycle's drifted (c2, c1, c0) capacities. On the fused path the
re-solve happens *inside* the scan — ``core.solver_batched.batched_policy``
(KKT water-filling + SAI, equal-task eta, or masked PGD, per
``MELConfig.scheme``) runs on the traced (1, K) capacity state each cycle,
so a fleet-scale run with per-cycle reallocation is still ONE XLA program
with zero per-cycle host round-trips. The drifted capacity rows themselves
are generated inside the scan — ``CapacityDrift.factors_at`` on the traced
cycle index — so no host-precomputed coefficient path enters the program
(the eager twin still materializes ``coefficient_path`` host-side; the two
contexts agree on the f32 factors to 1 ULP and on the resulting integer
tau/d exactly, pinned by the equivalence tests). Shards are pre-drawn flat (the
partitioner's rng consumption depends only on the constant per-cycle
total) and split by the traced d inside the scan, so for the same seed the
tau/d history and the per-learner shard contents match the eager
reallocation path exactly (allocation math runs in f64 under
``enable_x64``; training stays f32).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (
    Allocation,
    AllocationProblem,
    CapacityDrift,
    aggregate,
    batched_policy,
    TRACED_POLICIES,
    fedavg_weights,
    solve_eta,
    solve_kkt_energy,
    solve_kkt_sai,
    solve_pgd_jax,
    solve_slsqp,
    solve_synchronous,
    staleness_weights,
)
from repro.core.availability import has_availability
from repro.core.solver_batched import apply_active_mask
from repro.core.staleness import avg_staleness, max_staleness
from repro.core.time_model import is_state_coupled
from repro.data.pipeline import Dataset, FederatedPartitioner

__all__ = ["MELConfig", "Orchestrator", "local_train", "local_train_stacked"]

SCHEMES: dict[str, Callable[[AllocationProblem], Allocation]] = {
    "kkt_sai": solve_kkt_sai,
    "kkt_energy": solve_kkt_energy,
    "slsqp": solve_slsqp,
    "pgd": solve_pgd_jax,
    "eta": solve_eta,
    "sync": solve_synchronous,
}

# schemes whose traced policy takes the extra (e2, e1, e0, e_budget) operand
# (with e_budget = +inf the operand is decision-inert, so listing a scheme
# here never changes its energy-blind allocations)
ENERGY_SCHEMES = frozenset({"kkt_energy", "pgd"})


@dataclasses.dataclass(frozen=True)
class MELConfig:
    T: float = 15.0
    total_samples: int = 6000          # d dispatched per cycle
    d_lower_frac: float = 0.25         # d_l = frac * d/K
    d_upper_frac: float = 3.0          # d_u = frac * d/K
    lr: float = 0.1
    scheme: str = "kkt_sai"
    aggregation: str = "staleness"     # staleness | fedavg
    staleness_gamma: float = 1.0


@functools.partial(jax.jit, static_argnames=("max_tau", "loss_fn"))
def local_train_stacked(stacked, x, y, mask, tau, lr, *, max_tau: int, loss_fn):
    """Run tau_k local GD updates on each of K learners, vectorized, where
    every learner starts from its OWN params (leading K axis on each leaf) —
    the general form the event-driven async engine needs, since in-flight
    learners hold different dispatched model versions.

    x: (K, d_max, F); y, mask: (K, d_max); tau: (K,) int32.
    Returns stacked per-learner params (leading K axis).
    """

    def one_learner(params, xk, yk, mk, tau_k):
        batch = {"x": xk, "y": yk, "mask": mk}

        def step(p, i):
            def do(p):
                g = jax.grad(loss_fn)(p, batch)
                return jax.tree_util.tree_map(lambda pi, gi: pi - lr * gi, p, g)

            return jax.lax.cond(i < tau_k, do, lambda p: p, p), None

        p, _ = jax.lax.scan(step, params, jnp.arange(max_tau))
        return p

    return jax.vmap(one_learner)(stacked, x, y, mask, tau)


def local_train(global_params, x, y, mask, tau, lr, *, max_tau: int, loss_fn):
    """``local_train_stacked`` with every learner starting from the same
    global model (the paper's cycle-gated dispatch)."""
    k = x.shape[0]
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (k,) + p.shape), global_params
    )
    return local_train_stacked(
        stacked, x, y, mask, tau, lr, max_tau=max_tau, loss_fn=loss_fn
    )


def _stage_shards(shards: "list[Dataset]", d_max: int, feat: int):
    """Zero-pad per-learner shards into (K, d_max, ...) host arrays with a
    validity mask — shared by the eager per-cycle path and the fused
    pre-staging so their padding semantics cannot diverge."""
    k = len(shards)
    x = np.zeros((k, d_max, feat), np.float32)
    y = np.zeros((k, d_max), np.int32)
    m = np.zeros((k, d_max), np.float32)
    for i, sh in enumerate(shards):
        n = sh.size
        x[i, :n], y[i, :n], m[i, :n] = sh.x, sh.y, 1.0
    return x, y, m


@functools.partial(
    jax.jit,
    static_argnames=("max_tau", "loss_fn", "eval_fn", "use_pallas", "interpret"),
    donate_argnums=(0,),
)
def _fused_cycles(params, xs, ys, ms, tau, weights, lr, eval_x, eval_y, *,
                  max_tau: int, loss_fn, eval_fn, use_pallas: bool,
                  interpret: bool):
    """One XLA program for C global cycles: scan(allocated local_train ->
    fed_agg) with the params carry donated. xs: (C, K, d_max, F);
    ys/ms: (C, K, d_max); tau/weights: (K,)."""
    from repro.kernels import ops

    def one_cycle(p, batch):
        x, y, m = batch
        k = x.shape[0]
        disp = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf, (k,) + leaf.shape), p
        )
        new, _ = ops.train_agg_step(
            disp, x, y, m, tau, weights, lr, loss_fn=loss_fn,
            max_tau=max_tau, use_pallas=use_pallas, interpret=interpret,
        )
        acc = eval_fn(new, eval_x, eval_y) if eval_fn is not None else jnp.float32(0)
        return new, acc

    return jax.lax.scan(one_cycle, params, (xs, ys, ms))


def _local_train_dynamic(params, x, y, mask, tau, lr, *, loss_fn):
    """Traced-tau twin of ``local_train``: a ``while_loop`` to the TRACED
    fleet-max tau (so a reallocating scan only pays for the updates each
    cycle actually runs, not a static worst-case bound), with per-learner
    masked updates. The per-step select matches ``local_train``'s vmapped
    ``lax.cond`` numerics exactly, so both produce identical params."""
    k = x.shape[0]
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (k,) + p.shape), params
    )
    tau_max = jnp.max(tau)

    def one_step(i, pk, xk, yk, mk, tau_k):
        batch = {"x": xk, "y": yk, "mask": mk}
        g = jax.grad(loss_fn)(pk, batch)
        return jax.tree_util.tree_map(
            lambda p, gi: jnp.where(i < tau_k, p - lr * gi, p), pk, g
        )

    def body(state):
        p, i = state
        p = jax.vmap(functools.partial(one_step, i))(p, x, y, mask, tau)
        return p, i + 1

    p, _ = jax.lax.while_loop(
        lambda s: s[1] < tau_max, body, (stacked, jnp.zeros((), tau.dtype))
    )
    return p


@functools.lru_cache(maxsize=None)
def _jitted_policy(scheme: str):
    """One jitted wrapper per scheme so per-cycle eager re-solves hit the
    same compilation cache (the fused path inlines the identical traced
    policy inside its scan, and ``fed.async_engine`` re-solves through the
    same wrapper at every redispatch)."""
    return jax.jit(batched_policy(scheme))


def policy_problem_args(prob: AllocationProblem):
    """Static (1,)/(1, K) f64 problem tensors for a single-fleet call into a
    ``batched_policy`` — shared by the orchestrator's re-solves and the
    async engine's per-block allocation so all consumers see identical
    values."""
    k = prob.num_learners
    return (
        np.asarray([prob.T], np.float64),
        np.asarray([prob.total_samples], np.int64),
        np.full((1, k), float(prob.d_lower), np.float64),
        np.full((1, k), float(prob.d_upper), np.float64),
        np.ones((1, k), bool),
    )


def policy_energy_args(prob: AllocationProblem):
    """Static (1, K) f64 energy rows ``(e2, e1, e0, e_budget)`` for the
    ``kkt_energy`` traced policy — the problem's attached
    ``EnergyModel``/budget, or the zero-coefficient / infinite-budget
    defaults (under which the policy is decision-identical to
    ``kkt_sai``) when none is attached."""
    rows = prob.energy_rows()
    if rows is None:
        k = prob.num_learners
        z = np.zeros((1, k), np.float64)
        return z, z.copy(), z.copy(), np.full((1, k), np.inf)
    e2, e1, e0, eb = rows
    return (
        np.asarray(e2, np.float64)[None],
        np.asarray(e1, np.float64)[None],
        np.asarray(e0, np.float64)[None],
        np.asarray(eb, np.float64)[None],
    )


def require_standalone_rows(drift, *, remedy: str) -> None:
    """THE shared guard for code paths that need standalone capacity rows
    fixed up front: a state-coupled drift (``QueueDrift``) or an
    availability process has no such rows — they depend on the run state
    (past allocations, who was online) — so every consumer rejects them
    through this one helper with one actionable message. ``remedy`` names
    what the caller should do instead."""
    if drift is None:
        return
    avail = has_availability(drift)
    if not avail and not is_state_coupled(drift):
        return
    kind = "an availability process" if avail else "a state-coupled drift"
    raise TypeError(
        f"{type(drift).__name__} is {kind} and has no standalone "
        f"coefficient path (its rows depend on the run state); {remedy}"
    )


def coefficient_rows(prob: AllocationProblem, drift: CapacityDrift | None,
                     cycles: int):
    """(C, K) f64 capacity rows per global cycle / drift block — drifted
    when a CapacityDrift is attached, else the base coefficients tiled.
    THE shared row source for the orchestrator's eager re-solves and the
    async engine's schedule (their bitwise equivalence depends on it).
    State-coupled drifts (``QueueDrift``) and availability processes have
    no standalone row path — their rows/masks depend on the run state —
    so they are rejected here; callers roll rows and allocations out
    together via ``solve_rows_state_coupled`` /
    ``solve_rows_availability`` / the fused scan instead."""
    tm = prob.time_model
    require_standalone_rows(
        drift,
        remedy="roll rows and allocations out together via "
        "drift.rollout(...), solve_rows_state_coupled(...) or "
        "solve_rows_availability(...)",
    )
    if drift is None:
        tile = lambda a: np.broadcast_to(
            a, (cycles, tm.num_learners)
        ).astype(np.float64)
        return tile(tm.c2), tile(tm.c1), tile(tm.c0)
    return drift.coefficient_path(tm, cycles)


def solve_policy_row(scheme: str, c2r, c1r, c0r, prob: AllocationProblem,
                     *, label: str, active=None, e_budget=None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """One fleet's (tau, d) on a single (K,) capacity row through the
    jitted traced policy, f64 under ``enable_x64`` — THE single-row solve
    shared by the orchestrator's eager per-cycle re-solve and the async
    engine's per-block allocation (the barrier-equivalence guarantee
    depends on both paths using this exact code). Raises ValueError with
    ``label`` naming the infeasible capacity state.

    ``active`` (optional ``(K,)`` bool) masks offline learners out of the
    solve: their slots get the ``BatchedProblems`` padded-slot semantics
    and the sample budget is clipped into the live fleet's box
    (``apply_active_mask``), so tau/d budget flows to online learners.
    An all-offline row short-circuits to zeros without a policy call.

    ``e_budget`` (optional ``(K,)`` joules, ``kkt_energy`` only) tightens
    the problem's static per-learner budget with a per-dispatch one —
    min of the two — so a ``BatteryDrift`` charge state caps what each
    dispatch may spend."""
    policy = _jitted_policy(scheme)
    T1, total1, lo1, hi1, valid1 = policy_problem_args(prob)
    k = prob.num_learners
    energy1 = None
    if scheme in ENERGY_SCHEMES:
        e2r, e1r, e0r, ebr = policy_energy_args(prob)
        if e_budget is not None:
            ebr = np.minimum(ebr, np.asarray(e_budget, np.float64).reshape(1, k))
        energy1 = (e2r, e1r, e0r, ebr)
    elif e_budget is not None:
        raise ValueError(
            f"e_budget needs an energy-aware scheme "
            f"({' | '.join(sorted(ENERGY_SCHEMES))}); scheme {scheme!r} "
            "cannot honor it"
        )
    if active is not None:
        act = np.asarray(active, bool).reshape(1, k)
        if not act.any():
            z = np.zeros(k, np.int64)
            return z, z.copy()
    with enable_x64():
        total_j, lo_j, hi_j, valid_j = (
            jnp.asarray(total1), jnp.asarray(lo1),
            jnp.asarray(hi1), jnp.asarray(valid1),
        )
        if active is not None:
            total_j, lo_j, hi_j, valid_j = apply_active_mask(
                total_j, lo_j, hi_j, valid_j, jnp.asarray(act)
            )
        base_args = (
            jnp.asarray(c2r[None]), jnp.asarray(c1r[None]),
            jnp.asarray(c0r[None]), jnp.asarray(T1), total_j,
            lo_j, hi_j, valid_j,
        )
        if energy1 is not None:
            en_j = tuple(jnp.asarray(e) for e in energy1)
            tau, d, ok = policy(*base_args, en_j)
        else:
            tau, d, ok = policy(*base_args)
        tau = np.asarray(tau[0]); d = np.asarray(d[0]); ok = bool(ok[0])
    if not ok:
        sub = (
            f"; {int(np.asarray(active, bool).sum())}/{k} learners online"
            if active is not None else ""
        )
        raise ValueError(
            "infeasible: even with tau=0 the deadline T cannot absorb "
            f"d samples ({label}{sub})"
        )
    return tau.astype(np.int64), d.astype(np.int64)


def solve_rows_state_coupled(scheme: str, drift, prob: AllocationProblem,
                             cycles: int, *, label: str, lazy: bool = False):
    """Joint host rollout of capacity rows AND allocations for a
    state-coupled drift (``QueueDrift``): cycle by cycle, the drifted row
    is produced from the current drift state, solved through the SAME
    jitted traced policy as every other re-solve path
    (``solve_policy_row``), and the state advanced with the solved
    allocation. Shared by the orchestrator's eager reallocation path and
    the async engine's scheduler so both replay the fused scan's coupled
    trajectory. ``label`` is a format string receiving the cycle index for
    infeasibility errors.

    Returns ``((c2s, c1s, c0s), (taus, ds))``, or with ``lazy=True`` the
    underlying per-cycle iterator (``QueueDrift.rollout_iter``) so the
    caller can interleave work between solves — the eager orchestrator
    uses this to train the feasible prefix before an infeasible cycle
    raises, mirroring the fused scan's in-scan guard."""

    def _solve(c, c2r, c1r, c0r):
        return solve_policy_row(
            scheme, c2r, c1r, c0r, prob, label=label.format(c)
        )

    if lazy:
        return drift.rollout_iter(prob.time_model, cycles, _solve)
    return drift.rollout(prob.time_model, cycles, _solve)


def solve_rows_availability(scheme: str, drift, prob: AllocationProblem,
                            cycles: int, *, label: str):
    """Joint host rollout of capacity rows, allocations AND online masks
    for an availability process: per cycle, the online mask is read from
    the availability state, the (possibly base-drifted or
    backlog-coupled) capacity row materialized, the *masked* allocation
    solved through the SAME jitted traced policy as every other re-solve
    path (``solve_policy_row(active=...)``), and the joint state advanced
    with the solved allocation. Offline learners get tau = d = 0 and the
    budget degrades to the live fleet's box instead of going infeasible;
    all-offline cycles solve to all-zeros. ``label`` is a format string
    receiving the cycle index.

    Returns ``((c2s, c1s, c0s), (taus, ds), masks)`` with shapes
    ``(C, K)`` (masks bool) — the per-cycle numerics mirror
    ``QueueDrift.rollout_iter`` (f64 rows under ``enable_x64``).

    When the drift also exposes ``budget_at`` (a :class:`BatteryDrift`)
    and the scheme is energy-aware, each cycle's solve is additionally
    capped by the current per-learner charge — no dispatched task can
    cost more than its battery holds."""
    tm = prob.time_model
    k = tm.num_learners
    budgeted = scheme in ENERGY_SCHEMES and hasattr(drift, "budget_at")
    c2s = np.empty((cycles, k)); c1s = np.empty((cycles, k))
    c0s = np.empty((cycles, k))
    taus = np.zeros((cycles, k), np.int64)
    ds = np.zeros((cycles, k), np.int64)
    masks = np.zeros((cycles, k), bool)
    state = drift.state_init(k)
    for c in range(cycles):
        mask = np.asarray(drift.online_at(c, k, state))
        with enable_x64():
            clock, rate = drift.factors_at(c, k, state)
            clock = np.asarray(clock, np.float64)
            rate = np.asarray(rate, np.float64)
        c2r = tm.c2 / clock
        c1r = tm.c1 / rate
        c0r = tm.c0 / rate
        e_budget = drift.budget_at(c, k, state) if budgeted else None
        tau, d = solve_policy_row(
            scheme, c2r, c1r, c0r, prob, label=label.format(c), active=mask,
            e_budget=e_budget,
        )
        state = drift.state_update(c, state, jnp.asarray(tau), jnp.asarray(d))
        masks[c] = mask
        c2s[c], c1s[c], c0s[c] = c2r, c1r, c0r
        taus[c], ds[c] = tau, d
    return (c2s, c1s, c0s), (taus, ds), masks


def _weights_traced(tau, d, *, aggregation: str, gamma):
    """Traced twin of staleness_weights / fedavg_weights (f64 in, f32 out
    matches the eager numpy arithmetic followed by aggregate's cast)."""
    tau_f = tau.astype(jnp.float64)
    d_f = d.astype(jnp.float64)
    if aggregation == "staleness":
        w = d_f / (1.0 + gamma * (jnp.max(tau_f) - tau_f))
    else:
        w = d_f
    return (w / w.sum()).astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("d_cap", "loss_fn", "eval_fn", "policy",
                     "aggregation", "drift", "use_pallas", "interpret"),
    donate_argnums=(0,),
)
def _fused_realloc_cycles(params, state0, xs, ys, c2b, c1b, c0b, T1, total1,
                          lo1, hi1, valid1, energy1, gamma, lr, eval_x,
                          eval_y, *, d_cap: int, loss_fn, eval_fn, policy,
                          aggregation: str, drift, use_pallas: bool,
                          interpret: bool):
    """One XLA program for C global cycles WITH per-cycle reallocation:
    scan(drift capacities at the traced cycle index/state -> policy-solve
    -> in-scan feasibility guard -> shard split by traced d -> dynamic
    local_train -> fed_agg).

    Arguments
    ---------
    params : model pytree (the scan carry; this buffer is donated)
    state0 : (K,) f32 drift state for a state-coupled ``drift``
        (``QueueDrift.state_init``), else a (0,) placeholder
    xs, ys : (C, total, F) / (C, total) flat per-cycle sample tensors
    c2b, c1b, c0b : (1, K) f64 BASE capacity rows — per-cycle drifted rows
        are generated INSIDE the scan by ``drift.factors_at`` on the
        traced cycle index (and, for a state-coupled drift, the carried
        state), so no host-precomputed coefficient path enters the
        program; ``drift=None`` runs the static rows as-is
    T1, total1 : (1,); lo1/hi1/valid1 : (1, K) — the policy problem args
    energy1 : None for an energy-blind ``policy``, else the (1, K) f64
        ``(e2, e1, e0, e_budget)`` operand the ``kkt_energy`` policy takes
        (None-ness is pytree structure, so the branch resolves at trace
        time)

    Feasibility is guarded IN-SCAN: a cycle whose capacity state cannot
    absorb the sample budget latches a ``dead`` flag; that cycle and every
    later one pass the params (and drift state) through untouched, so the
    scan never trains through a neutralized allocation. The per-cycle
    ``feas`` flags are returned for the host to raise on.

    Must run under ``enable_x64`` so the allocation math stays f64 while
    training stays f32 (exogenous drift draws are f32-pinned either way,
    so the traced rows track ``CapacityDrift.coefficient_path`` to 1 f32
    ULP — and ``QueueDrift.rollout`` bitwise — and yield the same integer
    allocations).

    Returns ``((params, state, dead), (accs, taus, ds, feas))`` with
    per-cycle stacked outputs."""
    from repro.kernels import ops

    total = xs.shape[1]
    k = c2b.shape[1]
    state_coupled = is_state_coupled(drift)

    def one_cycle(carry, inp):
        p, qstate, dead = carry
        x_flat, y_flat, cyc = inp
        if drift is None:
            c2, c1, c0 = c2b, c1b, c0b
        else:
            if state_coupled:
                clock, rate = drift.factors_at(cyc, k, qstate)
            else:
                clock, rate = drift.factors_at(cyc, k)
            c2 = c2b / clock.astype(c2b.dtype)[None]
            c1 = c1b / rate.astype(c1b.dtype)[None]
            c0 = c0b / rate.astype(c0b.dtype)[None]
        if energy1 is None:
            tau_b, d_b, feas_b = policy(
                c2, c1, c0, T1, total1, lo1, hi1, valid1
            )
        else:
            tau_b, d_b, feas_b = policy(
                c2, c1, c0, T1, total1, lo1, hi1, valid1, energy1
            )
        tau, d, feas = tau_b[0], d_b[0], feas_b[0]
        ok = feas & jnp.logical_not(dead)

        def do_cycle(p):
            w = _weights_traced(tau, d, aggregation=aggregation, gamma=gamma)
            # split the flat draw into per-learner shards by the traced d —
            # identical contents to the eager path's contiguous slicing
            off = jnp.cumsum(d) - d
            j = jnp.arange(d_cap, dtype=d.dtype)
            gidx = off[:, None] + j[None, :]
            m = j[None, :] < d[:, None]
            safe = jnp.clip(gidx, 0, total - 1)
            x = jnp.take(x_flat, safe, axis=0)          # (K, d_cap, F)
            y = jnp.take(y_flat, safe, axis=0)          # (K, d_cap)

            if use_pallas:
                # megakernel path: the in-kernel fori_loop bounds itself
                # by the traced max(tau), so no static max_tau is needed
                disp = jax.tree_util.tree_map(
                    lambda leaf: jnp.broadcast_to(leaf, (k,) + leaf.shape), p
                )
                new, _ = ops.train_agg_step(
                    disp, x, y, m.astype(jnp.float32), tau, w, lr,
                    loss_fn=loss_fn, use_pallas=True, interpret=interpret,
                )
            else:
                locals_ = _local_train_dynamic(
                    p, x, y, m.astype(jnp.float32), tau, lr, loss_fn=loss_fn,
                )
                new = jax.tree_util.tree_map(
                    lambda leaf: ops.fed_agg(leaf, w), locals_
                )
            acc = (eval_fn(new, eval_x, eval_y).astype(jnp.float32)
                   if eval_fn is not None else jnp.float32(0))
            return new, acc

        def skip_cycle(p):
            return p, jnp.float32(0)

        p_new, acc = jax.lax.cond(ok, do_cycle, skip_cycle, p)
        if state_coupled:
            q_new = drift.state_update(cyc, qstate, tau, d)
            qstate = jnp.where(ok, q_new, qstate)
        return (p_new, qstate, dead | ~feas), (acc, tau, d, feas)

    cycle_idx = jnp.arange(xs.shape[0])
    carry0 = (params, state0, jnp.zeros((), bool))
    return jax.lax.scan(one_cycle, carry0, (xs, ys, cycle_idx))


class Orchestrator:
    def __init__(
        self,
        mel: MELConfig,
        problem: AllocationProblem,
        loss_fn,
        init_params,
        *,
        seed: int = 0,
        drift: CapacityDrift | None = None,
    ):
        self.mel = mel
        self.problem = problem
        self.loss_fn = loss_fn
        self.params = init_params
        self.rng = np.random.default_rng(seed)
        if has_availability(drift):
            # the cycle-gated orchestrator has no offline semantics (every
            # learner participates in every barrier round by construction)
            raise TypeError(
                f"{type(drift).__name__} models client availability; the "
                "cycle-gated Orchestrator has no offline semantics — run "
                "churn scenarios through fed.async_engine.AsyncFedEngine"
            )
        self.drift = drift
        self.allocation = SCHEMES[mel.scheme](problem)

    # -- time-varying capacities --------------------------------------------
    def _coefficient_path(self, cycles: int):
        """(C, K) f64 capacity rows — drifted when a CapacityDrift is
        attached, else the base coefficients tiled (static capacities)."""
        return coefficient_rows(self.problem, self.drift, cycles)

    def _policy_args(self):
        """Static (1,)/(1, K) f64 problem tensors shared by every per-cycle
        re-solve (eager and in-scan paths consume identical values)."""
        return policy_problem_args(self.problem)

    def _reallocate_cycle(self, coeff_path, c: int) -> Allocation:
        """Eager per-cycle re-solve on cycle c's capacity row (drifted or
        tiled-static), through the same traced policy the fused scan
        inlines (bitwise-identical tau/d between the two paths under
        x64)."""
        c2s, c1s, c0s = coeff_path
        tau, d = solve_policy_row(
            self.mel.scheme, c2s[c], c1s[c], c0s[c], self.problem,
            label=f"drifted capacities at cycle {c}",
        )
        return Allocation(tau=tau, d=d, method=f"{self.mel.scheme}_drift")

    # -- one global cycle ---------------------------------------------------
    def run_cycle(self, shards: list[Dataset]) -> dict:
        alloc = self.allocation
        tau = np.asarray(alloc.tau)
        d = np.asarray(alloc.d)
        d_max = int(d.max())
        feat = shards[0].x.shape[1]
        x, y, m = _stage_shards(shards, d_max, feat)

        max_tau = max(int(tau.max()), 1)
        locals_ = local_train(
            self.params, jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
            jnp.asarray(tau), jnp.asarray(self.mel.lr, jnp.float32),
            max_tau=max_tau, loss_fn=self.loss_fn,
        )
        if self.mel.aggregation == "staleness":
            w = staleness_weights(tau, d, gamma=self.mel.staleness_gamma)
        else:
            w = fedavg_weights(d)
        self.params = aggregate(locals_, jnp.asarray(w))
        return {
            "max_staleness": max_staleness(tau),
            "avg_staleness": avg_staleness(tau),
            "tau": tau.copy(),
            "d": d.copy(),
            "wall_clock_s": self.mel.T,
        }

    # -- full run -------------------------------------------------------------
    def run(
        self,
        train: Dataset,
        cycles: int,
        *,
        eval_fn=None,
        reallocate: bool = False,
        fused: bool = False,
        eval_batch=None,
        use_pallas: bool = False,
        interpret: bool = False,
    ) -> list[dict]:
        if fused:
            return self.run_fused(
                train, cycles, eval_fn=eval_fn, eval_batch=eval_batch,
                use_pallas=use_pallas, interpret=interpret,
                reallocate=reallocate,
            )
        if self.drift is not None and not reallocate:
            import warnings

            # a state-coupled drift cannot even be *simulated* statically
            # (its rows need the dispatched allocations) — same shared
            # rejection as coefficient_rows, not a silent base-capacity run
            require_standalone_rows(
                self.drift,
                remedy="run with reallocate=True so rows and allocations "
                "roll out together",
            )
            warnings.warn(
                "a CapacityDrift is attached but reallocate=False: the run "
                "simulates the BASE capacities and the drift is ignored "
                "(static-under-drift staleness analysis lives in "
                "fed.simulation.drift_staleness_sweep)", stacklevel=2,
            )
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        # reallocate routes through the traced policy whenever the scheme
        # has one (same solver the fused scan inlines -> exact-match twin);
        # schemes without a policy (slsqp, sync) keep the legacy per-problem
        # re-solve, which only reacts to drift-free problem changes.
        coeff_path = None
        rollout = None
        if (reallocate and is_state_coupled(self.drift)
                and self.mel.scheme not in TRACED_POLICIES):
            # the legacy per-problem re-solve below cannot see drifted
            # capacities at all: silently simulating static capacities
            # would mislabel the run (the async engine and
            # coefficient_rows reject this configuration too)
            raise ValueError(
                "state-coupled drift needs a traced policy scheme "
                f"({' | '.join(TRACED_POLICIES)}); scheme "
                f"{self.mel.scheme!r} has none"
            )
        if reallocate and self.mel.scheme in TRACED_POLICIES:
            if is_state_coupled(self.drift):
                # rows depend on the allocations: roll both out together
                # (the host twin of the fused scan's coupled carry).
                # Lazy: each cycle solves right before it trains, so an
                # infeasible cycle raises AFTER the feasible prefix ran —
                # the same params-state contract as the fused in-scan
                # guard.
                rollout = solve_rows_state_coupled(
                    self.mel.scheme, self.drift, self.problem, cycles,
                    label="drifted capacities at cycle {}", lazy=True,
                )
            else:
                coeff_path = self._coefficient_path(cycles)
        history = []
        for c in range(cycles):
            if rollout is not None:
                _, _, _, tau_c, d_c = next(rollout)
                self.allocation = Allocation(
                    tau=tau_c, d=d_c, method=f"{self.mel.scheme}_drift",
                )
            elif coeff_path is not None:
                self.allocation = self._reallocate_cycle(coeff_path, c)
            elif reallocate and c:
                self.allocation = SCHEMES[self.mel.scheme](self.problem)
            shards = part.draw(self.allocation.d)
            rec = self.run_cycle(shards)
            rec["cycle"] = c
            rec["elapsed_s"] = (c + 1) * self.mel.T
            if eval_fn is not None:
                rec["accuracy"] = float(eval_fn(self.params))
            history.append(rec)
        return history

    # -- fused fast path ------------------------------------------------------
    def run_fused(
        self,
        train: Dataset,
        cycles: int,
        *,
        eval_fn=None,
        eval_batch=None,
        use_pallas: bool = False,
        interpret: bool = False,
        reallocate: bool = False,
    ) -> list[dict]:
        """Fused scan-over-cycles twin of ``run``: same shard draws, same
        allocation, one jitted lax.scan instead of C host round-trips.

        Parameters
        ----------
        train : Dataset to draw per-cycle shards from (identical rng
            consumption to the eager path for the same engine seed).
        cycles : number of global cycles C to scan over.
        eval_fn : optional jit-traceable ``(params, x, y) -> scalar``
            (e.g. ``mlp.accuracy``), evaluated inside the scan each cycle
            on ``eval_batch``; None skips per-cycle eval.
        eval_batch : ``(x, y)`` arrays; required with ``eval_fn``.
        use_pallas, interpret : route the whole per-cycle train+aggregate
            body through the ``ops.train_agg_step`` Pallas megakernel
            (``interpret=True`` emulates it on CPU); the default runs the
            unfused ``local_train_stacked`` + ``fed_agg`` composition.
        reallocate : re-solve the allocation INSIDE the scan each cycle on
            that cycle's capacity state via the traced
            ``batched_policy(mel.scheme)`` — still one XLA program, zero
            per-cycle host round-trips. With a ``CapacityDrift`` the rows
            are generated in-scan from ``factors_at`` on the traced cycle
            index; with a state-coupled ``QueueDrift`` additionally from
            the drift state carried through the scan (no host coefficient
            path enters the program in either case). The tau/d history and
            shard contents reproduce the eager ``run(reallocate=True)``
            path exactly for the same seed. Feasibility is guarded
            in-scan: an infeasible cycle stops all further updates and the
            call raises ValueError naming it, with ``self.params`` holding
            the state trained through the feasible prefix.

        Returns
        -------
        One history dict per cycle (tau, d, staleness metrics, elapsed
        virtual time, and ``accuracy`` when ``eval_fn`` is given) —
        the same rows the eager ``run`` produces.
        """
        if reallocate:
            return self._run_fused_realloc(
                train, cycles, eval_fn=eval_fn, eval_batch=eval_batch,
                use_pallas=use_pallas, interpret=interpret,
            )
        if self.drift is not None:
            import warnings

            require_standalone_rows(
                self.drift,
                remedy="run with reallocate=True so rows and allocations "
                "roll out together",
            )
            warnings.warn(
                "a CapacityDrift is attached but reallocate=False: the run "
                "simulates the BASE capacities and the drift is ignored "
                "(static-under-drift staleness analysis lives in "
                "fed.simulation.drift_staleness_sweep)", stacklevel=2,
            )
        alloc = self.allocation
        tau = np.asarray(alloc.tau)
        d = np.asarray(alloc.d)
        k = len(d)
        d_max = int(d.max())
        feat = train.x.shape[1]

        # identical shard sequence to the eager path (same rng consumption)
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        xs = np.zeros((cycles, k, d_max, feat), np.float32)
        ys = np.zeros((cycles, k, d_max), np.int32)
        ms = np.zeros((cycles, k, d_max), np.float32)
        for c in range(cycles):
            xs[c], ys[c], ms[c] = _stage_shards(part.draw(d), d_max, feat)

        if self.mel.aggregation == "staleness":
            w = staleness_weights(tau, d, gamma=self.mel.staleness_gamma)
        else:
            w = fedavg_weights(d)

        if eval_fn is not None and eval_batch is None:
            raise ValueError("run_fused needs eval_batch=(x, y) with eval_fn")
        ex = jnp.asarray(eval_batch[0]) if eval_fn is not None else None
        ey = jnp.asarray(eval_batch[1]) if eval_fn is not None else None

        max_tau = max(int(tau.max()), 1)
        self.params, accs = _fused_cycles(
            self.params, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ms),
            jnp.asarray(tau), jnp.asarray(w),
            jnp.asarray(self.mel.lr, jnp.float32), ex, ey,
            max_tau=max_tau, loss_fn=self.loss_fn, eval_fn=eval_fn,
            use_pallas=use_pallas, interpret=interpret,
        )
        accs = np.asarray(accs)

        history = []
        for c in range(cycles):
            rec = {
                "max_staleness": max_staleness(tau),
                "avg_staleness": avg_staleness(tau),
                "tau": tau.copy(),
                "d": d.copy(),
                "wall_clock_s": self.mel.T,
                "cycle": c,
                "elapsed_s": (c + 1) * self.mel.T,
            }
            if eval_fn is not None:
                rec["accuracy"] = float(accs[c])
            history.append(rec)
        return history

    # -- fused fast path with in-scan reallocation ----------------------------
    def _run_fused_realloc(
        self,
        train: Dataset,
        cycles: int,
        *,
        eval_fn=None,
        eval_batch=None,
        use_pallas: bool = False,
        interpret: bool = False,
    ) -> list[dict]:
        prob = self.problem
        policy = batched_policy(self.mel.scheme)  # raises for slsqp/sync
        if self.mel.aggregation not in ("staleness", "fedavg"):
            raise ValueError(f"unknown aggregation {self.mel.aggregation!r}")
        total = prob.total_samples
        feat = train.x.shape[1]
        T1, total1, lo1, hi1, valid1 = self._policy_args()
        energy1 = (policy_energy_args(prob)
                   if self.mel.scheme in ENERGY_SCHEMES else None)
        tm = prob.time_model
        c2b = np.asarray(tm.c2[None], np.float64)
        c1b = np.asarray(tm.c1[None], np.float64)
        c0b = np.asarray(tm.c0[None], np.float64)

        # Feasibility is guarded IN-SCAN (see _fused_realloc_cycles): an
        # infeasible cycle latches the scan dead so no training runs on a
        # neutralized allocation, and the host raises from the returned
        # flags below. No host coefficient path enters the fused route at
        # all — the scan regenerates every row from ``factors_at`` on the
        # traced cycle index (and the carried state for a state-coupled
        # drift, which a host pre-check could not replay).

        # d_k <= d_upper bounds the shard split width (tau needs no static
        # bound: the dynamic trainer while-loops to each cycle's traced max)
        d_cap = int(prob.d_upper)

        # identical rng consumption to the eager path: one flat draw of the
        # (constant) per-cycle total; the split by d happens in the scan
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        xs = np.zeros((cycles, total, feat), np.float32)
        ys = np.zeros((cycles, total), np.int32)
        for c in range(cycles):
            idx = part.draw_indices(total)
            xs[c] = train.x[idx]
            ys[c] = train.y[idx]

        if eval_fn is not None and eval_batch is None:
            raise ValueError("run_fused needs eval_batch=(x, y) with eval_fn")
        ex = jnp.asarray(eval_batch[0]) if eval_fn is not None else None
        ey = jnp.asarray(eval_batch[1]) if eval_fn is not None else None

        state0 = (self.drift.state_init(len(tm.c2))
                  if is_state_coupled(self.drift)
                  else jnp.zeros((0,), jnp.float32))
        with enable_x64():
            (params, _, _), (accs, taus, ds, feas) = _fused_realloc_cycles(
                self.params, state0, jnp.asarray(xs), jnp.asarray(ys),
                jnp.asarray(c2b), jnp.asarray(c1b), jnp.asarray(c0b),
                jnp.asarray(T1), jnp.asarray(total1), jnp.asarray(lo1),
                jnp.asarray(hi1), jnp.asarray(valid1),
                (tuple(jnp.asarray(e) for e in energy1)
                 if energy1 is not None else None),
                jnp.asarray(self.mel.staleness_gamma, jnp.float64),
                jnp.asarray(self.mel.lr, jnp.float32), ex, ey,
                d_cap=d_cap, loss_fn=self.loss_fn,
                eval_fn=eval_fn, policy=policy,
                aggregation=self.mel.aggregation, drift=self.drift,
                use_pallas=use_pallas, interpret=interpret,
            )
            # the input params buffer was donated: re-point at the scan
            # carry BEFORE any raise so the orchestrator stays usable (the
            # in-scan dead-latch guarantees it holds the params trained
            # through the feasible prefix only)
            self.params = params
            accs, taus, ds, feas = (np.asarray(a) for a in (accs, taus, ds, feas))
        if not feas.all():
            bad = int(np.flatnonzero(~feas)[0])
            raise ValueError(
                "infeasible: even with tau=0 the deadline T cannot absorb "
                f"d samples (drifted capacities at cycle {bad})"
            )

        history = []
        for c in range(cycles):
            tau_c = taus[c].astype(np.int64)
            d_c = ds[c].astype(np.int64)
            rec = {
                "max_staleness": max_staleness(tau_c),
                "avg_staleness": avg_staleness(tau_c),
                "tau": tau_c,
                "d": d_c,
                "wall_clock_s": self.mel.T,
                "cycle": c,
                "elapsed_s": (c + 1) * self.mel.T,
            }
            if eval_fn is not None:
                rec["accuracy"] = float(accs[c])
            history.append(rec)
        self.allocation = Allocation(
            tau=history[-1]["tau"], d=history[-1]["d"],
            method=f"{self.mel.scheme}_drift",
        )
        return history
