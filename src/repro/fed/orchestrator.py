"""Asynchronous MEL orchestrator (paper Sec. II + V).

One global cycle of wall-clock budget ``T``:
  1. allocate (tau_k, d_k) with the chosen scheme (KKT+SAI / numeric / ETA /
     synchronous),
  2. dispatch the global model + per-learner batches,
  3. every learner runs tau_k local updates — implemented as a **masked
     lax.scan to max(tau)**, vmapped over the learner axis, so the whole
     heterogeneous fleet is one XLA program (and the learner axis can be
     sharded over the mesh's data axes for pod-scale fleets),
  4. staleness-aware aggregation (ref [10]) of the returned models.

The simulated wall-clock of a cycle is T by construction (constraint 7b of
the paper: every learner works the full cycle).

Two execution paths:

  * ``run`` / ``run_cycle`` — eager: one host round-trip per global cycle
    (NumPy shard staging -> jit local_train -> aggregate). Supports
    per-cycle re-allocation and arbitrary host eval callbacks.
  * ``run_fused`` (or ``run(..., fused=True)``) — fast path: shards for
    ALL cycles are drawn up front, padded into one (C, K, d_max, F)
    device-resident tensor, and allocate -> local_train ->
    staleness-weighted aggregation runs as a single jitted ``lax.scan``
    over global cycles with the carried params buffer donated. The
    aggregation contraction goes through ``kernels.ops.fed_agg``
    (Pallas on TPU via ``use_pallas=True``). Trades C× shard memory for
    zero per-cycle host staging; allocation is fixed over the scan
    (reallocate is an eager-path feature).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    Allocation,
    AllocationProblem,
    aggregate,
    fedavg_weights,
    solve_eta,
    solve_kkt_sai,
    solve_pgd_jax,
    solve_slsqp,
    solve_synchronous,
    staleness_weights,
)
from repro.core.staleness import avg_staleness, max_staleness
from repro.data.pipeline import Dataset, FederatedPartitioner

__all__ = ["MELConfig", "Orchestrator", "local_train"]

SCHEMES: dict[str, Callable[[AllocationProblem], Allocation]] = {
    "kkt_sai": solve_kkt_sai,
    "slsqp": solve_slsqp,
    "pgd": solve_pgd_jax,
    "eta": solve_eta,
    "sync": solve_synchronous,
}


@dataclasses.dataclass(frozen=True)
class MELConfig:
    T: float = 15.0
    total_samples: int = 6000          # d dispatched per cycle
    d_lower_frac: float = 0.25         # d_l = frac * d/K
    d_upper_frac: float = 3.0          # d_u = frac * d/K
    lr: float = 0.1
    scheme: str = "kkt_sai"
    aggregation: str = "staleness"     # staleness | fedavg
    staleness_gamma: float = 1.0


@functools.partial(jax.jit, static_argnames=("max_tau", "loss_fn"))
def local_train(global_params, x, y, mask, tau, lr, *, max_tau: int, loss_fn):
    """Run tau_k local GD updates on each of K learners, vectorized.

    x: (K, d_max, F); y, mask: (K, d_max); tau: (K,) int32.
    Returns stacked per-learner params (leading K axis).
    """

    def one_learner(params, xk, yk, mk, tau_k):
        batch = {"x": xk, "y": yk, "mask": mk}

        def step(p, i):
            def do(p):
                g = jax.grad(loss_fn)(p, batch)
                return jax.tree_util.tree_map(lambda pi, gi: pi - lr * gi, p, g)

            return jax.lax.cond(i < tau_k, do, lambda p: p, p), None

        p, _ = jax.lax.scan(step, params, jnp.arange(max_tau))
        return p

    k = x.shape[0]
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (k,) + p.shape), global_params
    )
    return jax.vmap(one_learner)(stacked, x, y, mask, tau)


def _stage_shards(shards: "list[Dataset]", d_max: int, feat: int):
    """Zero-pad per-learner shards into (K, d_max, ...) host arrays with a
    validity mask — shared by the eager per-cycle path and the fused
    pre-staging so their padding semantics cannot diverge."""
    k = len(shards)
    x = np.zeros((k, d_max, feat), np.float32)
    y = np.zeros((k, d_max), np.int32)
    m = np.zeros((k, d_max), np.float32)
    for i, sh in enumerate(shards):
        n = sh.size
        x[i, :n], y[i, :n], m[i, :n] = sh.x, sh.y, 1.0
    return x, y, m


@functools.partial(
    jax.jit,
    static_argnames=("max_tau", "loss_fn", "eval_fn", "use_pallas", "interpret"),
    donate_argnums=(0,),
)
def _fused_cycles(params, xs, ys, ms, tau, weights, lr, eval_x, eval_y, *,
                  max_tau: int, loss_fn, eval_fn, use_pallas: bool,
                  interpret: bool):
    """One XLA program for C global cycles: scan(allocated local_train ->
    fed_agg) with the params carry donated. xs: (C, K, d_max, F);
    ys/ms: (C, K, d_max); tau/weights: (K,)."""
    from repro.kernels import ops

    def one_cycle(p, batch):
        x, y, m = batch
        locals_ = local_train(
            p, x, y, m, tau, lr, max_tau=max_tau, loss_fn=loss_fn
        )
        new = jax.tree_util.tree_map(
            lambda leaf: ops.fed_agg(
                leaf, weights, use_pallas=use_pallas, interpret=interpret
            ),
            locals_,
        )
        acc = eval_fn(new, eval_x, eval_y) if eval_fn is not None else jnp.float32(0)
        return new, acc

    return jax.lax.scan(one_cycle, params, (xs, ys, ms))


class Orchestrator:
    def __init__(
        self,
        mel: MELConfig,
        problem: AllocationProblem,
        loss_fn,
        init_params,
        *,
        seed: int = 0,
    ):
        self.mel = mel
        self.problem = problem
        self.loss_fn = loss_fn
        self.params = init_params
        self.rng = np.random.default_rng(seed)
        self.allocation = SCHEMES[mel.scheme](problem)

    # -- one global cycle ---------------------------------------------------
    def run_cycle(self, shards: list[Dataset]) -> dict:
        alloc = self.allocation
        tau = np.asarray(alloc.tau)
        d = np.asarray(alloc.d)
        d_max = int(d.max())
        feat = shards[0].x.shape[1]
        x, y, m = _stage_shards(shards, d_max, feat)

        max_tau = max(int(tau.max()), 1)
        locals_ = local_train(
            self.params, jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
            jnp.asarray(tau), jnp.asarray(self.mel.lr, jnp.float32),
            max_tau=max_tau, loss_fn=self.loss_fn,
        )
        if self.mel.aggregation == "staleness":
            w = staleness_weights(tau, d, gamma=self.mel.staleness_gamma)
        else:
            w = fedavg_weights(d)
        self.params = aggregate(locals_, jnp.asarray(w))
        return {
            "max_staleness": max_staleness(tau),
            "avg_staleness": avg_staleness(tau),
            "tau": tau.copy(),
            "d": d.copy(),
            "wall_clock_s": self.mel.T,
        }

    # -- full run -------------------------------------------------------------
    def run(
        self,
        train: Dataset,
        cycles: int,
        *,
        eval_fn=None,
        reallocate: bool = False,
        fused: bool = False,
        eval_batch=None,
        use_pallas: bool = False,
        interpret: bool = False,
    ) -> list[dict]:
        if fused:
            if reallocate:
                raise ValueError("fused fast path keeps allocation fixed; "
                                 "use the eager path for reallocate=True")
            return self.run_fused(
                train, cycles, eval_fn=eval_fn, eval_batch=eval_batch,
                use_pallas=use_pallas, interpret=interpret,
            )
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        history = []
        for c in range(cycles):
            if reallocate and c:
                self.allocation = SCHEMES[self.mel.scheme](self.problem)
            shards = part.draw(self.allocation.d)
            rec = self.run_cycle(shards)
            rec["cycle"] = c
            rec["elapsed_s"] = (c + 1) * self.mel.T
            if eval_fn is not None:
                rec["accuracy"] = float(eval_fn(self.params))
            history.append(rec)
        return history

    # -- fused fast path ------------------------------------------------------
    def run_fused(
        self,
        train: Dataset,
        cycles: int,
        *,
        eval_fn=None,
        eval_batch=None,
        use_pallas: bool = False,
        interpret: bool = False,
    ) -> list[dict]:
        """Fused scan-over-cycles twin of ``run``: same shard draws, same
        allocation, one jitted lax.scan instead of C host round-trips.

        ``eval_fn`` here must be jit-traceable with signature
        ``eval_fn(params, x, y) -> scalar`` (e.g. ``mlp.accuracy``) and is
        evaluated inside the scan on ``eval_batch = (x, y)``; pass None to
        skip per-cycle eval.
        """
        alloc = self.allocation
        tau = np.asarray(alloc.tau)
        d = np.asarray(alloc.d)
        k = len(d)
        d_max = int(d.max())
        feat = train.x.shape[1]

        # identical shard sequence to the eager path (same rng consumption)
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        xs = np.zeros((cycles, k, d_max, feat), np.float32)
        ys = np.zeros((cycles, k, d_max), np.int32)
        ms = np.zeros((cycles, k, d_max), np.float32)
        for c in range(cycles):
            xs[c], ys[c], ms[c] = _stage_shards(part.draw(d), d_max, feat)

        if self.mel.aggregation == "staleness":
            w = staleness_weights(tau, d, gamma=self.mel.staleness_gamma)
        else:
            w = fedavg_weights(d)

        if eval_fn is not None and eval_batch is None:
            raise ValueError("run_fused needs eval_batch=(x, y) with eval_fn")
        ex = jnp.asarray(eval_batch[0]) if eval_fn is not None else None
        ey = jnp.asarray(eval_batch[1]) if eval_fn is not None else None

        max_tau = max(int(tau.max()), 1)
        self.params, accs = _fused_cycles(
            self.params, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ms),
            jnp.asarray(tau), jnp.asarray(w),
            jnp.asarray(self.mel.lr, jnp.float32), ex, ey,
            max_tau=max_tau, loss_fn=self.loss_fn, eval_fn=eval_fn,
            use_pallas=use_pallas, interpret=interpret,
        )
        accs = np.asarray(accs)

        history = []
        for c in range(cycles):
            rec = {
                "max_staleness": max_staleness(tau),
                "avg_staleness": avg_staleness(tau),
                "tau": tau.copy(),
                "d": d.copy(),
                "wall_clock_s": self.mel.T,
                "cycle": c,
                "elapsed_s": (c + 1) * self.mel.T,
            }
            if eval_fn is not None:
                rec["accuracy"] = float(accs[c])
            history.append(rec)
        return history
