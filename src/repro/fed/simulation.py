"""End-to-end MEL experiment driver (reproduces the paper's Figs. 2-3).

Builds the 802.11 indoor environment, derives the time-model coefficients
from the paper's exact MNIST-DNN constants (S_m = 8,974,080 bits,
C_m = 1,123,736 FLOPs/sample), allocates with the requested scheme, and
runs asynchronous federated training on synthetic MNIST-class data.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax

from repro.core import (
    AllocationProblem,
    TimeModel,
    indoor_80211_profile,
    mnist_dnn_cost,
)
from repro.data.pipeline import Dataset, synthetic_mnist
from repro.fed.orchestrator import MELConfig, Orchestrator, SCHEMES
from repro.models import mlp

__all__ = ["build_problem", "run_experiment", "staleness_sweep"]


def build_problem(
    k: int,
    T: float,
    *,
    total_samples: int = 6000,
    d_lower_frac: float = 0.25,
    d_upper_frac: float = 3.0,
    seed: int = 0,
) -> AllocationProblem:
    cost = mnist_dnn_cost()
    profiles = indoor_80211_profile(k, seed=seed)
    tm = TimeModel.build(
        profiles,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    d_l = max(1, int(d_lower_frac * total_samples / k))
    d_u = min(total_samples, int(d_upper_frac * total_samples / k))
    return AllocationProblem(
        time_model=tm, T=T, total_samples=total_samples, d_lower=d_l, d_upper=d_u
    )


def staleness_sweep(ks, T: float, *, schemes=("kkt_sai", "slsqp", "eta"), seed: int = 0,
                    total_samples: int = 6000) -> list[dict]:
    """Fig. 2: max/avg staleness vs number of learners K per scheme."""
    rows = []
    for k in ks:
        prob = build_problem(k, T, seed=seed, total_samples=total_samples)
        for scheme in schemes:
            try:
                alloc = SCHEMES[scheme](prob)
                s = alloc.summary(prob)
                rows.append({
                    "K": k, "T": T, "scheme": scheme,
                    "max_staleness": s["max_staleness"],
                    "avg_staleness": s["avg_staleness"],
                    "total_updates": s["total_updates"],
                })
            except ValueError as e:
                rows.append({"K": k, "T": T, "scheme": scheme, "error": str(e)})
    return rows


def run_experiment(
    *,
    k: int = 10,
    T: float = 15.0,
    cycles: int = 12,
    scheme: str = "kkt_sai",
    aggregation: str = "staleness",
    total_samples: int = 6000,
    lr: float = 0.1,
    seed: int = 0,
    train: Dataset | None = None,
    test: Dataset | None = None,
) -> dict:
    """One full MEL run; returns history with accuracy per global cycle."""
    if train is None or test is None:
        train, test = synthetic_mnist(max(total_samples * 2, 12_000), seed=seed)
    prob = build_problem(k, T, total_samples=total_samples, seed=seed)
    mel = MELConfig(
        T=T, total_samples=total_samples, lr=lr, scheme=scheme, aggregation=aggregation
    )
    params = mlp.init(jax.random.key(seed))
    orch = Orchestrator(mel, prob, mlp.loss, params, seed=seed)

    eval_fn = functools.partial(_accuracy, x=test.x[:2000], y=test.y[:2000])
    history = orch.run(train, cycles, eval_fn=eval_fn)
    return {
        "scheme": scheme,
        "K": k,
        "T": T,
        "history": history,
        "final_accuracy": history[-1]["accuracy"],
        "allocation": orch.allocation.summary(prob),
    }


@functools.partial(jax.jit, static_argnames=())
def _acc_jit(params, x, y):
    return mlp.accuracy(params, x, y)


def _accuracy(params, *, x, y):
    return _acc_jit(params, x, y)
