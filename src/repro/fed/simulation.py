"""End-to-end MEL experiment driver (reproduces the paper's Figs. 2-3).

Builds the 802.11 indoor environment, derives the time-model coefficients
from the paper's exact MNIST-DNN constants (S_m = 8,974,080 bits,
C_m = 1,123,736 FLOPs/sample), allocates with the requested scheme, and
runs asynchronous federated training on synthetic MNIST-class data.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax

from repro.core import (
    AllocationProblem,
    BatchedProblems,
    TimeModel,
    batched_summary,
    indoor_80211_profile,
    mnist_dnn_cost,
    solve_eta_batched,
    solve_kkt_batched,
)
from repro.data.pipeline import Dataset, synthetic_mnist
from repro.fed.orchestrator import MELConfig, Orchestrator, SCHEMES
from repro.models import mlp

__all__ = ["build_problem", "run_experiment", "staleness_sweep"]


def build_problem(
    k: int,
    T: float,
    *,
    total_samples: int = 6000,
    d_lower_frac: float = 0.25,
    d_upper_frac: float = 3.0,
    seed: int = 0,
) -> AllocationProblem:
    cost = mnist_dnn_cost()
    profiles = indoor_80211_profile(k, seed=seed)
    tm = TimeModel.build(
        profiles,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    d_l = max(1, int(d_lower_frac * total_samples / k))
    d_u = min(total_samples, int(d_upper_frac * total_samples / k))
    return AllocationProblem(
        time_model=tm, T=T, total_samples=total_samples, d_lower=d_l, d_upper=d_u
    )


_BATCHED_SCHEMES = {"kkt_sai": solve_kkt_batched, "eta": solve_eta_batched}


def staleness_sweep(ks, T: float, *, schemes=("kkt_sai", "slsqp", "eta"), seed: int = 0,
                    total_samples: int = 6000, seeds=None,
                    use_batched: bool = True) -> list[dict]:
    """Fig. 2: max/avg staleness vs number of learners K per scheme.

    With ``use_batched`` (default) every (K, seed) fleet is padded into one
    ``BatchedProblems`` tensor and each batched scheme (kkt_sai, eta) is ONE
    ``solve_*_batched`` call for the whole sweep; remaining schemes fall
    back to the per-problem solvers. On feasible points the rows are
    identical to the eager path (the batched engine replicates the NumPy
    solvers exactly); infeasible points carry the same error message for
    the bisection-infeasibility case the batched solver detects.
    """
    seeds = (seed,) if seeds is None else tuple(seeds)
    cases = [(k, s) for k in ks for s in seeds]
    probs = [
        build_problem(k, T, seed=s, total_samples=total_samples)
        for k, s in cases
    ]

    rows: list[dict] = []
    batched = {}
    if use_batched:
        bp = BatchedProblems.from_problems(probs)
        for scheme in schemes:
            if scheme in _BATCHED_SCHEMES:
                ba = _BATCHED_SCHEMES[scheme](bp)
                batched[scheme] = (ba, ba.summary(bp))

    for i, ((k, s), prob) in enumerate(zip(cases, probs)):
        for scheme in schemes:
            row = {"K": k, "T": T, "scheme": scheme}
            if len(seeds) > 1:
                row["seed"] = s
            if scheme in batched:
                ba, summ = batched[scheme]
                if not ba.feasible[i]:
                    # same wording as solver_kkt.solve_relaxed's ValueError
                    row["error"] = (
                        "infeasible: even with tau=0 the deadline T cannot "
                        "absorb d samples"
                    )
                else:
                    row.update(
                        max_staleness=int(summ["max_staleness"][i]),
                        avg_staleness=float(summ["avg_staleness"][i]),
                        total_updates=int(summ["total_updates"][i]),
                    )
                rows.append(row)
                continue
            try:
                alloc = SCHEMES[scheme](prob)
                sm = alloc.summary(prob)
                row.update(
                    max_staleness=sm["max_staleness"],
                    avg_staleness=sm["avg_staleness"],
                    total_updates=sm["total_updates"],
                )
            except ValueError as e:
                row["error"] = str(e)
            rows.append(row)
    return rows


def run_experiment(
    *,
    k: int = 10,
    T: float = 15.0,
    cycles: int = 12,
    scheme: str = "kkt_sai",
    aggregation: str = "staleness",
    total_samples: int = 6000,
    lr: float = 0.1,
    seed: int = 0,
    train: Dataset | None = None,
    test: Dataset | None = None,
    fused: bool = False,
    use_pallas: bool = False,
) -> dict:
    """One full MEL run; returns history with accuracy per global cycle.

    ``fused=True`` routes through the orchestrator's scan-over-cycles fast
    path (one XLA program for the whole run, eval inside the scan) and
    reproduces the eager history for the same seed.
    """
    if train is None or test is None:
        train, test = synthetic_mnist(max(total_samples * 2, 12_000), seed=seed)
    prob = build_problem(k, T, total_samples=total_samples, seed=seed)
    mel = MELConfig(
        T=T, total_samples=total_samples, lr=lr, scheme=scheme, aggregation=aggregation
    )
    params = mlp.init(jax.random.key(seed))
    orch = Orchestrator(mel, prob, mlp.loss, params, seed=seed)

    if fused:
        history = orch.run(
            train, cycles, fused=True, eval_fn=mlp.accuracy,
            eval_batch=(test.x[:2000], test.y[:2000]), use_pallas=use_pallas,
        )
    else:
        eval_fn = functools.partial(_accuracy, x=test.x[:2000], y=test.y[:2000])
        history = orch.run(train, cycles, eval_fn=eval_fn)
    return {
        "scheme": scheme,
        "K": k,
        "T": T,
        "history": history,
        "final_accuracy": history[-1]["accuracy"],
        "allocation": orch.allocation.summary(prob),
    }


@functools.partial(jax.jit, static_argnames=())
def _acc_jit(params, x, y):
    return mlp.accuracy(params, x, y)


def _accuracy(params, *, x, y):
    return _acc_jit(params, x, y)
