"""End-to-end MEL experiment driver (reproduces the paper's Figs. 2-3).

Builds the 802.11 indoor environment, derives the time-model coefficients
from the paper's exact MNIST-DNN constants (S_m = 8,974,080 bits,
C_m = 1,123,736 FLOPs/sample), allocates with the requested scheme, and
runs asynchronous federated training on synthetic MNIST-class data.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

import jax

from repro.core import (
    AllocationProblem,
    BatchedProblems,
    CapacityDrift,
    EnergyModel,
    TimeModel,
    batched_avg_staleness,
    batched_max_staleness,
    batched_summary,
    indoor_80211_profile,
    mnist_dnn_cost,
    solve_energy_batched,
    solve_eta_batched,
    solve_kkt_batched,
)
from repro.data.pipeline import Dataset, synthetic_mnist
from repro.fed.orchestrator import MELConfig, Orchestrator, SCHEMES
from repro.models import mlp

__all__ = [
    "build_problem",
    "build_spread_problem",
    "run_experiment",
    "staleness_sweep",
    "drift_staleness_sweep",
    "run_async_experiment",
    "async_mode_sweep",
    "churn_sweep",
    "build_energy_problem",
    "energy_sweep",
    "fleet_scale_sweep",
    "multi_model_sweep",
    "laggard_time_to_accuracy",
]


def build_problem(
    k: int,
    T: float,
    *,
    total_samples: int = 6000,
    d_lower_frac: float = 0.25,
    d_upper_frac: float = 3.0,
    seed: int = 0,
) -> AllocationProblem:
    cost = mnist_dnn_cost()
    profiles = indoor_80211_profile(k, seed=seed)
    tm = TimeModel.build(
        profiles,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    d_l = max(1, int(d_lower_frac * total_samples / k))
    d_u = min(total_samples, int(d_upper_frac * total_samples / k))
    return AllocationProblem(
        time_model=tm, T=T, total_samples=total_samples, d_lower=d_l, d_upper=d_u
    )


def build_spread_problem(
    k: int = 3, T: float = 6.0, *, total_samples: int = 60,
) -> AllocationProblem:
    """A small (K <= 5) fleet whose integer-rounded cycle times land well
    apart — the regime where the async engine's exact bucket grid stays
    small and local training stays cheap. The KKT allocator equalizes
    *relaxed* finish times, so the spread comes from the integer tau
    rounding: the coefficients are hand-picked to make that slack differ
    per learner. Shared by the async tests and ``benchmarks/async_bench``
    so the spread property is tuned in one place."""
    if not (1 <= k <= 5):
        raise ValueError("the hand-tuned spread fleet has at most 5 learners")
    c2 = np.array([0.050, 0.031, 0.022, 0.045, 0.027])[:k]
    c1 = np.array([0.004, 0.006, 0.003, 0.005, 0.002])[:k]
    c0 = np.array([0.40, 0.55, 0.30, 0.25, 0.45])[:k]
    return AllocationProblem(
        time_model=TimeModel(c2=c2, c1=c1, c0=c0), T=T,
        total_samples=total_samples,
        d_lower=max(1, total_samples // (2 * k)),
        d_upper=min(total_samples, 2 * total_samples // k),
    )


_BATCHED_SCHEMES = {
    "kkt_sai": solve_kkt_batched,
    "eta": solve_eta_batched,
    "kkt_energy": solve_energy_batched,
}


def staleness_sweep(ks, T: float, *, schemes=("kkt_sai", "slsqp", "eta"), seed: int = 0,
                    total_samples: int = 6000, seeds=None,
                    use_batched: bool = True, reallocate: bool = False,
                    drift: CapacityDrift | None = None,
                    cycles: int = 8) -> list[dict]:
    """Fig. 2: max/avg staleness vs number of learners K per scheme.

    With ``use_batched`` (default) every (K, seed) fleet is padded into one
    ``BatchedProblems`` tensor and each batched scheme (kkt_sai, eta) is ONE
    ``solve_*_batched`` call for the whole sweep; remaining schemes fall
    back to the per-problem solvers. On feasible points the rows are
    identical to the eager path (the batched engine replicates the NumPy
    solvers exactly); infeasible points carry the same error message for
    the bisection-infeasibility case the batched solver detects.

    ``reallocate=True`` switches to the time-varying sweep: capacities
    drift per cycle (``drift``, default ``CapacityDrift(seed=seed)``) and
    each scheme is scored both adaptively (re-solved every cycle — ALL
    case x cycle problems batched into one ``solve_*_batched`` call) and
    statically (solved once on the base capacities, staleness then measured
    under the drifted capacities) — see ``drift_staleness_sweep``.
    """
    if reallocate:
        return drift_staleness_sweep(
            ks, T, cycles=cycles, drift=drift, schemes=schemes, seed=seed,
            total_samples=total_samples, seeds=seeds,
        )
    seeds = (seed,) if seeds is None else tuple(seeds)
    cases = [(k, s) for k in ks for s in seeds]
    probs = [
        build_problem(k, T, seed=s, total_samples=total_samples)
        for k, s in cases
    ]

    rows: list[dict] = []
    batched = {}
    if use_batched:
        bp = BatchedProblems.from_problems(probs)
        for scheme in schemes:
            if scheme in _BATCHED_SCHEMES:
                ba = _BATCHED_SCHEMES[scheme](bp)
                batched[scheme] = (ba, ba.summary(bp))

    for i, ((k, s), prob) in enumerate(zip(cases, probs)):
        for scheme in schemes:
            row = {"K": k, "T": T, "scheme": scheme}
            if len(seeds) > 1:
                row["seed"] = s
            if scheme in batched:
                ba, summ = batched[scheme]
                if not ba.feasible[i]:
                    # same wording as solver_kkt.solve_relaxed's ValueError
                    row["error"] = (
                        "infeasible: even with tau=0 the deadline T cannot "
                        "absorb d samples"
                    )
                else:
                    row.update(
                        max_staleness=int(summ["max_staleness"][i]),
                        avg_staleness=float(summ["avg_staleness"][i]),
                        total_updates=int(summ["total_updates"][i]),
                    )
                rows.append(row)
                continue
            try:
                alloc = SCHEMES[scheme](prob)
                sm = alloc.summary(prob)
                row.update(
                    max_staleness=sm["max_staleness"],
                    avg_staleness=sm["avg_staleness"],
                    total_updates=sm["total_updates"],
                )
            except ValueError as e:
                row["error"] = str(e)
            rows.append(row)
    return rows


def drift_staleness_sweep(ks, T: float, *, cycles: int = 8,
                          drift: CapacityDrift | None = None,
                          schemes=("kkt_sai", "eta"), seed: int = 0,
                          total_samples: int = 6000, seeds=None) -> list[dict]:
    """Adaptive-vs-static staleness under time-varying edge capacities.

    For every (K, seed) fleet the drifted capacity path (C cycles) is
    scored two ways per scheme:

      * ``mode="adaptive"`` — the allocation is re-solved on each cycle's
        true capacities; ALL case x cycle problems are padded into ONE
        mixed-K ``BatchedProblems`` struct and solved with a single
        ``solve_*_batched`` call per scheme;
      * ``mode="static"`` — the allocation is solved once on the base
        (cycle-averaged) capacities and frozen; each cycle's realized
        tau_k is then the largest integer feasible under that cycle's TRUE
        capacities with the frozen d_k, so staleness reflects the drift the
        static scheduler ignored.

    Rows report mean/worst max-staleness and mean avg-staleness over the C
    cycles. Schemes are restricted to the batched engines (kkt_sai, eta).
    """
    drift = CapacityDrift(seed=seed) if drift is None else drift
    seeds_ = (seed,) if seeds is None else tuple(seeds)
    cases = [(k, s) for k in ks for s in seeds_]
    probs = [
        build_problem(k, T, seed=s, total_samples=total_samples)
        for k, s in cases
    ]
    unsupported = [s for s in schemes if s not in _BATCHED_SCHEMES]
    schemes = [s for s in schemes if s in _BATCHED_SCHEMES]
    n = len(cases)
    kmax = max(p.num_learners for p in probs)

    # one (n * cycles, kmax) struct holding every drifted cycle-problem
    paths = [drift.coefficient_path(p.time_model, cycles) for p in probs]
    b = n * cycles
    c2 = np.ones((b, kmax)); c1 = np.ones((b, kmax)); c0 = np.zeros((b, kmax))
    d_lo = np.zeros((b, kmax)); d_hi = np.zeros((b, kmax))
    valid = np.zeros((b, kmax), bool)
    Tb = np.full(b, T); total = np.full(b, total_samples, np.int64)
    for i, (p, (c2s, c1s, c0s)) in enumerate(zip(probs, paths)):
        kk = p.num_learners
        rows = slice(i * cycles, (i + 1) * cycles)
        c2[rows, :kk], c1[rows, :kk], c0[rows, :kk] = c2s, c1s, c0s
        d_lo[rows, :kk] = p.d_lower
        d_hi[rows, :kk] = p.d_upper
        valid[rows, :kk] = True
    bp_drift = BatchedProblems(c2, c1, c0, Tb, total, d_lo, d_hi, valid)
    bp_base = BatchedProblems.from_problems(probs)

    out: list[dict] = []
    for scheme in unsupported:
        # requested schemes without a batched engine get explicit error
        # rows (mirrors the non-realloc sweep's row-per-scheme contract)
        for (k, s) in cases:
            row = {"K": k, "T": T, "scheme": scheme, "cycles": cycles,
                   "error": (f"scheme {scheme!r} has no batched engine; the "
                             "drift sweep supports "
                             + " | ".join(sorted(_BATCHED_SCHEMES)))}
            if len(seeds_) > 1:
                row["seed"] = s
            out.append(row)
    for scheme in schemes:
        solver = _BATCHED_SCHEMES[scheme]
        ba = solver(bp_drift)
        summ = ba.summary(bp_drift)
        ba_static = solver(bp_base)
        for i, ((k, s), p, (c2s, c1s, c0s)) in enumerate(zip(cases, probs, paths)):
            rows = slice(i * cycles, (i + 1) * cycles)
            base = {"K": k, "T": T, "scheme": scheme, "cycles": cycles}
            if len(seeds_) > 1:
                base["seed"] = s
            if not ba.feasible[rows].all() or not ba_static.feasible[i]:
                out.append({**base, "error": (
                    "infeasible: even with tau=0 the deadline T cannot "
                    "absorb d samples"
                )})
                continue
            smax = summ["max_staleness"][rows]
            savg = summ["avg_staleness"][rows]
            out.append({
                **base, "mode": "adaptive",
                "max_staleness_mean": float(smax.mean()),
                "max_staleness_worst": int(smax.max()),
                "avg_staleness_mean": float(savg.mean()),
                "total_updates_mean": float(summ["total_updates"][rows].mean()),
            })
            # frozen allocation, realized tau under each cycle's true caps:
            # a (C, K)-broadcast TimeModel reuses max_tau's clamp semantics
            kk = p.num_learners
            d0 = ba_static.d[i, :kk].astype(float)
            tau_c = TimeModel(c2=c2s, c1=c1s, c0=c0s).max_tau(
                np.broadcast_to(d0, c2s.shape), T
            )
            smax_s = batched_max_staleness(tau_c)
            savg_s = batched_avg_staleness(tau_c)
            upd = (tau_c * d0[None]).sum(axis=1)
            out.append({
                **base, "mode": "static",
                "max_staleness_mean": float(smax_s.mean()),
                "max_staleness_worst": int(smax_s.max()),
                "avg_staleness_mean": float(savg_s.mean()),
                "total_updates_mean": float(upd.mean()),
            })
    return out


def run_experiment(
    *,
    k: int = 10,
    T: float = 15.0,
    cycles: int = 12,
    scheme: str = "kkt_sai",
    aggregation: str = "staleness",
    total_samples: int = 6000,
    lr: float = 0.1,
    seed: int = 0,
    train: Dataset | None = None,
    test: Dataset | None = None,
    fused: bool = False,
    use_pallas: bool = False,
    reallocate: bool = False,
    drift: CapacityDrift | None = None,
) -> dict:
    """One full MEL run; returns history with accuracy per global cycle.

    ``fused=True`` routes through the orchestrator's scan-over-cycles fast
    path (one XLA program for the whole run, eval inside the scan) and
    reproduces the eager history for the same seed. ``reallocate=True``
    re-solves the allocation every cycle — on the fused path this happens
    inside the scan on the traced capacity state; pass a ``CapacityDrift``
    to make the re-solve react to time-varying capacities. ``drift``
    without ``reallocate`` is ignored (with a warning): the training loop
    simulates the base capacities; frozen-allocation-under-drift staleness
    analysis lives in ``drift_staleness_sweep``.
    """
    if train is None or test is None:
        train, test = synthetic_mnist(max(total_samples * 2, 12_000), seed=seed)
    prob = build_problem(k, T, total_samples=total_samples, seed=seed)
    mel = MELConfig(
        T=T, total_samples=total_samples, lr=lr, scheme=scheme, aggregation=aggregation
    )
    params = mlp.init(jax.random.key(seed))
    orch = Orchestrator(mel, prob, mlp.loss, params, seed=seed, drift=drift)

    if fused:
        history = orch.run(
            train, cycles, fused=True, eval_fn=mlp.accuracy,
            eval_batch=(test.x[:2000], test.y[:2000]), use_pallas=use_pallas,
            reallocate=reallocate,
        )
    else:
        eval_fn = functools.partial(_accuracy, x=test.x[:2000], y=test.y[:2000])
        history = orch.run(train, cycles, eval_fn=eval_fn, reallocate=reallocate)
    return {
        "scheme": scheme,
        "K": k,
        "T": T,
        "history": history,
        "final_accuracy": history[-1]["accuracy"],
        "allocation": orch.allocation.summary(prob),
    }


@functools.partial(jax.jit, static_argnames=())
def _acc_jit(params, x, y):
    return mlp.accuracy(params, x, y)


def _accuracy(params, *, x, y):
    return _acc_jit(params, x, y)


# ---------------------------------------------------------------------------
# event-driven asynchronous federation (fed.async_engine)
# ---------------------------------------------------------------------------

def run_async_experiment(
    *,
    k: int = 6,
    T: float = 10.0,
    cycles: int = 6,
    mode: str = "fedasync",
    scheme: str = "kkt_sai",
    aggregation: str = "staleness",
    total_samples: int = 2000,
    lr: float = 0.1,
    seed: int = 0,
    drift: CapacityDrift | None = None,
    reallocate: bool = False,
    alpha: float = 0.6,
    staleness_fn: str = "poly",
    buffer_size: int = 0,
    bucketed: bool = False,
    num_buckets: int = 0,
    strict: bool = True,
    train: Dataset | None = None,
    test: Dataset | None = None,
    problem=None,
    max_events: int = 100_000,
    faults: dict | None = None,
) -> dict:
    """One event-driven async MEL run to virtual time ``cycles * T``.

    ``mode`` selects the server: ``"cycle"`` is the paper's cycle-gated
    scheme expressed as the engine's barrier regime (buffered, M = K, so
    the three modes share one code path and one rng discipline),
    ``"fedasync"`` mixes per upload with version-staleness discounting,
    ``"buffered"`` flushes a size-M buffer (default M = K/2, min 2).
    ``bucketed=True`` routes through the device-resident scan (event
    modes only): ``num_buckets=0`` (default) takes the exact
    event-indexed path (``run_events``, no grid needed);
    ``num_buckets > 0`` forces the legacy fixed grid (``run_bucketed``,
    benchmarking only). Pass ``problem`` to override the default
    MNIST-constants environment (``build_problem``) with a custom fleet.
    ``drift`` accepts a ``CapacityDrift``, a state-coupled ``QueueDrift``
    (``reallocate=True`` required), or an availability process
    (``core.availability``) for client churn. ``faults`` forwards fault
    knobs (``drop_rate``, ``straggler_rate``, ``deadline``, ``quorum``,
    ... — see ``AsyncConfig``) into the config; event modes only (the
    cycle barrier is the fault-free paper regime and rejects them). The
    returned summary's ``"faults"`` dict carries the schedule's fault
    counters.
    """
    from repro.fed.async_engine import (
        AsyncConfig, AsyncFedEngine, summarize_async_history,
    )

    if problem is None:
        problem = build_problem(k, T, total_samples=total_samples, seed=seed)
    else:
        k, T = problem.num_learners, problem.T
        total_samples = problem.total_samples
    # dataset sizing must see the RESOLVED per-cycle budget (a problem=
    # override replaces total_samples above)
    if train is None or test is None:
        train, test = synthetic_mnist(max(total_samples * 2, 12_000), seed=seed)
    horizon = cycles * T
    common = dict(scheme=scheme, aggregation=aggregation, lr=lr,
                  reallocate=reallocate, **(faults or {}))
    if mode == "cycle":
        cfg = AsyncConfig(mode="buffered", barrier=True, **common)
    elif mode == "buffered":
        cfg = AsyncConfig(
            mode="buffered", alpha=alpha, staleness_fn=staleness_fn,
            buffer_size=buffer_size or max(2, k // 2), **common,
        )
    else:
        cfg = AsyncConfig(
            mode=mode, alpha=alpha, staleness_fn=staleness_fn, **common
        )
    params = mlp.init(jax.random.key(seed))
    eng = AsyncFedEngine(cfg, problem, mlp.loss, params, seed=seed, drift=drift)
    eval_batch = (test.x[:2000], test.y[:2000])
    if bucketed:
        if mode == "cycle":
            raise ValueError(
                "mode='cycle' is the barrier regime: its one-XLA-program "
                "path is Orchestrator.run_fused (run_experiment(fused="
                "True)); bucketed=True applies to the event-driven modes"
            )
        if num_buckets:
            history = eng.run_bucketed(
                train, horizon, num_buckets, eval_fn=mlp.accuracy,
                eval_batch=eval_batch, strict=strict, max_events=max_events,
            )
        else:
            history = eng.run_events(
                train, horizon, eval_fn=mlp.accuracy, eval_batch=eval_batch,
                max_events=max_events,
            )
    else:
        history = eng.run(
            train, horizon, eval_fn=mlp.accuracy, eval_batch=eval_batch,
            max_events=max_events,
        )
    summary = summarize_async_history(
        history, counters=eng.fault_counters, energy=eng.energy_ledger
    )
    return {
        "mode": mode,
        "scheme": scheme,
        "K": k,
        "T": T,
        "cycles": cycles,
        "bucketed": bucketed,
        "history": history,
        "summary": summary,
        "final_accuracy": summary["final_accuracy"],
        "accuracy_trace": [
            (round(float(r["t"]), 3), round(float(r["accuracy"]), 4))
            for r in history if "accuracy" in r
        ],
    }


def async_mode_sweep(
    ks,
    T: float,
    *,
    cycles: int = 6,
    modes=("cycle", "fedasync", "buffered"),
    drift: CapacityDrift | None = None,
    scheme: str = "kkt_sai",
    seed: int = 0,
    total_samples: int = 2000,
    reallocate: bool = True,
    alpha: float = 0.6,
    staleness_fn: str = "poly",
    problem=None,
    train: Dataset | None = None,
    test: Dataset | None = None,
) -> list[dict]:
    """Score the paper's cycle-gated scheme against FedAsync and buffered
    asynchronous aggregation at EQUAL virtual time (``cycles * T`` seconds
    of simulated wall clock) under time-varying capacities.

    Every mode trains the same model on the same data stream discipline
    and reports final accuracy, the version-staleness profile of its
    aggregations, and the aggregation/upload counts — the async twin of
    ``drift_staleness_sweep``. ``drift`` defaults to
    ``CapacityDrift(seed=seed)``; pass ``reallocate=False`` to freeze
    every mode's allocation at the base capacities instead.
    """
    drift = CapacityDrift(seed=seed) if drift is None else drift
    rows: list[dict] = []
    for k in np.atleast_1d(ks):
        for mode in modes:
            try:
                res = run_async_experiment(
                    k=int(k), T=T, cycles=cycles, mode=mode, scheme=scheme,
                    seed=seed, total_samples=total_samples, drift=drift,
                    reallocate=reallocate, alpha=alpha,
                    staleness_fn=staleness_fn, problem=problem,
                    train=train, test=test,
                )
            except ValueError as e:
                rows.append({"K": int(k), "T": T, "mode": mode,
                             "cycles": cycles, "error": str(e)})
                continue
            s = res["summary"]
            rows.append({
                "K": res["K"],      # a problem= override resolves K and T
                "T": res["T"],
                "mode": mode,
                "cycles": cycles,
                "scheme": scheme,
                "reallocate": reallocate,
                "final_accuracy": res["final_accuracy"],
                "aggregations": s["aggregations"],
                "uploads": s["uploads"],
                "virtual_time": s["virtual_time"],
                "staleness_mean": s["staleness"]["mean"],
                "staleness_max": s["staleness"]["max"],
                "accuracy_trace": res["accuracy_trace"][:40],
            })
    return rows


def churn_sweep(
    drop_rates=(0.0, 0.2, 0.4),
    *,
    mode: str = "buffered",
    cycles: int = 10,
    seed: int = 0,
    policies=("adaptive", "static", "equal"),
    problem=None,
    train: Dataset | None = None,
    test: Dataset | None = None,
) -> list[dict]:
    """Adaptive KKT reallocation vs frozen/equal allocation as the fleet
    churns: one event-driven run per (dropout rate, policy) cell under a
    compound fault schedule, at equal virtual time.

    Each ``rate`` drives BOTH the client-availability Markov chain
    (``MarkovAvailability(p_drop=rate)`` — learners go offline between
    blocks) and upload loss (``drop_rate = rate / 2``), on top of a fixed
    straggler/delay/deadline-retry background and, in buffered mode, a
    quorum of 2 with graceful degradation — the regime the paper's
    allocator is supposed to absorb. Policies: ``"adaptive"`` re-solves
    the masked KKT allocation per drift block, ``"static"`` freezes the
    base KKT solve (dispatched whenever a learner is online), and
    ``"equal"`` re-solves the equal-task baseline (``eta``) per block.

    Every cell runs the exact event-indexed scan path (``run_events``)
    and reports accuracy, staleness quantiles and the schedule's fault
    counters; no cell may stall or raise, so a degraded fleet must still
    produce a history. The churn twin of ``async_mode_sweep``; feeds
    ``benchmarks/churn_bench.py``.
    """
    from repro.core.availability import MarkovAvailability

    prob = problem or build_spread_problem(k=4, total_samples=80)
    k, T = prob.num_learners, prob.T
    if train is None or test is None:
        train, test = synthetic_mnist(6000, seed=seed)
    policy_kw = {
        "adaptive": dict(scheme="kkt_sai", reallocate=True),
        "static": dict(scheme="kkt_sai", reallocate=False),
        "equal": dict(scheme="eta", reallocate=True),
    }
    rows: list[dict] = []
    for rate in drop_rates:
        availability = MarkovAvailability(
            p_drop=float(rate), p_join=0.5, seed=seed,
        )
        faults = dict(
            drop_rate=float(rate) / 2,
            straggler_rate=0.2, straggler_factor=3.0,
            delay_rate=0.2, delay_mean=0.5 * T,
            deadline=2.5 * T, retry_backoff=0.25 * T, retry_backoff_cap=T,
        )
        if mode == "buffered":
            faults.update(quorum=2, flush_timeout=1.5 * T)
        for policy in policies:
            res = run_async_experiment(
                mode=mode, cycles=cycles, seed=seed, problem=prob,
                train=train, test=test, drift=availability,
                buffer_size=min(3, k), bucketed=True, faults=faults,
                **policy_kw[policy],
            )
            s = res["summary"]
            rows.append({
                "K": k,
                "T": T,
                "mode": mode,
                "cycles": cycles,
                "drop_rate": float(rate),
                "policy": policy,
                "final_accuracy": res["final_accuracy"],
                "aggregations": s["aggregations"],
                "uploads": s["uploads"],
                "virtual_time": s["virtual_time"],
                "staleness_mean": s["staleness"]["mean"],
                "staleness_p50": s["staleness"]["p50"],
                "staleness_p90": s["staleness"]["p90"],
                "staleness_p99": s["staleness"]["p99"],
                "staleness_max": s["staleness"]["max"],
                "faults": s["faults"],
            })
    return rows


def build_energy_problem(
    k: int,
    T: float,
    *,
    total_samples: int = 2000,
    d_lower_frac: float = 0.25,
    d_upper_frac: float = 3.0,
    e_budget=None,
    seed: int = 0,
) -> AllocationProblem:
    """``build_problem`` with the matching per-cycle ``EnergyModel``
    attached: the same 802.11 profiles and MNIST-DNN constants feed both
    the time model (Eq. 5) and its energy mirror, so the (tau, d) decision
    variables carry a joule cost per cycle. ``e_budget=None`` attaches the
    model for ACCOUNTING only (any scheme may run; ``Allocation.validate``
    has nothing to enforce); a finite budget makes the problem strict —
    only energy-aware schemes (``kkt_energy``, the budgeted ``pgd``) can
    solve it."""
    cost = mnist_dnn_cost()
    profiles = indoor_80211_profile(k, seed=seed)
    tm = TimeModel.build(
        profiles,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    em = EnergyModel.build(
        profiles,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    d_l = max(1, int(d_lower_frac * total_samples / k))
    d_u = min(total_samples, int(d_upper_frac * total_samples / k))
    return AllocationProblem(
        time_model=tm, T=T, total_samples=total_samples,
        d_lower=d_l, d_upper=d_u, energy=em, e_budget=e_budget,
    )


def energy_sweep(
    budget_fracs=(0.5, 0.75, 1.0),
    *,
    k: int = 4,
    T: float = 10.0,
    cycles: int = 8,
    mode: str = "fedasync",
    schemes=("kkt_energy", "kkt_sai", "eta"),
    total_samples: int = 800,
    seed: int = 0,
    train: Dataset | None = None,
    test: Dataset | None = None,
) -> list[dict]:
    """Accuracy-vs-energy frontier: the budgeted KKT allocation against the
    energy-blind schemes across per-learner battery budgets, at equal
    virtual time.

    The budget axis is anchored to the fleet's OWN unconstrained spend:
    the blind ``kkt_sai`` allocation's per-learner cycle energies ``E0``
    set the scale, and each level dispatches under the uniform budget
    ``frac * median(E0)`` joules per cycle. ``kkt_energy`` solves WITH the
    budget (per-dispatch re-solves included — ``reallocate=True`` routes
    every re-dispatch through the budgeted policy) and must report zero
    violations by construction; the blind schemes run on the same fleet
    with the energy model attached for accounting only (a strict budgeted
    problem would be rejected by ``Allocation.validate`` at solve time),
    and their overruns are counted EXTERNALLY against the same budget from
    the per-dispatch joules in the history. Rows report final accuracy,
    total/percentile joules, and the violation counts — the frontier data
    for ``benchmarks/energy_bench.py``."""
    prob_free = build_energy_problem(
        k, T, total_samples=total_samples, seed=seed
    )
    em = prob_free.energy
    alloc0 = SCHEMES["kkt_sai"](prob_free)
    e_blind = em.cycle_energy(alloc0.tau, alloc0.d)
    if train is None or test is None:
        train, test = synthetic_mnist(max(total_samples * 2, 12_000), seed=seed)
    rows: list[dict] = []
    for frac in budget_fracs:
        eb = float(frac) * float(np.median(e_blind))
        for scheme in schemes:
            aware = scheme in ("kkt_energy", "pgd")
            prob = (dataclasses.replace(prob_free, e_budget=eb)
                    if aware else prob_free)
            res = run_async_experiment(
                mode=mode, cycles=cycles, seed=seed, problem=prob,
                train=train, test=test, scheme=scheme, reallocate=True,
                bucketed=(mode != "cycle"),
            )
            s = res["summary"]
            # blind schemes never see the budget: score their dispatches
            # against it after the fact (the frontier's violation axis)
            overruns = sum(
                int((np.atleast_1d(r.get("energy", [])) > eb * (1 + 1e-9)).sum())
                for r in res["history"]
            )
            rows.append({
                "K": k,
                "T": T,
                "mode": mode,
                "cycles": cycles,
                "scheme": scheme,
                "energy_aware": aware,
                "budget_frac": float(frac),
                "e_budget_j": round(eb, 4),
                "final_accuracy": res["final_accuracy"],
                "aggregations": s["aggregations"],
                "uploads": s["uploads"],
                "joules_total": round(s["energy"]["joules_total"], 3),
                "joules_p50": round(s["energy"]["joules_p50"], 4),
                "joules_p99": round(s["energy"]["joules_p99"], 4),
                "violations": int(s["energy"]["violations"]) if aware
                              else overruns,
                "staleness_mean": s["staleness"]["mean"],
                "staleness_max": s["staleness"]["max"],
            })
    return rows


# ---------------------------------------------------------------------------
# population-scale fleet-of-fleets federation (fed.fleet)
# ---------------------------------------------------------------------------

def fleet_scale_sweep(
    fleet_counts=(4, 16),
    *,
    k: int = 4,
    rounds: int = 3,
    T: float = 6.0,
    total_samples: int = 40,
    participation: float = 0.5,
    features: int = 64,
    hidden: int = 32,
    seed: int = 0,
    mesh=None,
    train: Dataset | None = None,
    test: Dataset | None = None,
) -> list[dict]:
    """Population-scale rows: one two-tier ``FleetEngine`` run per fleet
    count F — F fleets x ``k`` learners on sharded fleet tensors, FedAST
    partial participation at ``participation``, a compact
    ``[features, hidden, 10]`` model so the per-round cost is dominated by
    the fleet machinery rather than one matmul.

    Every fleet trains every round (unsampled fleets keep working on their
    stale pull), so one global round of virtual-time T simulates F x k
    busy learners: the reported ``learners_per_vtu`` is exactly F x k.
    ``mesh=None`` takes ``launch.mesh.host_mesh()`` — a real (2, 4)
    ``shard_map`` partition under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the 1-device
    mesh elsewhere. Feeds ``benchmarks/fleet_scale.py``."""
    from repro.fed.fleet import FleetConfig, FleetEngine, build_fleet_problems

    if train is None or test is None:
        train, test = synthetic_mnist(
            6000, n_test=2000, features=features, seed=seed
        )
    params = mlp.init(jax.random.key(seed), layers=[features, hidden, 10])
    cfg = FleetConfig(participation=participation)
    rows: list[dict] = []
    for f in fleet_counts:
        bp = build_fleet_problems(
            int(f), k, T=T, total_samples=total_samples, seed=seed
        )
        eng = FleetEngine(cfg, bp, mlp.loss, params, seed=seed, mesh=mesh)
        t0 = time.time()
        hist = eng.run(
            train, rounds, eval_fn=mlp.accuracy,
            eval_batch=(test.x[:1000], test.y[:1000]),
        )
        wall = time.time() - t0
        learners = int(f) * k
        rows.append({
            "F": int(f),
            "K": k,
            "learners": learners,
            "rounds": rounds,
            "participation": participation,
            "mesh_devices": int(np.prod(list(eng.mesh.shape.values()))),
            "fleet_axes": list(eng.fleet_axes),
            "learners_per_vtu": learners,
            "final_accuracy": float(hist[-1]["accuracy"]),
            "fleet_staleness_max": max(
                r["fleet_staleness_max"] for r in hist
            ),
            "wall_s": round(wall, 3),
            "learner_rounds_per_s": round(
                learners * rounds / max(wall, 1e-9), 1
            ),
        })
    return rows


# ---------------------------------------------------------------------------
# multi-tenant simultaneous training (fed.multimodel)
# ---------------------------------------------------------------------------

def multi_model_sweep(
    totals=(200, 200, 600),
    *,
    k: int = 4,
    T: float = 8.0,
    cycles: int = 8,
    splits=("deficit", "equal"),
    mode: str = "fedasync",
    alpha: float = 0.6,
    lr: float = 0.05,
    share_floor: float = 0.1,
    seed: int = 0,
    train: Dataset | None = None,
    test: Dataset | None = None,
) -> list[dict]:
    """S tenant models time-sharing one fleet, deficit split vs equal
    split (``fed.multimodel.MultiModelEngine``), at equal virtual time.

    The tenants differ only in per-round sample budget (``totals``): the
    LAGGARD (largest total) needs more learner-seconds per aggregation,
    so under the equal split it falls behind in server versions while the
    light tenants spin. The deficit split reads that version gap
    (FedAST-style behind-ness — model-value-free) and shifts each
    learner's time budget toward the laggard; the frontier question is
    the laggard's time-to-accuracy. Each row reports per-model accuracy
    traces, final versions, and the laggard's trace for the
    time-to-accuracy comparison in ``benchmarks/multimodel_bench.py``.

    ``share_floor`` defaults to 0.1: a floored split keeps every
    tenant's slice of the deadline large enough that the deadline-filling
    solver doesn't pile hundreds of local iterations onto a handful of
    samples (tiny ``w`` => tiny ``d`` at the box floor => huge ``tau``,
    which diverges plain GD). ``lr`` is likewise gentler than the
    single-model default for the same reason."""
    from repro.fed.async_engine import AsyncConfig
    from repro.fed.multimodel import MultiModelEngine

    s = len(totals)
    probs = [
        build_problem(k, T, total_samples=int(t), seed=seed) for t in totals
    ]
    if train is None or test is None:
        train, test = synthetic_mnist(
            max(max(totals) * 2, 12_000), seed=seed
        )
    eval_batch = (test.x[:2000], test.y[:2000])
    params = tuple(
        mlp.init(jax.random.key(seed + i)) for i in range(s)
    )
    laggard = int(np.argmax(totals))
    horizon = cycles * T
    rows: list[dict] = []
    for split in splits:
        cfg = AsyncConfig(mode=mode, alpha=alpha, lr=lr, staleness_fn="poly")
        eng = MultiModelEngine(
            cfg, probs, mlp.loss, params, seed=seed, split=split,
            share_floor=share_floor,
        )
        histories = eng.run(
            [train] * s, horizon,
            eval_fns=[mlp.accuracy] * s, eval_batches=[eval_batch] * s,
        )
        traces = [
            [(round(float(r["t"]), 3), round(float(r["accuracy"]), 4))
             for r in h if "accuracy" in r]
            for h in histories
        ]
        rows.append({
            "S": s,
            "K": k,
            "T": T,
            "cycles": cycles,
            "mode": mode,
            "lr": lr,
            "split": split,
            "share_floor": share_floor,
            "totals": [int(t) for t in totals],
            "laggard": laggard,
            "versions": [int(h[-1]["server_version"]) if h else 0
                         for h in histories],
            "final_accuracy": [t[-1][1] if t else 0.0 for t in traces],
            "laggard_trace": traces[laggard],
            "events": sum(len(h) for h in histories),
            "split_weights_seen": [
                [round(float(x), 4) for x in w]
                for w in eng.split_weight_log[:8]
            ],
        })
    return rows


def laggard_time_to_accuracy(rows, target: float | None = None):
    """First virtual time each split's laggard reaches ``target`` accuracy
    (default: 95% of the worst split's laggard final accuracy, so every
    row has a finite crossing). Returns ``{split: t}``."""
    if target is None:
        finals = [r["laggard_trace"][-1][1] for r in rows
                  if r["laggard_trace"]]
        target = 0.95 * min(finals)
    out = {}
    for r in rows:
        t_hit = next(
            (t for t, acc in r["laggard_trace"] if acc >= target), None
        )
        out[r["split"]] = t_hit
    return out, float(target)
