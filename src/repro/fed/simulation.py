"""End-to-end MEL experiment driver (reproduces the paper's Figs. 2-3).

Builds the 802.11 indoor environment, derives the time-model coefficients
from the paper's exact MNIST-DNN constants (S_m = 8,974,080 bits,
C_m = 1,123,736 FLOPs/sample), allocates with the requested scheme, and
runs asynchronous federated training on synthetic MNIST-class data.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax

from repro.core import (
    AllocationProblem,
    BatchedProblems,
    CapacityDrift,
    TimeModel,
    batched_avg_staleness,
    batched_max_staleness,
    batched_summary,
    indoor_80211_profile,
    mnist_dnn_cost,
    solve_eta_batched,
    solve_kkt_batched,
)
from repro.data.pipeline import Dataset, synthetic_mnist
from repro.fed.orchestrator import MELConfig, Orchestrator, SCHEMES
from repro.models import mlp

__all__ = ["build_problem", "run_experiment", "staleness_sweep", "drift_staleness_sweep"]


def build_problem(
    k: int,
    T: float,
    *,
    total_samples: int = 6000,
    d_lower_frac: float = 0.25,
    d_upper_frac: float = 3.0,
    seed: int = 0,
) -> AllocationProblem:
    cost = mnist_dnn_cost()
    profiles = indoor_80211_profile(k, seed=seed)
    tm = TimeModel.build(
        profiles,
        model_complexity_flops=cost.flops_per_sample,
        model_size_bits=cost.model_bits,
    )
    d_l = max(1, int(d_lower_frac * total_samples / k))
    d_u = min(total_samples, int(d_upper_frac * total_samples / k))
    return AllocationProblem(
        time_model=tm, T=T, total_samples=total_samples, d_lower=d_l, d_upper=d_u
    )


_BATCHED_SCHEMES = {"kkt_sai": solve_kkt_batched, "eta": solve_eta_batched}


def staleness_sweep(ks, T: float, *, schemes=("kkt_sai", "slsqp", "eta"), seed: int = 0,
                    total_samples: int = 6000, seeds=None,
                    use_batched: bool = True, reallocate: bool = False,
                    drift: CapacityDrift | None = None,
                    cycles: int = 8) -> list[dict]:
    """Fig. 2: max/avg staleness vs number of learners K per scheme.

    With ``use_batched`` (default) every (K, seed) fleet is padded into one
    ``BatchedProblems`` tensor and each batched scheme (kkt_sai, eta) is ONE
    ``solve_*_batched`` call for the whole sweep; remaining schemes fall
    back to the per-problem solvers. On feasible points the rows are
    identical to the eager path (the batched engine replicates the NumPy
    solvers exactly); infeasible points carry the same error message for
    the bisection-infeasibility case the batched solver detects.

    ``reallocate=True`` switches to the time-varying sweep: capacities
    drift per cycle (``drift``, default ``CapacityDrift(seed=seed)``) and
    each scheme is scored both adaptively (re-solved every cycle — ALL
    case x cycle problems batched into one ``solve_*_batched`` call) and
    statically (solved once on the base capacities, staleness then measured
    under the drifted capacities) — see ``drift_staleness_sweep``.
    """
    if reallocate:
        return drift_staleness_sweep(
            ks, T, cycles=cycles, drift=drift, schemes=schemes, seed=seed,
            total_samples=total_samples, seeds=seeds,
        )
    seeds = (seed,) if seeds is None else tuple(seeds)
    cases = [(k, s) for k in ks for s in seeds]
    probs = [
        build_problem(k, T, seed=s, total_samples=total_samples)
        for k, s in cases
    ]

    rows: list[dict] = []
    batched = {}
    if use_batched:
        bp = BatchedProblems.from_problems(probs)
        for scheme in schemes:
            if scheme in _BATCHED_SCHEMES:
                ba = _BATCHED_SCHEMES[scheme](bp)
                batched[scheme] = (ba, ba.summary(bp))

    for i, ((k, s), prob) in enumerate(zip(cases, probs)):
        for scheme in schemes:
            row = {"K": k, "T": T, "scheme": scheme}
            if len(seeds) > 1:
                row["seed"] = s
            if scheme in batched:
                ba, summ = batched[scheme]
                if not ba.feasible[i]:
                    # same wording as solver_kkt.solve_relaxed's ValueError
                    row["error"] = (
                        "infeasible: even with tau=0 the deadline T cannot "
                        "absorb d samples"
                    )
                else:
                    row.update(
                        max_staleness=int(summ["max_staleness"][i]),
                        avg_staleness=float(summ["avg_staleness"][i]),
                        total_updates=int(summ["total_updates"][i]),
                    )
                rows.append(row)
                continue
            try:
                alloc = SCHEMES[scheme](prob)
                sm = alloc.summary(prob)
                row.update(
                    max_staleness=sm["max_staleness"],
                    avg_staleness=sm["avg_staleness"],
                    total_updates=sm["total_updates"],
                )
            except ValueError as e:
                row["error"] = str(e)
            rows.append(row)
    return rows


def drift_staleness_sweep(ks, T: float, *, cycles: int = 8,
                          drift: CapacityDrift | None = None,
                          schemes=("kkt_sai", "eta"), seed: int = 0,
                          total_samples: int = 6000, seeds=None) -> list[dict]:
    """Adaptive-vs-static staleness under time-varying edge capacities.

    For every (K, seed) fleet the drifted capacity path (C cycles) is
    scored two ways per scheme:

      * ``mode="adaptive"`` — the allocation is re-solved on each cycle's
        true capacities; ALL case x cycle problems are padded into ONE
        mixed-K ``BatchedProblems`` struct and solved with a single
        ``solve_*_batched`` call per scheme;
      * ``mode="static"`` — the allocation is solved once on the base
        (cycle-averaged) capacities and frozen; each cycle's realized
        tau_k is then the largest integer feasible under that cycle's TRUE
        capacities with the frozen d_k, so staleness reflects the drift the
        static scheduler ignored.

    Rows report mean/worst max-staleness and mean avg-staleness over the C
    cycles. Schemes are restricted to the batched engines (kkt_sai, eta).
    """
    drift = CapacityDrift(seed=seed) if drift is None else drift
    seeds_ = (seed,) if seeds is None else tuple(seeds)
    cases = [(k, s) for k in ks for s in seeds_]
    probs = [
        build_problem(k, T, seed=s, total_samples=total_samples)
        for k, s in cases
    ]
    unsupported = [s for s in schemes if s not in _BATCHED_SCHEMES]
    schemes = [s for s in schemes if s in _BATCHED_SCHEMES]
    n = len(cases)
    kmax = max(p.num_learners for p in probs)

    # one (n * cycles, kmax) struct holding every drifted cycle-problem
    paths = [drift.coefficient_path(p.time_model, cycles) for p in probs]
    b = n * cycles
    c2 = np.ones((b, kmax)); c1 = np.ones((b, kmax)); c0 = np.zeros((b, kmax))
    d_lo = np.zeros((b, kmax)); d_hi = np.zeros((b, kmax))
    valid = np.zeros((b, kmax), bool)
    Tb = np.full(b, T); total = np.full(b, total_samples, np.int64)
    for i, (p, (c2s, c1s, c0s)) in enumerate(zip(probs, paths)):
        kk = p.num_learners
        rows = slice(i * cycles, (i + 1) * cycles)
        c2[rows, :kk], c1[rows, :kk], c0[rows, :kk] = c2s, c1s, c0s
        d_lo[rows, :kk] = p.d_lower
        d_hi[rows, :kk] = p.d_upper
        valid[rows, :kk] = True
    bp_drift = BatchedProblems(c2, c1, c0, Tb, total, d_lo, d_hi, valid)
    bp_base = BatchedProblems.from_problems(probs)

    out: list[dict] = []
    for scheme in unsupported:
        # requested schemes without a batched engine get explicit error
        # rows (mirrors the non-realloc sweep's row-per-scheme contract)
        for (k, s) in cases:
            row = {"K": k, "T": T, "scheme": scheme, "cycles": cycles,
                   "error": (f"scheme {scheme!r} has no batched engine; the "
                             "drift sweep supports "
                             + " | ".join(sorted(_BATCHED_SCHEMES)))}
            if len(seeds_) > 1:
                row["seed"] = s
            out.append(row)
    for scheme in schemes:
        solver = _BATCHED_SCHEMES[scheme]
        ba = solver(bp_drift)
        summ = ba.summary(bp_drift)
        ba_static = solver(bp_base)
        for i, ((k, s), p, (c2s, c1s, c0s)) in enumerate(zip(cases, probs, paths)):
            rows = slice(i * cycles, (i + 1) * cycles)
            base = {"K": k, "T": T, "scheme": scheme, "cycles": cycles}
            if len(seeds_) > 1:
                base["seed"] = s
            if not ba.feasible[rows].all() or not ba_static.feasible[i]:
                out.append({**base, "error": (
                    "infeasible: even with tau=0 the deadline T cannot "
                    "absorb d samples"
                )})
                continue
            smax = summ["max_staleness"][rows]
            savg = summ["avg_staleness"][rows]
            out.append({
                **base, "mode": "adaptive",
                "max_staleness_mean": float(smax.mean()),
                "max_staleness_worst": int(smax.max()),
                "avg_staleness_mean": float(savg.mean()),
                "total_updates_mean": float(summ["total_updates"][rows].mean()),
            })
            # frozen allocation, realized tau under each cycle's true caps:
            # a (C, K)-broadcast TimeModel reuses max_tau's clamp semantics
            kk = p.num_learners
            d0 = ba_static.d[i, :kk].astype(float)
            tau_c = TimeModel(c2=c2s, c1=c1s, c0=c0s).max_tau(
                np.broadcast_to(d0, c2s.shape), T
            )
            smax_s = batched_max_staleness(tau_c)
            savg_s = batched_avg_staleness(tau_c)
            upd = (tau_c * d0[None]).sum(axis=1)
            out.append({
                **base, "mode": "static",
                "max_staleness_mean": float(smax_s.mean()),
                "max_staleness_worst": int(smax_s.max()),
                "avg_staleness_mean": float(savg_s.mean()),
                "total_updates_mean": float(upd.mean()),
            })
    return out


def run_experiment(
    *,
    k: int = 10,
    T: float = 15.0,
    cycles: int = 12,
    scheme: str = "kkt_sai",
    aggregation: str = "staleness",
    total_samples: int = 6000,
    lr: float = 0.1,
    seed: int = 0,
    train: Dataset | None = None,
    test: Dataset | None = None,
    fused: bool = False,
    use_pallas: bool = False,
    reallocate: bool = False,
    drift: CapacityDrift | None = None,
) -> dict:
    """One full MEL run; returns history with accuracy per global cycle.

    ``fused=True`` routes through the orchestrator's scan-over-cycles fast
    path (one XLA program for the whole run, eval inside the scan) and
    reproduces the eager history for the same seed. ``reallocate=True``
    re-solves the allocation every cycle — on the fused path this happens
    inside the scan on the traced capacity state; pass a ``CapacityDrift``
    to make the re-solve react to time-varying capacities. ``drift``
    without ``reallocate`` is ignored (with a warning): the training loop
    simulates the base capacities; frozen-allocation-under-drift staleness
    analysis lives in ``drift_staleness_sweep``.
    """
    if train is None or test is None:
        train, test = synthetic_mnist(max(total_samples * 2, 12_000), seed=seed)
    prob = build_problem(k, T, total_samples=total_samples, seed=seed)
    mel = MELConfig(
        T=T, total_samples=total_samples, lr=lr, scheme=scheme, aggregation=aggregation
    )
    params = mlp.init(jax.random.key(seed))
    orch = Orchestrator(mel, prob, mlp.loss, params, seed=seed, drift=drift)

    if fused:
        history = orch.run(
            train, cycles, fused=True, eval_fn=mlp.accuracy,
            eval_batch=(test.x[:2000], test.y[:2000]), use_pallas=use_pallas,
            reallocate=reallocate,
        )
    else:
        eval_fn = functools.partial(_accuracy, x=test.x[:2000], y=test.y[:2000])
        history = orch.run(train, cycles, eval_fn=eval_fn, reallocate=reallocate)
    return {
        "scheme": scheme,
        "K": k,
        "T": T,
        "history": history,
        "final_accuracy": history[-1]["accuracy"],
        "allocation": orch.allocation.summary(prob),
    }


@functools.partial(jax.jit, static_argnames=())
def _acc_jit(params, x, y):
    return mlp.accuracy(params, x, y)


def _accuracy(params, *, x, y):
    return _acc_jit(params, x, y)
