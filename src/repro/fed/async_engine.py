"""Event-driven asynchronous federation engine (FedAsync / FedBuff style).

The paper's scheme is asynchronous only *within* a global cycle: every
learner's work is gated to the same wall-clock budget ``T`` (constraint 7b)
and the server aggregates once per cycle. This module drops the cycle gate:
a **virtual-clock event queue** lets every learner upload the moment it
finishes, and the server reacts per upload in the style of FedAsync (Xie et
al., arXiv:1903.03934) and FedBuff/FedAST (arXiv:2106.06639 / 2406.00302):

  * each learner's task completion time follows the paper's own per-learner
    wall-clock model (Eq. 5: download + tau_k * compute + upload =
    ``C2 tau_k d_k + C1 d_k + C0`` under the capacities of the drift block
    it was dispatched in);
  * ``mode="fedasync"`` — on every arrival the server mixes immediately,
    ``w <- (1 - alpha * s(v)) * w + alpha * s(v) * w_k`` with **version
    staleness** ``v = server_version - dispatch_version`` and the
    constant / hinge / polynomial discount ``s`` of the FedAsync paper
    (``core.staleness.staleness_factor``);
  * ``mode="buffered"`` — arrivals accumulate in a size-``M`` buffer; a
    full buffer is flushed as one staleness-weighted aggregation (the
    intra-buffer tau weighting of ``core.aggregation.staleness_weights``
    times the version-staleness discount) and bumps the server version
    once. With ``M = K`` and ``barrier=True`` the engine degenerates to
    the paper's cycle-gated scheme and reproduces ``Orchestrator.run``
    exactly (pinned by tests);
  * at every (re)dispatch the learner's ``(tau_k, d_k)`` comes from the
    fleet-level allocation re-solved through the existing traced
    ``core.solver_batched.batched_policy`` on the capacities of the current
    drift block — adaptive allocation composes with true asynchrony.

Two execution paths share one host-side **schedule**. The key structural
property is that the event timeline is *model-independent*: completion
times, versions, staleness, shard draws and aggregation coefficients depend
only on allocations and capacities, never on parameter values. The
scheduler therefore simulates the whole event system once on the host
(cheap scalar math, identical rng consumption for both paths) and the
device work is pure tensor compute:

  * ``run`` — eager: walk the schedule, train each arrival's dispatched
    model (one ``local_train`` call per event), mix/flush per event. One
    host round-trip per event.
  * ``run_events`` — device-resident fast path (**event-indexed / jagged
    bucketing**): the scheduler already fixes the full event timeline, so
    arrivals are grouped by their *flush structure* — one ``lax.scan``
    step per flush group (a fedasync arrival, or a buffered group split
    wherever a learner repeats) — instead of per time bucket. Each step
    trains the fleet's carried dispatch models (masked, to the
    schedule-wide max tau), folds the step's arrivals into a weighted
    accumulator and applies the flush as masked ``kernels.ops.fed_agg``
    contractions, with the (server, dispatched, accumulator) params carry
    donated — the whole campaign is ONE XLA program, like
    ``Orchestrator.run_fused``. Because grouping is by event index, not
    arrival time, the replay is **exact for every schedule** — including
    the near-tie and exactly-tying completion times a KKT allocator
    produces by design, which no fixed time grid can resolve. Memory cost:
    the pre-staged shard tensor is (S, K, d_cap, F) with S = number of
    scan steps (≈ number of aggregated arrivals), independent of how
    close the arrival times are.
  * ``run_bucketed`` — the legacy fixed-grid fast path: completion times
    quantized onto a ``num_buckets`` uniform grid, same scan body. Exact
    only when the grid resolves every arrival into its own bucket (the
    required bucket count blows up as 1/min-gap on near-tie schedules);
    ``strict=False`` merges colliding fedasync arrivals via
    sequentially-composed weights (aggregation exact, mid-bucket
    redispatch approximated), and buffered flushes that straddle a
    bucket boundary raise. Kept for grid-vs-jagged benchmarking; new
    callers should use ``run_events``.

Capacity drift composes with both paths through the schedule: exogenous
``CapacityDrift`` rows are materialized per block, and a state-coupled
``QueueDrift`` (capacities degraded by the backlog the dispatched
allocations themselves build up) is rolled out block-by-block jointly
with the per-block re-solves (``reallocate=True`` required).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import heapq

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    AllocationProblem,
    CapacityDrift,
    aggregate,
    fedavg_weights,
    is_state_coupled,
    staleness_weights,
)
from repro.core.staleness import (
    STALENESS_FNS,
    avg_staleness,
    max_staleness,
    staleness_factor,
    version_staleness_profile,
)
from repro.core.availability import (
    availability_masks,
    capacity_state_coupled,
    has_availability,
)
from repro.data.pipeline import Dataset, FederatedPartitioner
from repro.fed.orchestrator import (
    SCHEMES,
    _stage_shards,
    coefficient_rows,
    local_train,
    local_train_stacked,
    solve_policy_row,
    solve_rows_availability,
    solve_rows_state_coupled,
)

__all__ = [
    "AsyncConfig",
    "AsyncFedEngine",
    "FAULT_COUNTERS",
    "summarize_async_history",
]

# NOTE: the multi-model scheduler (``fed.multimodel``) replays its per-model
# schedules through the SAME module-level executors below
# (``_replay_eager_schedule`` / ``_run_group_program``) — the S = 1
# record-for-record equivalence is literal code sharing, not re-derivation.


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Server behaviour of the event-driven engine.

    ``buffer_size = 0`` means "fleet size K" (resolved at engine init).
    ``barrier=True`` (buffered only, requires M = K) gates every round on
    the slowest learner and redispatches the whole fleet at the cycle
    boundary — the paper's scheme as a point in this family.

    Fault injection (all off by default; any event mode, virtual-clock
    seconds; see ``docs/robustness.md``): ``drop_rate`` loses uploads in
    transit, ``delay_rate``/``delay_mean`` adds exponential transit
    delay, ``straggler_rate``/``straggler_factor`` slows a dispatch's
    whole computation, ``deadline`` bounds each dispatch server-side with
    ``retry_backoff``-capped-exponential redispatch on a miss, and
    ``quorum``/``flush_timeout`` lets a buffered server flush an
    incomplete group (>= quorum arrivals at the timeout; below quorum it
    extends once, then degrades and flushes whatever arrived rather than
    stalling). ``barrier=True`` rejects every fault knob: the barrier is
    the fault-free paper regime.
    """

    mode: str = "fedasync"             # fedasync | buffered
    alpha: float = 0.6                 # FedAsync server mixing rate
    staleness_fn: str = "poly"         # constant | hinge | poly
    staleness_a: float = 0.5           # discount exponent / slope
    staleness_b: float = 4.0           # hinge knee
    buffer_size: int = 0               # M (buffered); 0 -> K
    barrier: bool = False              # cycle barrier (paper scheme at M=K)
    aggregation: str = "staleness"     # intra-buffer weighting: staleness|fedavg
    staleness_gamma: float = 1.0
    lr: float = 0.1
    scheme: str = "kkt_sai"            # allocation policy at (re)dispatch
    reallocate: bool = False           # re-solve per drift block
    # -- fault / churn injection (virtual-clock seconds) --------------------
    drop_rate: float = 0.0             # P(an upload is lost in transit)
    delay_rate: float = 0.0            # P(an upload is delayed in transit)
    delay_mean: float = 1.0            # mean exponential transit delay (s)
    straggler_rate: float = 0.0        # P(a dispatch straggles)
    straggler_factor: float = 4.0      # straggler slowdown (>= 1)
    deadline: float = 0.0              # per-dispatch deadline (s); 0 = off
    retry_backoff: float = 1.0         # first redispatch backoff (s)
    retry_backoff_cap: float = 8.0     # exponential backoff ceiling (s)
    quorum: int = 0                    # buffered: min arrivals at timeout
    flush_timeout: float = 0.0         # buffered: group deadline (s)

    @property
    def has_faults(self) -> bool:
        """Whether any fault/churn knob is active (fault rng is only
        drawn — and fault events only scheduled — when this is True, so
        fault-free schedules consume the historical rng stream)."""
        return (self.drop_rate > 0 or self.delay_rate > 0
                or self.straggler_rate > 0 or self.deadline > 0
                or self.quorum > 0)

    def __post_init__(self):
        if self.mode not in ("fedasync", "buffered"):
            raise ValueError(f"unknown mode {self.mode!r}: fedasync | buffered")
        if self.staleness_fn not in STALENESS_FNS:
            raise ValueError(
                f"unknown staleness fn {self.staleness_fn!r}: "
                + " | ".join(STALENESS_FNS)
            )
        if self.aggregation not in ("staleness", "fedavg"):
            raise ValueError(f"unknown aggregation {self.aggregation!r}")
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        if self.barrier and self.mode != "buffered":
            raise ValueError("barrier=True is the buffered (M=K) regime; "
                             "fedasync has no cycle gate")
        for name in ("drop_rate", "delay_rate", "straggler_rate"):
            if not (0.0 <= getattr(self, name) <= 1.0):
                raise ValueError(f"{name} must be a probability in [0, 1]")
        if self.straggler_factor < 1.0:
            raise ValueError("straggler_factor must be >= 1 (a straggler "
                             "is slower, never faster)")
        if self.delay_rate > 0 and self.delay_mean <= 0:
            raise ValueError("delay_rate > 0 needs delay_mean > 0")
        if self.deadline < 0:
            raise ValueError("deadline must be >= 0 (0 disables it)")
        if self.deadline > 0 and self.retry_backoff <= 0:
            raise ValueError("deadline retries need retry_backoff > 0")
        if self.retry_backoff_cap < self.retry_backoff:
            raise ValueError("retry_backoff_cap must be >= retry_backoff")
        if self.quorum < 0:
            raise ValueError("quorum must be >= 0 (0 disables timer flushes)")
        if self.quorum > 0:
            if self.mode != "buffered":
                raise ValueError("quorum applies to buffered flushes only; "
                                 "fedasync flushes every arrival already")
            if self.flush_timeout <= 0:
                raise ValueError("quorum > 0 needs flush_timeout > 0 (the "
                                 "group deadline that triggers the quorum "
                                 "check)")
        elif self.flush_timeout > 0:
            raise ValueError("flush_timeout without quorum has no effect; "
                             "set quorum >= 1")
        if self.barrier and self.has_faults:
            raise ValueError(
                "barrier=True is the fault-free paper regime (every round "
                "gates on the full fleet); fault injection needs the "
                "event-driven modes"
            )


# ---------------------------------------------------------------------------
# host-side schedule (model-independent event timeline)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Arrival:
    """One upload event. Aggregation coefficients are filled retroactively
    when the event's flush group closes (the schedule is fully simulated
    before any training runs, so this is always possible)."""

    seq: int                 # chronological arrival index
    learner: int
    t: float                 # completion (= arrival) time
    tau: int
    d: int
    idx: np.ndarray          # shard sample indices drawn at dispatch
    dispatch_t: float
    dispatch_version: int
    staleness: int           # server_version - dispatch_version at arrival
    energy: float = 0.0      # joules the dispatch cost (0 without a model)
    version_after: int = 0
    flush: bool = False      # this arrival closes a flush
    timer_flush: bool = False  # the flush fired on a quorum timer, AFTER
    #                          this arrival redispatched (pre-flush server)
    flush_t: float = 0.0     # virtual time the flush applied (= t unless
    #                          a quorum timer closed the group later)
    keep: float = 1.0        # server self-weight at the flush
    weight: float = 0.0      # this local model's coefficient in its flush
    flush_id: int = -1
    group_weights: np.ndarray | None = None   # on flush arrivals only


@dataclasses.dataclass
class _Schedule:
    arrivals: list
    n_flushes: int
    d_cap: int               # max d over arrivals (>= 1)
    max_tau: int             # max tau over arrivals (>= 1)
    counters: dict = dataclasses.field(default_factory=dict)
    # per-learner joules spent over ALL dispatches (including dropped /
    # deadline-cancelled ones — the device burned the energy either way)
    energy_spent: np.ndarray | None = None
    energy_violations: int = 0   # dispatches costing more than e_budget


FAULT_COUNTERS = (
    "dispatches", "drops", "delays", "stragglers", "deadline_misses",
    "retries", "late_discards", "quorum_flushes", "quorum_extensions",
    "quorum_degradations", "offline_deferrals", "offline_churned",
)


def _zero_fault_counters() -> dict:
    return {key: 0 for key in FAULT_COUNTERS}


_EV_ARRIVE, _EV_DEADLINE, _EV_QUORUM = 0, 1, 2   # heap tie-break priority


def _event_segments(arrivals: "list[_Arrival]") -> "list[list[_Arrival]]":
    """Partition the flush-ordered arrival sequence into **event-indexed
    (jagged) segments** — the scan steps of ``run_events``.

    Invariants (what makes one segment representable as one step of the
    bucketed scan body, and the whole partition an *exact* replay):

      * at most one arrival per learner per segment (the scan holds one
        carried dispatch model per learner slot);
      * at most one flush per segment, always the segment's LAST arrival
        (so the post-step server is the post-flush server and every
        mid-segment redispatch sees an unchanged server — which is exactly
        what the eager loop dispatches, since buffered arrivals before a
        flush redispatch with the untouched server);
      * fedasync arrivals each close their own flush, so their segments
        have exactly one arrival — no weight composition, no mid-step
        redispatch approximation, regardless of how closely (or exactly)
        the arrival times tie;
      * never-flushed trailing arrivals (``flush_id < 0``) are dropped —
        their local models are unobservable (same rule as the grid path).

    Buffered flush groups are split greedily at learner repeats; the split
    prefixes become accumulate-only segments (no flush, server untouched).
    """
    segments: list[list[_Arrival]] = []
    cur: list[_Arrival] = []
    seen: set[int] = set()
    for a in arrivals:
        if a.flush_id < 0:
            continue
        if a.learner in seen:
            segments.append(cur)
            cur, seen = [], set()
        cur.append(a)
        seen.add(a.learner)
        if a.flush:
            segments.append(cur)
            cur, seen = [], set()
    # every kept arrival belongs to a flush group that closes within the
    # horizon, so the walk always ends on a flush boundary
    assert not cur
    return segments


def _flush_row(ev: _Arrival, group: "list[_Arrival]", mode: str) -> dict:
    """One history record per server aggregation — shared by every replay
    path (and by the multi-model engine's per-model histories)."""
    ss = [g.staleness for g in group]
    return {
        "event": ev.flush_id,
        "t": ev.flush_t,
        "mode": mode,
        "server_version": ev.version_after,
        "learners": [g.learner for g in group],
        "tau": np.array([g.tau for g in group], np.int64),
        "d": np.array([g.d for g in group], np.int64),
        "staleness_list": list(map(int, ss)),
        "version_staleness_max": int(max(ss)),
        "version_staleness_mean": float(np.mean(ss)),
        "weights": np.asarray(ev.group_weights, np.float64),
        "keep": ev.keep,
        "energy": np.array([g.energy for g in group], np.float64),
    }


def _replay_eager_schedule(params, sched: _Schedule, train: Dataset, *,
                           mode: str, lr: float, num_learners: int, loss_fn,
                           evalj, ex, ey):
    """The eager event walk over ONE model's schedule: train each arrival's
    dispatched model, mix/flush per event. Returns ``(params, history)``.
    Extracted from ``AsyncFedEngine.run`` so the multi-model engine replays
    each of its per-model schedules through the IDENTICAL executor (their
    S = 1 record-for-record equivalence is this code sharing)."""
    feat = train.x.shape[1]
    dispatch_params = [params] * num_learners
    pending: list = []          # trained locals of the open buffer group
    group: list[_Arrival] = []
    history: list[dict] = []
    lrj = jnp.asarray(lr, jnp.float32)

    for ev in sched.arrivals:
        if ev.flush_id < 0:
            # trailing buffered arrival whose group never flushes
            # within the horizon: its local model is unobservable, so
            # skip the training (the redispatch model is the unchanged
            # server either way)
            dispatch_params[ev.learner] = params
            continue
        # pad to the schedule-wide (d_cap, max_tau) so every event hits
        # ONE local_train compilation (and the same masked-scan numerics
        # as the bucketed path)
        x = np.zeros((1, sched.d_cap, feat), np.float32)
        y = np.zeros((1, sched.d_cap), np.int32)
        msk = np.zeros((1, sched.d_cap), np.float32)
        x[0, : ev.d] = train.x[ev.idx]
        y[0, : ev.d] = train.y[ev.idx]
        msk[0, : ev.d] = 1.0
        out = local_train(
            dispatch_params[ev.learner], jnp.asarray(x), jnp.asarray(y),
            jnp.asarray(msk), jnp.asarray([ev.tau], jnp.int32), lrj,
            max_tau=sched.max_tau, loss_fn=loss_fn,
        )
        pending.append(jax.tree_util.tree_map(lambda l: l[0], out))
        group.append(ev)
        if ev.flush:
            if ev.timer_flush:
                # a quorum timer closed this group AFTER its last
                # arrival redispatched: the schedule gave that dispatch
                # the PRE-flush server, so hand it out before flushing
                dispatch_params[ev.learner] = params
            models = [params] + pending
            stacked = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *models
            )
            wvec = np.concatenate([[ev.keep], ev.group_weights])
            params = aggregate(stacked, jnp.asarray(wvec, jnp.float32))
            rec = _flush_row(ev, group, mode)
            if evalj is not None:
                rec["accuracy"] = float(evalj(params, ex, ey))
            history.append(rec)
            pending, group = [], []
            if not ev.timer_flush:
                dispatch_params[ev.learner] = params
        else:
            dispatch_params[ev.learner] = params
    return params, history


class AsyncFedEngine:
    """Virtual-clock asynchronous federation over one fleet.

    Parameters mirror ``Orchestrator``: the ``AllocationProblem`` supplies
    the per-learner wall-clock model, ``drift`` (optional) the per-block
    capacity evolution (block length = ``problem.T``, the paper's
    capacities-constant-per-cycle block model; task cost is evaluated under
    the block of its dispatch time).
    """

    def __init__(
        self,
        cfg: AsyncConfig,
        problem: AllocationProblem,
        loss_fn,
        init_params,
        *,
        seed: int = 0,
        drift: CapacityDrift | None = None,
    ):
        self.cfg = cfg
        self.problem = problem
        self.loss_fn = loss_fn
        self.params = init_params
        self.rng = np.random.default_rng(seed)
        self.drift = drift
        k = problem.num_learners
        self.buffer_size = cfg.buffer_size or k
        if not (1 <= self.buffer_size <= k):
            raise ValueError(f"buffer_size must be in [1, K={k}]")
        if cfg.barrier and self.buffer_size != k:
            raise ValueError(
                "the cycle barrier gates on the whole fleet: it requires "
                f"buffer_size == K (= {k}); M < K is the event-driven "
                "buffered regime"
            )
        if cfg.quorum > self.buffer_size:
            raise ValueError(
                f"quorum (= {cfg.quorum}) must be <= buffer_size "
                f"(= {self.buffer_size}): a full buffer flushes on its own"
            )
        if has_availability(drift):
            if cfg.barrier:
                raise ValueError(
                    "availability churn has no barrier regime (one offline "
                    "learner would gate every round forever); use the "
                    "event-driven modes, or the Orchestrator for the "
                    "fault-free paper scheme"
                )
            coupled = capacity_state_coupled(drift)
        else:
            coupled = is_state_coupled(drift)
        if coupled and not cfg.reallocate:
            raise ValueError(
                "state-coupled drift ties capacities to the dispatched "
                "allocations; the async engine supports it only with "
                "reallocate=True (per-block re-solves drive the state)"
            )
        # the paper-scheme allocation on the base capacities (used by the
        # barrier path so it matches Orchestrator.run bitwise); event-mode
        # dispatches go through the traced batched_policy instead.
        self.allocation = SCHEMES[cfg.scheme](problem)
        self._alloc_cache: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._static_alloc: tuple[np.ndarray, np.ndarray] | None = None
        self._block_masks: np.ndarray | None = None
        # fault/churn tallies of the LAST schedule built by a run method
        self.fault_counters: dict = _zero_fault_counters()
        # per-learner joule ledger of the LAST run (all-zero without an
        # EnergyModel on the problem): total joules spent per learner and
        # the count of dispatches that overran their e_budget — zero by
        # construction under scheme="kkt_energy"
        self.energy_ledger: dict = {
            "per_learner": np.zeros(k), "violations": 0,
        }

    # -- capacities & allocation --------------------------------------------
    def _block_rows(self, nblocks: int):
        """(C, K) f64 capacity rows per drift block — the SAME row source
        as ``Orchestrator._coefficient_path`` so barrier runs replay the
        orchestrator's exact re-solves. A state-coupled drift has no
        standalone row path (its rows depend on the allocations), so rows
        and per-block solves are rolled out jointly and the allocation
        cache prefilled. An availability process additionally yields the
        per-block online masks (``self._block_masks``) that gate
        dispatching: adaptive runs solve each block masked
        (``solve_rows_availability``); frozen runs dispatch the static
        base allocation whenever a learner is online, with the masks
        rolled out under that frozen allocation."""
        drift = self.drift
        self._block_masks = None
        if has_availability(drift):
            if self.cfg.reallocate:
                rows, (taus, ds), masks = solve_rows_availability(
                    self.cfg.scheme, drift, self.problem, nblocks,
                    label="capacities at drift block {}",
                )
                for b in range(nblocks):
                    self._alloc_cache[b] = (taus[b], ds[b])
                self._block_masks = masks
                return rows
            tau0, d0 = self._alloc_base()
            self._block_masks = availability_masks(
                drift, self.problem.num_learners, nblocks, tau=tau0, d=d0,
            )
            return coefficient_rows(self.problem, drift.base, nblocks)
        if is_state_coupled(drift):
            rows, (taus, ds) = solve_rows_state_coupled(
                self.cfg.scheme, drift, self.problem, nblocks,
                label="capacities at drift block {}",
            )
            for b in range(nblocks):
                self._alloc_cache[b] = (taus[b], ds[b])
            return rows
        return coefficient_rows(self.problem, drift, nblocks)

    def _solve_row(self, c2r, c1r, c0r, *, label) -> tuple[np.ndarray, np.ndarray]:
        """Fleet allocation (tau, d) on one (K,) capacity row, through the
        SAME traced-policy solve the orchestrator's re-solves use (the
        barrier-equivalence guarantee depends on sharing it)."""
        return solve_policy_row(
            self.cfg.scheme, c2r, c1r, c0r, self.problem, label=label
        )

    def _alloc_for_block(self, block: int, rows) -> tuple[np.ndarray, np.ndarray]:
        """Per-block adaptive allocation (cached per drift block)."""
        hit = self._alloc_cache.get(block)
        if hit is None:
            c2s, c1s, c0s = rows
            hit = self._solve_row(
                c2s[block], c1s[block], c0s[block],
                label=f"capacities at drift block {block}",
            )
            self._alloc_cache[block] = hit
        return hit

    def _alloc_base(self) -> tuple[np.ndarray, np.ndarray]:
        """Static allocation: solved ONCE on the base (undrifted)
        capacities — the frozen-scheduler regime a drifting run is compared
        against."""
        if self._static_alloc is None:
            tm = self.problem.time_model
            self._static_alloc = self._solve_row(
                tm.c2.astype(np.float64), tm.c1.astype(np.float64),
                tm.c0.astype(np.float64), label="base capacities",
            )
        return self._static_alloc

    # -- schedule ------------------------------------------------------------
    def _build_schedule(
        self, part: FederatedPartitioner, horizon: float, max_events: int
    ) -> _Schedule:
        """Simulate the full event system WITHOUT touching model values:
        completion times, version bookkeeping, per-dispatch shard draws and
        all aggregation coefficients. Both executors consume this verbatim,
        so their rng streams and event orders agree by construction —
        including every fault event: drops, transit delays, stragglers,
        deadline-retry redispatches and quorum timer flushes are all
        decided here, so eager and jagged replays of a faulty schedule
        stay exactly equivalent for free.

        The heap carries typed events ``(t, kind, seq, payload)`` with
        kind priority arrival < deadline < quorum, so an upload landing
        exactly at its deadline counts as arrived and an upload landing
        exactly at a quorum timeout joins the group before the check.
        Fault randomness comes from a dedicated generator seeded off the
        engine rng ONLY when ``cfg.has_faults`` — fault-free schedules
        consume the historical stream bit-for-bit (the barrier/orchestrator
        equivalence depends on this)."""
        cfg, prob = self.cfg, self.problem
        k_fleet, T = prob.num_learners, prob.T
        m = self.buffer_size
        nblocks = max(int(np.ceil(horizon / T)) + 1, 1)
        rows = self._block_rows(nblocks)
        masks = self._block_masks           # (nblocks, K) bool under churn
        # without drift every block row is the tiled base row: re-solving
        # per block would just repeat the static solve
        realloc = cfg.reallocate and self.drift is not None
        frng = (np.random.default_rng(int(self.rng.integers(2**31)))
                if cfg.has_faults else None)
        counters = _zero_fault_counters()
        # energy accounting: joules are charged at DISPATCH (the device
        # burns them whether or not the upload survives transit), against
        # the problem's static per-learner budget rows
        e_rows = prob.energy_rows()
        energy_spent = np.zeros(k_fleet)
        energy_violations = 0
        heap: list = []
        seq = 0
        server_version = 0
        arrivals: list[_Arrival] = []
        group: list[_Arrival] = []
        flush_id = 0
        next_did = 0                    # dispatch id
        dstate: dict[int, str] = {}     # did -> pending | arrived | cancelled
        open_gid = -1                   # quorum timer id of the open group
        gid_counter = 0

        def push(t: float, kind: int, payload) -> None:
            nonlocal seq
            heapq.heappush(heap, (t, kind, seq, payload))
            seq += 1

        def dispatch(k: int, t: float, attempt: int = 0) -> None:
            nonlocal next_did, energy_violations
            block = min(int(t // T), nblocks - 1)
            if masks is not None:
                # an offline learner cannot accept a task: defer the
                # dispatch to the start of its next online block (or churn
                # it out of the run if none remains within the horizon)
                b = block
                while b < nblocks and not masks[b][k]:
                    b += 1
                if b >= nblocks or b * T > horizon:
                    counters["offline_churned"] += 1
                    return
                if b != block:
                    counters["offline_deferrals"] += 1
                    block, t = b, b * T
            if realloc:
                tau_a, d_a = self._alloc_for_block(block, rows)
            else:
                tau_a, d_a = self._alloc_base()
            tau_k, d_k = int(tau_a[k]), int(d_a[k])
            if masks is not None and d_k == 0:
                # the masked solve starved this (online) learner — the
                # budget fit inside the rest of the fleet; try next block
                if (block + 1) * T <= horizon and block + 1 < nblocks:
                    dispatch(k, (block + 1) * T, attempt)
                else:
                    counters["offline_churned"] += 1
                return
            idx = part.draw_indices(d_k)
            c2, c1, c0 = (r[block, k] for r in rows)
            cost = float(c2 * tau_k * d_k + c1 * d_k + c0)
            counters["dispatches"] += 1
            energy_j = 0.0
            if e_rows is not None:
                e2k, e1k, e0k, ebk = (row[k] for row in e_rows)
                energy_j = float(e2k * tau_k * d_k + e1k * d_k + e0k)
                energy_spent[k] += energy_j
                if energy_j > ebk * (1 + 1e-9):
                    energy_violations += 1
            dropped = False
            if frng is not None:
                # fixed per-dispatch draw order: straggle -> delay -> drop
                if (cfg.straggler_rate > 0
                        and frng.random() < cfg.straggler_rate):
                    counters["stragglers"] += 1
                    cost *= cfg.straggler_factor
                if cfg.delay_rate > 0 and frng.random() < cfg.delay_rate:
                    counters["delays"] += 1
                    cost += float(frng.exponential(cfg.delay_mean))
                dropped = cfg.drop_rate > 0 and frng.random() < cfg.drop_rate
            did = next_did
            next_did += 1
            dstate[did] = "pending"
            if dropped:
                # the upload is lost in transit: no arrival event — only a
                # deadline (if armed) ever hears from this dispatch again
                counters["drops"] += 1
            else:
                push(t + cost, _EV_ARRIVE,
                     (did, k, t, server_version, tau_k, d_k, idx, attempt,
                      energy_j))
            if cfg.deadline > 0:
                push(t + cfg.deadline, _EV_DEADLINE, (did, k, attempt))

        def close_group(t_flush: float, timer: bool) -> None:
            """Flush the open buffered group (arrival-triggered at M, or a
            quorum timer firing at ``t_flush`` after the last arrival)."""
            nonlocal server_version, flush_id, group, open_gid
            taus = np.array([g.tau for g in group], float)
            ds = np.array([g.d for g in group], float)
            phi = staleness_factor(
                np.array([g.staleness for g in group], float),
                kind=cfg.staleness_fn, a=cfg.staleness_a, b=cfg.staleness_b,
            )
            # the paper's intra-buffer weighting (shared with the
            # barrier/cycle server), version-discounted by phi;
            # the renormalization absorbs staleness_weights' own
            base = (fedavg_weights(ds)
                    if cfg.aggregation == "fedavg" else
                    staleness_weights(taus, ds, gamma=cfg.staleness_gamma))
            w = base * phi
            w = w / w.sum()
            for g, wg in zip(group, w):
                g.weight = float(wg)
                g.flush_id = flush_id
            closer = group[-1]
            closer.flush = True
            closer.timer_flush = timer
            closer.flush_t = t_flush
            closer.keep = 0.0
            closer.group_weights = np.asarray(w, np.float64)
            server_version += 1
            closer.version_after = server_version
            flush_id += 1
            group = []
            open_gid = -1

        for k in range(k_fleet):
            dispatch(k, 0.0)

        while heap and len(arrivals) < max_events:
            t_e, kind, _, payload = heapq.heappop(heap)
            if t_e > horizon:
                break
            if kind == _EV_DEADLINE:
                did, k, attempt = payload
                if dstate.get(did) != "pending":
                    continue   # arrived in time (or already cancelled)
                dstate[did] = "cancelled"
                counters["deadline_misses"] += 1
                counters["retries"] += 1
                backoff = min(cfg.retry_backoff * (2.0 ** attempt),
                              cfg.retry_backoff_cap)
                dispatch(k, t_e + backoff, attempt + 1)
                continue
            if kind == _EV_QUORUM:
                gid, extended = payload
                if gid != open_gid or not group:
                    continue   # the group already flushed at M
                if len(group) >= cfg.quorum:
                    counters["quorum_flushes"] += 1
                    close_group(t_e, timer=True)
                elif not extended:
                    # below quorum: extend the deadline once before degrading
                    counters["quorum_extensions"] += 1
                    push(t_e + cfg.flush_timeout, _EV_QUORUM, (gid, True))
                else:
                    # still below quorum after the extension: flush whatever
                    # arrived instead of stalling the server forever
                    counters["quorum_degradations"] += 1
                    close_group(t_e, timer=True)
                continue
            did, k, t_disp, v_disp, tau_k, d_k, idx, attempt, e_j = payload
            if dstate.get(did) == "cancelled":
                counters["late_discards"] += 1
                continue   # its deadline already fired and retried
            dstate[did] = "arrived"
            a = _Arrival(
                seq=len(arrivals), learner=k, t=t_e, tau=tau_k, d=d_k,
                idx=idx, dispatch_t=t_disp, dispatch_version=v_disp,
                staleness=server_version - v_disp, energy=e_j,
            )
            group.append(a)
            arrivals.append(a)
            if cfg.mode == "fedasync":
                phi = staleness_factor(
                    np.array([a.staleness], float),
                    kind=cfg.staleness_fn, a=cfg.staleness_a,
                    b=cfg.staleness_b,
                )
                w = np.array([cfg.alpha]) * phi
                a.weight = float(w[0])
                a.flush_id = flush_id
                a.flush = True
                a.flush_t = t_e
                a.keep = 1.0 - float(w[0])
                a.group_weights = np.asarray(w, np.float64)
                server_version += 1
                a.version_after = server_version
                flush_id += 1
                group = []
            elif len(group) == m:
                close_group(t_e, timer=False)
            else:
                if cfg.quorum > 0 and len(group) == 1:
                    gid_counter += 1
                    open_gid = gid_counter
                    push(t_e + cfg.flush_timeout, _EV_QUORUM,
                         (open_gid, False))
                a.version_after = server_version
            dispatch(k, t_e)   # immediate redispatch with the current server

        return _Schedule(
            arrivals=arrivals, n_flushes=flush_id,
            d_cap=max([a.d for a in arrivals], default=1),
            max_tau=max([a.tau for a in arrivals] + [1]),
            counters=counters,
            energy_spent=energy_spent, energy_violations=energy_violations,
        )

    # -- shared pieces -------------------------------------------------------
    def _eval_pair(self, eval_fn, eval_batch):
        if eval_fn is None:
            return None, None, None
        if eval_batch is None:
            raise ValueError("eval_fn needs eval_batch=(x, y)")
        return (jax.jit(eval_fn), jnp.asarray(eval_batch[0]),
                jnp.asarray(eval_batch[1]))

    def _flush_row(self, ev: _Arrival, group: list[_Arrival]) -> dict:
        return _flush_row(ev, group, self.cfg.mode)

    # -- eager event loop ----------------------------------------------------
    def run(
        self,
        train: Dataset,
        horizon: float | None = None,
        *,
        cycles: int | None = None,
        eval_fn=None,
        eval_batch=None,
        max_events: int = 100_000,
    ) -> list[dict]:
        """Simulate to virtual time ``horizon`` (seconds). Returns one
        history row per server aggregation (per arrival in fedasync mode,
        per buffer flush in buffered mode). ``eval_fn`` must be
        jit-traceable with signature ``(params, x, y) -> scalar`` and is
        evaluated on ``eval_batch`` after every aggregation.

        With ``cfg.barrier=True`` the run is round-gated instead (pass
        ``cycles``, or ``horizon`` as a multiple of T) and reproduces
        ``Orchestrator.run`` exactly for the same seed.
        """
        if self.cfg.barrier:
            return self._run_barrier(
                train, horizon=horizon, cycles=cycles,
                eval_fn=eval_fn, eval_batch=eval_batch,
            )
        if horizon is None:
            raise ValueError("event mode needs a virtual-time horizon")
        # counters describe THIS run only: reset before building, so a
        # schedule build that raises cannot leave the previous run's tallies
        self.fault_counters = _zero_fault_counters()
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        sched = self._build_schedule(part, horizon, max_events)
        self.fault_counters = sched.counters
        self.energy_ledger = {
            "per_learner": sched.energy_spent,
            "violations": sched.energy_violations,
        }
        evalj, ex, ey = self._eval_pair(eval_fn, eval_batch)
        self.params, history = _replay_eager_schedule(
            self.params, sched, train, mode=self.cfg.mode, lr=self.cfg.lr,
            num_learners=self.problem.num_learners, loss_fn=self.loss_fn,
            evalj=evalj, ex=ex, ey=ey,
        )
        return history

    # -- barrier (paper-scheme) rounds --------------------------------------
    def _run_barrier(self, train, *, horizon, cycles, eval_fn, eval_batch):
        prob, cfg = self.problem, self.cfg
        if cycles is None:
            if horizon is None:
                raise ValueError("barrier mode needs cycles or horizon")
            cycles = int(np.floor(horizon / prob.T + 1e-9))
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        self.fault_counters = _zero_fault_counters()   # barrier is fault-free
        e_rows = prob.energy_rows()
        energy_spent = np.zeros(prob.num_learners)
        energy_violations = 0
        evalj, ex, ey = self._eval_pair(eval_fn, eval_batch)
        # without drift, per-cycle re-solves would repeat the static solve
        rows = (self._block_rows(cycles)
                if cfg.reallocate and self.drift is not None else None)
        feat = train.x.shape[1]
        history = []
        for c in range(cycles):
            if rows is not None:
                tau, d = self._alloc_for_block(c, rows)
            else:
                tau = np.asarray(self.allocation.tau)
                d = np.asarray(self.allocation.d)
            shards = part.draw(d)
            x, y, msk = _stage_shards(shards, int(d.max()), feat)
            locals_ = local_train(
                self.params, jnp.asarray(x), jnp.asarray(y), jnp.asarray(msk),
                jnp.asarray(tau), jnp.asarray(cfg.lr, jnp.float32),
                max_tau=max(int(tau.max()), 1), loss_fn=self.loss_fn,
            )
            if cfg.aggregation == "staleness":
                w = staleness_weights(tau, d, gamma=cfg.staleness_gamma)
            else:
                w = fedavg_weights(d)
            # all versions are equal under the barrier, so the version
            # discount is exactly 1.0 for every learner and the weights
            # reduce to the orchestrator's (bitwise — no factor applied)
            self.params = aggregate(locals_, jnp.asarray(w))
            if e_rows is not None:
                e2r, e1r, e0r, ebr = e_rows
                e_c = np.where(d > 0, e2r * tau * d + e1r * d + e0r, 0.0)
                energy_spent += e_c
                energy_violations += int(np.sum(e_c > ebr * (1 + 1e-9)))
            else:
                e_c = np.zeros(prob.num_learners)
            rec = {
                "event": c,
                "t": (c + 1) * prob.T,
                "mode": "cycle",
                "server_version": c + 1,
                "learners": list(range(prob.num_learners)),
                "tau": tau.copy(),
                "d": d.copy(),
                "staleness_list": [0] * prob.num_learners,
                "version_staleness_max": 0,
                "version_staleness_mean": 0.0,
                "weights": np.asarray(w, np.float64),
                "keep": 0.0,
                "energy": e_c,
                "max_staleness": max_staleness(tau),
                "avg_staleness": avg_staleness(tau),
                "cycle": c,
                "elapsed_s": (c + 1) * prob.T,
                "wall_clock_s": prob.T,
            }
            if evalj is not None:
                rec["accuracy"] = float(evalj(self.params, ex, ey))
            history.append(rec)
        self.energy_ledger = {
            "per_learner": energy_spent, "violations": energy_violations,
        }
        return history

    # -- shared one-XLA-program execution over event groups -------------------
    def _run_groups(self, groups, sched: _Schedule, train: Dataset, *,
                    eval_fn, eval_batch, use_pallas: bool,
                    interpret: bool, seg_batch=None) -> list[dict]:
        self.params, history = _run_group_program(
            self.params, groups, sched, train, mode=self.cfg.mode,
            lr=self.cfg.lr, num_learners=self.problem.num_learners,
            loss_fn=self.loss_fn, eval_fn=eval_fn, eval_batch=eval_batch,
            use_pallas=use_pallas, interpret=interpret, seg_batch=seg_batch,
        )
        return history


    # -- event-indexed (jagged) device-resident fast path ---------------------
    def run_events(
        self,
        train: Dataset,
        horizon: float,
        *,
        eval_fn=None,
        eval_batch=None,
        use_pallas: bool = False,
        interpret: bool = False,
        seg_batch=None,
        max_events: int = 100_000,
    ) -> list[dict]:
        """The eager event loop as ONE jitted ``lax.scan`` over
        **event-indexed (jagged) segments** — the exact device-resident
        fast path.

        Arrivals are grouped by flush structure (``_event_segments``), not
        onto a time grid: one scan step per fedasync arrival / buffered
        flush group (split at learner repeats). Exactness needs no grid
        resolution, so near-tie and exactly-tying completion times — the
        norm under the paper's KKT allocator, which equalizes finish times
        — replay exactly, where ``run_bucketed`` needed an exploding
        ``num_buckets`` or lossy ``strict=False`` merging.

        Parameters
        ----------
        train : Dataset the shard draws index into (same rng discipline as
            ``run`` — the two paths share one host schedule).
        horizon : float — virtual-time horizon in seconds.
        eval_fn : optional jit-traceable ``(params, x, y) -> scalar``,
            evaluated inside the scan after every flush on ``eval_batch``.
        eval_batch : ``(x, y)`` arrays; required with ``eval_fn``.
        use_pallas, interpret : route each scan step's whole
            train+accumulate+flush body through the ``ops.train_agg_step``
            Pallas megakernel (``interpret=True`` emulates it on CPU).
        seg_batch : optional int — sub-batch each jagged segment into
            chunks of at most this many arrivals, staged COMPACTLY over
            arrival slots (``(S', seg_batch, d_cap, F)`` with a
            slot-to-learner gather) instead of densely over all K
            learners. Buffered runs with large flush quorum M keep the
            per-step working set at ``seg_batch`` learner rows rather
            than paying widest-segment padding on every step; prefix
            chunks are accumulate-only, the closing chunk carries the
            flush. Same history rows; params match the dense staging to
            float tolerance (the accumulate folds in chunks).
        max_events : schedule-length safety cap.

        Returns
        -------
        One history row per server aggregation, identical to ``run``'s for
        the same seed: versions, staleness lists and weights bitwise (they
        come from the shared schedule); aggregated params match to float
        tolerance (the scan composes the same contractions in a different
        reduction order).
        """
        if self.cfg.barrier:
            raise ValueError(
                "the barrier (cycle-gated) regime is already one XLA "
                "program via Orchestrator.run_fused; run_events is the "
                "event-driven fast path"
            )
        self.fault_counters = _zero_fault_counters()   # this run's tallies only
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        sched = self._build_schedule(part, horizon, max_events)
        self.fault_counters = sched.counters
        self.energy_ledger = {
            "per_learner": sched.energy_spent,
            "violations": sched.energy_violations,
        }
        segments = _event_segments(sched.arrivals)
        if not segments:
            return []
        return self._run_groups(
            segments, sched, train, eval_fn=eval_fn, eval_batch=eval_batch,
            use_pallas=use_pallas, interpret=interpret, seg_batch=seg_batch,
        )

    # -- bucketed device-resident fast path (legacy fixed grid) ---------------
    def run_bucketed(
        self,
        train: Dataset,
        horizon: float,
        num_buckets: int,
        *,
        eval_fn=None,
        eval_batch=None,
        strict: bool = True,
        use_pallas: bool = False,
        interpret: bool = False,
        max_events: int = 100_000,
    ) -> list[dict]:
        """LEGACY fixed-grid twin of ``run_events``: the eager event loop
        as ONE jitted ``lax.scan`` over a ``num_buckets`` uniform time
        grid (see module docstring). History rows are identical to
        ``run``'s for the same seed (same host schedule); the aggregation
        sequence matches to float tolerance whenever each bucket holds at
        most one arrival — the guards below raise (with a remedy) for
        grids too coarse to be faithful at all.

        Prefer ``run_events``: it groups by event index instead of time,
        so it is exact on the near-tie/tied schedules this grid cannot
        represent, needs no ``num_buckets``/``strict`` tuning, and stages
        a smaller tensor (S segments vs H >= S buckets). This path is
        kept for grid-vs-jagged benchmarking (``benchmarks/async_bench``).

        Parameters mirror ``run_events`` plus ``num_buckets`` (grid size)
        and ``strict`` (raise on multi-arrival buckets vs merge fedasync
        collisions into composed weights — exact aggregation, approximated
        mid-bucket redispatch)."""
        if self.cfg.barrier:
            raise ValueError(
                "the barrier (cycle-gated) regime is already one XLA "
                "program via Orchestrator.run_fused; run_bucketed is the "
                "event-driven fast path"
            )
        if num_buckets < 1:
            raise ValueError("num_buckets must be >= 1")
        self.fault_counters = _zero_fault_counters()   # this run's tallies only
        part = FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
        sched = self._build_schedule(part, horizon, max_events)
        self.fault_counters = sched.counters
        self.energy_ledger = {
            "per_learner": sched.energy_spent,
            "violations": sched.energy_violations,
        }

        h = num_buckets
        width = horizon / h
        buckets: list[list[_Arrival]] = [[] for _ in range(h)]
        for a in sched.arrivals:
            if a.flush_id < 0:
                continue   # never-flushed trailing buffer: unobservable
            buckets[min(int(a.t / width), h - 1)].append(a)

        # guards: configurations the grid cannot represent at all
        for b, evs in enumerate(buckets):
            learners = [a.learner for a in evs]
            if len(set(learners)) < len(learners):
                raise ValueError(
                    f"bucket {b} holds two arrivals of the same learner — "
                    "its second task would need training before the bucket "
                    "ends; increase num_buckets"
                )
            if strict and len(evs) > 1:
                raise ValueError(
                    f"bucket {b} holds {len(evs)} arrivals; increase "
                    "num_buckets for an exact replay, pass strict=False "
                    "to merge them (exact aggregation via composed weights; "
                    "mid-bucket redispatches then see the bucket-end "
                    "server), or use run_events (exact without a grid)"
                )
            if self.cfg.mode == "buffered":
                # fedasync flushes per arrival and merges exactly via the
                # composed weights below; buffered groups cannot straddle a
                # bucket boundary mid-bucket
                tie = len({a.t for a in evs}) < len(evs)
                remedy = (
                    "arrival times tie exactly, so NO grid separates them "
                    "— use run_events (event-indexed segments replay tied "
                    "buffered schedules exactly)"
                    if tie else "increase num_buckets (or use run_events)"
                )
                nflush = sum(a.flush for a in evs)
                if nflush > 1:
                    raise ValueError(
                        f"bucket {b} holds {nflush} buffer flushes; {remedy}"
                    )
                if nflush == 1 and not evs[-1].flush:
                    raise ValueError(
                        f"a buffer flush splits bucket {b} (arrivals of "
                        f"the next group share it); {remedy}"
                    )

        return self._run_groups(
            buckets, sched, train, eval_fn=eval_fn, eval_batch=eval_batch,
            use_pallas=use_pallas, interpret=interpret,
        )


def _compose_group_row(evs, mode: str):
    """Per-group flush coefficients: the composed keep factor, the flush
    flag, and one contraction weight per arrival (arrival order).
    fedasync groups compose their sequential mixes into one contraction
    server' = prod(1-b_i) * server + sum_i b_i prod_{j>i}(1-b_j) w_i —
    for single-arrival groups (always, on the jagged path) bitwise the
    schedule's own per-arrival coefficients."""
    if mode == "fedasync":
        betas = np.array([a.weight for a in evs])
        suffix = np.cumprod((1.0 - betas)[::-1])[::-1]
        comp = betas * np.concatenate([suffix[1:], [1.0]])
        return float(suffix[0]), 1.0, comp
    comp = np.array([a.weight for a in evs])
    if evs[-1].flush:
        return float(evs[-1].keep), 1.0, comp
    return 1.0, 0.0, comp


def _stage_groups_dense(groups, train: Dataset, *, mode: str, k_fleet: int,
                        d_cap: int, feat: int):
    """Stage one scan step per event group over the full (n, K, d_cap, F)
    learner grid (``_bucketed_events`` layout)."""
    n = len(groups)
    xs = np.zeros((n, k_fleet, d_cap, feat), np.float32)
    ys = np.zeros((n, k_fleet, d_cap), np.int32)
    ms = np.zeros((n, k_fleet, d_cap), np.float32)
    tau_g = np.zeros((n, k_fleet), np.int32)
    wc = np.zeros((n, k_fleet), np.float32)
    keepv = np.ones(n, np.float32)
    fflag = np.zeros(n, np.float32)
    rmask = np.zeros((n, k_fleet), bool)
    pmask = np.zeros((n, k_fleet), bool)
    for i, evs in enumerate(groups):
        if not evs:
            continue
        keepv[i], fflag[i], comp = _compose_group_row(evs, mode)
        for a, w_i in zip(evs, comp):
            wc[i, a.learner] = w_i
        for a in evs:
            k = a.learner
            rmask[i, k] = True
            # a timer-flush closer redispatched BEFORE the timer fired,
            # so it takes the pre-flush server like any accumulate
            # upload; only arrival-triggered closers see the post-flush
            pmask[i, k] = a.flush and not a.timer_flush
            tau_g[i, k] = a.tau
            xs[i, k, : a.d] = train.x[a.idx]
            ys[i, k, : a.d] = train.y[a.idx]
            ms[i, k, : a.d] = 1.0
    return xs, ys, ms, tau_g, wc, keepv, fflag, rmask, pmask


def _stage_groups_compact(groups, train: Dataset, *, mode: str, slots: int,
                          d_cap: int, feat: int):
    """Stage over ARRIVAL SLOTS instead of learner rows: (n, slots,
    d_cap, F) plus a slot-to-learner ``ids`` map — the sub-batched
    ``_bucketed_events_compact`` layout. Padding slots point at learner 0
    with tau = 0, weight 0, mask 0 (exact no-ops)."""
    n = len(groups)
    xs = np.zeros((n, slots, d_cap, feat), np.float32)
    ys = np.zeros((n, slots, d_cap), np.int32)
    ms = np.zeros((n, slots, d_cap), np.float32)
    tau_g = np.zeros((n, slots), np.int32)
    wc = np.zeros((n, slots), np.float32)
    keepv = np.ones(n, np.float32)
    fflag = np.zeros(n, np.float32)
    ids = np.zeros((n, slots), np.int32)
    rms = np.zeros((n, slots), bool)
    pms = np.zeros((n, slots), bool)
    for i, evs in enumerate(groups):
        if not evs:
            continue
        keepv[i], fflag[i], comp = _compose_group_row(evs, mode)
        wc[i, : len(evs)] = comp
        for j, a in enumerate(evs):
            ids[i, j] = a.learner
            rms[i, j] = True
            pms[i, j] = a.flush and not a.timer_flush
            tau_g[i, j] = a.tau
            xs[i, j, : a.d] = train.x[a.idx]
            ys[i, j, : a.d] = train.y[a.idx]
            ms[i, j, : a.d] = 1.0
    return xs, ys, ms, tau_g, wc, keepv, fflag, ids, rms, pms


_STAGING_CACHE: "dict[tuple, tuple]" = {}
_STAGING_STATS = {"stages": 0, "hits": 0}
_STAGING_CACHE_MAX = 4


def staging_cache_stats() -> dict:
    """Copy of the group-staging cache counters (tests/diagnostics)."""
    return dict(_STAGING_STATS)


def clear_staging_cache() -> None:
    _STAGING_CACHE.clear()
    _STAGING_STATS["stages"] = 0
    _STAGING_STATS["hits"] = 0


def _schedule_digest(groups, *, mode: str, k_fleet: int, d_cap: int,
                     feat: int, seg_batch) -> str:
    """Digest of everything the staged tensors depend on besides the
    dataset contents: the staging geometry and, per arrival, the fields
    the staging loops read (learner, tau, d, weight, flush structure,
    sample indices)."""
    h = hashlib.sha1()
    h.update(repr((mode, k_fleet, d_cap, feat, seg_batch)).encode())
    for i, evs in enumerate(groups):
        h.update(b"|g%d" % i)
        for a in evs:
            h.update(repr((a.learner, int(a.tau), int(a.d), float(a.weight),
                           bool(a.flush), bool(a.timer_flush),
                           float(a.keep))).encode())
            h.update(np.ascontiguousarray(a.idx).tobytes())
    return h.hexdigest()


def _staged_group_arrays(groups, train: Dataset, *, mode: str, k_fleet: int,
                         d_cap: int, feat: int, seg_batch):
    """The host-staging front of the group program, cached keyed on
    (dataset identity, schedule digest): repeated replays of one schedule
    — parameter sweeps, golden-trace replays, the multi-model engine's
    per-model reruns — skip re-staging the full (S, K, d_cap, F) tensor
    and pay it once per distinct schedule."""
    key = (id(train), _schedule_digest(
        groups, mode=mode, k_fleet=k_fleet, d_cap=d_cap, feat=feat,
        seg_batch=seg_batch,
    ))
    hit = _STAGING_CACHE.get(key)
    # the entry pins the dataset object, so its id cannot be recycled
    # while the entry lives — an identity check makes that explicit
    if hit is not None and hit[0] is train:
        _STAGING_STATS["hits"] += 1
        return hit[1]
    _STAGING_STATS["stages"] += 1
    if seg_batch is None:
        staged = _stage_groups_dense(
            groups, train, mode=mode, k_fleet=k_fleet, d_cap=d_cap, feat=feat
        )
    else:
        staged = _stage_groups_compact(
            groups, train, mode=mode, slots=seg_batch, d_cap=d_cap, feat=feat
        )
    while len(_STAGING_CACHE) >= _STAGING_CACHE_MAX:
        _STAGING_CACHE.pop(next(iter(_STAGING_CACHE)))
    _STAGING_CACHE[key] = (train, staged)
    return staged


def _run_group_program(params, groups, sched: _Schedule, train: Dataset, *,
                       mode: str, lr: float, num_learners: int, loss_fn,
                       eval_fn, eval_batch, use_pallas: bool,
                       interpret: bool, seg_batch=None):
    """Stage one scan step per event group, run the whole campaign as
    ONE jitted program (``_bucketed_events``), and replay the history
    rows — THE shared back half of ``run_events`` (jagged segments)
    and ``run_bucketed`` (grid buckets), so the two scan paths cannot
    diverge in staging semantics. Module-level so the multi-model engine's
    per-model replays run the identical program. Returns
    ``(params, history)``.

    Empty groups are allowed (empty grid buckets; runtime-skipped scan
    steps). fedasync groups may hold several arrivals (grid
    ``strict=False`` merging): their sequential mixes are composed
    into one contraction — for single-arrival groups (always, on the
    jagged path) the composition degenerates to the schedule's own
    per-arrival coefficients bitwise. The post-step accuracy is
    attributed to the group's LAST flush row (earlier merged flushes
    have no mid-step eval point).

    ``seg_batch`` sub-batches each group into chunks of at most that many
    arrivals and runs the slot-compact program
    (``_bucketed_events_compact``): prefix chunks are accumulate-only,
    the closing chunk carries the group's flush, so a buffered run's
    per-step working set is ``seg_batch`` learner rows instead of the
    widest segment padded over all K."""
    if eval_fn is not None and eval_batch is None:
        raise ValueError("eval_fn needs eval_batch=(x, y)")
    if seg_batch is not None:
        if seg_batch < 1:
            raise ValueError("seg_batch must be >= 1")
        groups = [evs[j: j + seg_batch]
                  for evs in groups
                  for j in range(0, max(len(evs), 1), seg_batch)]
    k_fleet = num_learners
    feat = train.x.shape[1]
    d_cap, max_tau = sched.d_cap, sched.max_tau
    staged = _staged_group_arrays(
        groups, train, mode=mode, k_fleet=k_fleet, d_cap=d_cap, feat=feat,
        seg_batch=seg_batch,
    )

    ex = jnp.asarray(eval_batch[0]) if eval_fn is not None else None
    ey = jnp.asarray(eval_batch[1]) if eval_fn is not None else None
    disp0 = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (k_fleet,) + p.shape),
        params,
    )
    accum0 = jax.tree_util.tree_map(jnp.zeros_like, params)
    if seg_batch is None:
        xs, ys, ms, tau_g, wc, keepv, fflag, rmask, pmask = staged
        params, accs = _bucketed_events(
            params, disp0, accum0, jnp.asarray(xs), jnp.asarray(ys),
            jnp.asarray(ms), jnp.asarray(tau_g), jnp.asarray(wc),
            jnp.asarray(keepv), jnp.asarray(fflag),
            jnp.asarray(rmask), jnp.asarray(pmask),
            jnp.asarray(lr, jnp.float32), ex, ey,
            max_tau=max_tau, loss_fn=loss_fn, eval_fn=eval_fn,
            use_pallas=use_pallas, interpret=interpret,
        )
    else:
        xs, ys, ms, tau_g, wc, keepv, fflag, ids, rms, pms = staged
        params, accs = _bucketed_events_compact(
            params, disp0, accum0, jnp.asarray(xs), jnp.asarray(ys),
            jnp.asarray(ms), jnp.asarray(tau_g), jnp.asarray(wc),
            jnp.asarray(keepv), jnp.asarray(fflag),
            jnp.asarray(ids), jnp.asarray(rms), jnp.asarray(pms),
            jnp.asarray(lr, jnp.float32), ex, ey,
            max_tau=max_tau, loss_fn=loss_fn, eval_fn=eval_fn,
            use_pallas=use_pallas, interpret=interpret,
        )
    accs = np.asarray(accs)

    history: list[dict] = []
    group: list[_Arrival] = []
    for i, evs in enumerate(groups):
        flushes = [a for a in evs if a.flush]
        for a in evs:
            group.append(a)
            if a.flush:
                rec = _flush_row(a, group, mode)
                if eval_fn is not None and a is flushes[-1]:
                    rec["accuracy"] = float(accs[i])
                history.append(rec)
                group = []
    return params, history


@functools.partial(
    jax.jit,
    static_argnames=("max_tau", "loss_fn", "eval_fn", "use_pallas", "interpret"),
)
def _bucketed_events(server, disp, accum, xs, ys, ms, taus, wcs, keeps, fs,
                     rmask, pmask, lr, eval_x, eval_y, *, max_tau: int,
                     loss_fn, eval_fn, use_pallas: bool, interpret: bool):
    """One XLA program for H scan steps (time buckets of ``run_bucketed``
    or jagged event segments of ``run_events``) of the async event system:
    scan(train carried dispatch models -> fold arrivals into the weighted
    accumulator -> masked flush into the server -> masked redispatch). The
    initial server buffer is NOT donated on purpose: engines may share the
    caller's init_params object (the scan carry is double-buffered by XLA
    either way).

    xs: (H, K, d_cap, F); ys/ms: (H, K, d_cap); taus/wcs: (H, K);
    keeps/fs: (H,); rmask/pmask: (H, K) bool. Per step the whole
    train+accumulate+flush body is one ``ops.train_agg_step`` call
    (= ``local_train_stacked`` then server' = fed_agg([server, A'],
    [keep, f]) with A' = fed_agg([A, locals], [1, w_c]); the Pallas
    megakernel under ``use_pallas=True``) — f = 0 steps leave the server
    untouched, f = 1 steps apply a flush whose coefficients the host
    composed to be exactly the eager loop's sequential mixes.

    Redispatch is mask-split to mirror the eager loop's timing exactly:
    arrivals in ``pmask`` (flush arrivals — all of fedasync, the buffer
    closer in buffered mode) redispatch with the POST-flush server; the
    remaining ``rmask`` arrivals (buffered accumulate uploads, which the
    eager loop redispatches before any flush touches the server)
    redispatch with the step's incoming PRE-flush server."""
    from repro.kernels import ops

    def one_bucket(carry, inp):
        x, y, m, tau, w, keep, f, rm, pm = inp

        def process(op):
            server, dp, acc = op
            server1, acc2 = ops.train_agg_step(
                dp, x, y, m, tau, w, lr, loss_fn=loss_fn, max_tau=max_tau,
                server=server, acc=acc, keep=keep, flush=f,
                use_pallas=use_pallas, interpret=interpret,
            )
            pre = rm & jnp.logical_not(pm)
            dp1 = jax.tree_util.tree_map(
                lambda old, new_post, new_pre: jnp.where(
                    pm.reshape((-1,) + (1,) * new_post.ndim),
                    new_post[None],
                    jnp.where(
                        pre.reshape((-1,) + (1,) * new_pre.ndim),
                        new_pre[None], old,
                    ),
                ),
                dp, server1, server,
            )
            # only flush buckets' accuracies are ever read back (buffered
            # accumulation buckets would be dead eval compute)
            a_out = (
                jax.lax.cond(
                    f > 0,
                    lambda s: eval_fn(s, eval_x, eval_y).astype(jnp.float32),
                    lambda s: jnp.float32(0),
                    server1,
                )
                if eval_fn is not None else jnp.float32(0)
            )
            return (server1, dp1, acc2), a_out

        def skip(op):
            return op, jnp.float32(0)

        # empty buckets skip training entirely at RUNTIME (scan-level cond
        # is real branching, not a select) — a fine exact grid costs only
        # its active buckets
        return jax.lax.cond(jnp.any(rm), process, skip, carry)

    (server, disp, accum), accs = jax.lax.scan(
        one_bucket, (server, disp, accum), (xs, ys, ms, taus, wcs, keeps, fs,
                                            rmask, pmask)
    )
    return server, accs


@functools.partial(
    jax.jit,
    static_argnames=("max_tau", "loss_fn", "eval_fn", "use_pallas", "interpret"),
)
def _bucketed_events_compact(server, disp, accum, xs, ys, ms, taus, wcs,
                             keeps, fs, ids, rms, pms, lr, eval_x, eval_y, *,
                             max_tau: int, loss_fn, eval_fn,
                             use_pallas: bool, interpret: bool):
    """Slot-compact twin of ``_bucketed_events`` for sub-batched jagged
    segments: each scan step trains only its <= seg_batch arrival SLOTS —
    ``ids`` gathers the slots' dispatch models out of the (K, ...) carry
    and the redispatch decisions scatter back — so the per-step working
    set is bounded by the slot count however wide the fleet or the widest
    flush group is. Padding slots carry tau = 0, weight 0, mask 0 (exact
    no-ops on a gathered copy of learner 0). xs: (H, B, d_cap, F);
    ys/ms: (H, B, d_cap); taus/wcs/ids: (H, B); rms/pms: (H, B) bool;
    keeps/fs: (H,). Flush/redispatch semantics match ``_bucketed_events``
    row for row; only the accumulate fold is chunked, so params agree to
    float tolerance."""
    from repro.kernels import ops

    k_fleet = jax.tree_util.tree_leaves(disp)[0].shape[0]

    def one_bucket(carry, inp):
        x, y, m, tau, w, keep, f, idr, rm, pm = inp

        def process(op):
            server, dp, acc = op
            sub = jax.tree_util.tree_map(
                lambda leaf: jnp.take(leaf, idr, axis=0), dp
            )
            server1, acc2 = ops.train_agg_step(
                sub, x, y, m, tau, w, lr, loss_fn=loss_fn, max_tau=max_tau,
                server=server, acc=acc, keep=keep, flush=f,
                use_pallas=use_pallas, interpret=interpret,
            )
            # scatter the slots' redispatch decisions to learner rows
            # (<= 1 arrival per learner per chunk, so add == or)
            post_k = jnp.zeros((k_fleet,), jnp.int32).at[idr].add(
                pm.astype(jnp.int32)) > 0
            pre_k = jnp.zeros((k_fleet,), jnp.int32).at[idr].add(
                (rm & jnp.logical_not(pm)).astype(jnp.int32)) > 0
            dp1 = jax.tree_util.tree_map(
                lambda old, new_post, new_pre: jnp.where(
                    post_k.reshape((-1,) + (1,) * new_post.ndim),
                    new_post[None],
                    jnp.where(
                        pre_k.reshape((-1,) + (1,) * new_pre.ndim),
                        new_pre[None], old,
                    ),
                ),
                dp, server1, server,
            )
            a_out = (
                jax.lax.cond(
                    f > 0,
                    lambda s: eval_fn(s, eval_x, eval_y).astype(jnp.float32),
                    lambda s: jnp.float32(0),
                    server1,
                )
                if eval_fn is not None else jnp.float32(0)
            )
            return (server1, dp1, acc2), a_out

        def skip(op):
            return op, jnp.float32(0)

        return jax.lax.cond(jnp.any(rm), process, skip, carry)

    (server, disp, accum), accs = jax.lax.scan(
        one_bucket, (server, disp, accum),
        (xs, ys, ms, taus, wcs, keeps, fs, ids, rms, pms),
    )
    return server, accs


def summarize_async_history(history: list[dict], *,
                            counters: dict | None = None,
                            energy: dict | None = None) -> dict:
    """Fleet-level summary of an async run: the version-staleness profile
    (mean/max AND p50/p90/p99 quantiles) over all aggregated uploads,
    aggregation counts, the virtual time span, and — under ``counters``
    (pass ``engine.fault_counters``) — the fault tallies of the schedule
    (drops, retries, deadline misses, quorum degradations, ...). The
    ``faults`` dict always carries every ``FAULT_COUNTERS`` key so
    consumers need no presence checks; without ``counters`` it is all
    zeros. Barrier (cycle) rows carry zero version staleness by
    construction.

    The ``energy`` section summarizes the joule ledger: per-upload
    dispatch energies from the history rows (total, p50/p99), plus —
    under ``energy`` (pass ``engine.energy_ledger``) — the engine's
    per-learner joule totals over ALL dispatches (aggregated or not) and
    the count of dispatches that overran their ``e_budget``. The
    violation count is zero by construction under ``scheme="kkt_energy"``
    (the policy caps every (tau, d) inside the budget); an energy-blind
    scheme under finite budgets reports its overruns here. All keys are
    always present (zeros without an ``EnergyModel``)."""
    stal: list[int] = []
    joules: list[float] = []
    for rec in history:
        stal.extend(rec.get("staleness_list", [0] * len(rec["learners"])))
        joules.extend(np.atleast_1d(rec.get("energy", [])).tolist())
    jarr = np.asarray(joules, np.float64)
    ledger = energy or {}
    per_learner = ledger.get("per_learner")
    return {
        "aggregations": len(history),
        "uploads": int(sum(len(r["learners"]) for r in history)),
        "virtual_time": float(history[-1]["t"]) if history else 0.0,
        "staleness": version_staleness_profile(np.asarray(stal)),
        "final_accuracy": history[-1].get("accuracy") if history else None,
        "faults": {**_zero_fault_counters(), **(counters or {})},
        "energy": {
            "joules_total": float(jarr.sum()) if jarr.size else 0.0,
            "joules_p50": float(np.percentile(jarr, 50)) if jarr.size else 0.0,
            "joules_p99": float(np.percentile(jarr, 99)) if jarr.size else 0.0,
            "per_learner": (np.asarray(per_learner, np.float64)
                            if per_learner is not None else None),
            "violations": int(ledger.get("violations", 0)),
        },
    }
