"""Multi-tenant asynchronous federation: S models time-sharing one fleet.

FedAST (arXiv 2406.00302) trains several federated models *simultaneously*
on one shared client population, steering more client time toward the
model that is furthest behind. This module lifts the single-model
``fed.async_engine`` to that regime: a :class:`MultiModelEngine` runs S
independent models — each with its own params pytree, dataset shards,
staleness discount and FedAsync/FedBuff server — on ONE shared pool of K
learners, where every (re)dispatch first runs a **cross-model allocation
layer** before the paper's per-model (tau, d) solve:

  1. a progress-deficit signal is read off the per-model server versions
     (``deficit_s = max_v - v_s`` — FedAST-style behind-ness). The signal
     is deliberately **model-value-free** (round counts, never losses or
     params), so the whole event schedule stays bit-reproducible and the
     eager / device-resident replays of one schedule agree for free — the
     same cornerstone invariant the single-model engine is built on;
  2. ``core.solver_batched.cross_model_weights`` turns the deficits into
     per-model shares ``w_s`` on a 2^-20 binary grid (sum provably <= 1),
     splitting each learner's time budget ``T`` — and, when an
     ``EnergyModel`` budget is attached, its joule budget — across the S
     models: model s dispatches under deadline ``w_s * T``;
  3. the per-model (tau, d) comes from the existing traced
     ``batched_policy`` applied to the (S, K) problem tensor in ONE
     compiled solve (``multimodel_policy``): model rows whose share cannot
     cover even ``c0 + c1 * d_lo`` degrade to padded slots instead of
     going infeasible (the feasible-or-degraded idiom shared with churn).

The S event chains share one virtual clock, one fault process and one
availability process: a single heap carries every model's arrivals,
deadlines and quorum timers, a single fault rng decides drops / delays /
stragglers in dispatch order, and an offline learner defers ALL of its
models' dispatches. Per-model servers evolve independently — each model
keeps its own version counter, buffer and staleness discount.

Exactness anchors (pinned by ``tests/test_multimodel.py``):

  * **S = 1 is the single-model engine, record for record.** The unit
    split is static (``w = 1.0`` exactly, no mask, no scaling), every
    solve routes through the SAME ``solve_policy_row`` /
    ``solve_rows_availability`` / ``solve_rows_state_coupled`` calls the
    single-model engine makes, the engine rng draws one partitioner seed
    then (under faults) one fault seed — so versions, weights, staleness,
    times and shard draws reproduce ``AsyncFedEngine`` bitwise (params to
    float tolerance), under drift, faults and availability alike.
  * **barrier + M = K reproduces ``Orchestrator.run`` bitwise** at S = 1
    (the paper's cycle-gated scheme as the degenerate point of the whole
    family), via the same numpy ``SCHEMES`` solve the single-model
    barrier uses.

Execution reuses the single-model executors verbatim: the event timeline
is model-independent, so after ONE host schedule build the S models
replay through ``async_engine._replay_eager_schedule`` (eager) or
``async_engine._run_group_program`` (one XLA program per model, with
per-model staged tensors — models may have entirely different param
pytrees / feature widths, which is why the scan is per model rather than
stacked)."""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (
    AllocationProblem,
    CapacityDrift,
    aggregate,
    fedavg_weights,
    is_state_coupled,
    staleness_weights,
)
from repro.core.availability import (
    availability_masks,
    capacity_state_coupled,
    has_availability,
)
from repro.core.solver_batched import (
    SPLIT_POLICIES,
    apply_active_mask,
    multimodel_policy,
)
from repro.core.staleness import avg_staleness, max_staleness, staleness_factor
from repro.data.pipeline import FederatedPartitioner
from repro.fed.async_engine import (
    AsyncConfig,
    _Arrival,
    _Schedule,
    _event_segments,
    _EV_ARRIVE,
    _EV_DEADLINE,
    _EV_QUORUM,
    _replay_eager_schedule,
    _run_group_program,
    _zero_fault_counters,
)
from repro.fed.orchestrator import (
    ENERGY_SCHEMES,
    SCHEMES,
    _stage_shards,
    coefficient_rows,
    local_train,
    policy_energy_args,
    policy_problem_args,
    solve_policy_row,
    solve_rows_state_coupled,
)

__all__ = ["MultiModelEngine", "solve_multimodel_rows"]

import heapq

# a zero-share model's dispatch is deferred to the next block via a typed
# heap event (NOT immediate recursion: its deficit should be re-read at the
# boundary, after the other models' intervening aggregations)
_EV_REDISPATCH = 3

# scheduler-level AsyncConfig fields that must agree across the S models:
# one virtual clock, one allocation scheme, one fault/availability process
_SHARED_CFG_FIELDS = (
    "scheme", "reallocate", "barrier", "drop_rate", "delay_rate",
    "delay_mean", "straggler_rate", "straggler_factor", "deadline",
    "retry_backoff", "retry_backoff_cap", "quorum", "flush_timeout",
)


@functools.lru_cache(maxsize=None)
def _jitted_multimodel(scheme: str, split: str, share_floor: float):
    """One jitted cross-model policy per (scheme, split, floor) so every
    re-dispatch re-solve hits the same compilation cache."""
    return jax.jit(
        multimodel_policy(scheme, split=split, share_floor=share_floor)
    )


def solve_multimodel_rows(
    scheme: str,
    c2r,
    c1r,
    c0r,
    problems,
    deficits,
    *,
    split: str = "deficit",
    share_floor: float = 0.0,
    label: str,
    active=None,
    e_budget=None,
):
    """(tau, d, w) for S models sharing one (K,) capacity row — the
    multi-model twin of ``orchestrator.solve_policy_row``.

    The S models' problem tensors are stacked into an (S, K) batch, the
    deficit-driven split computed inside the traced
    ``multimodel_policy``, and the whole thing solved as ONE compiled
    ``batched_policy`` call. Operand construction mirrors
    ``solve_policy_row`` exactly (f64 under ``enable_x64``, the same
    ``policy_problem_args`` / ``policy_energy_args`` row builders), so at
    S = 1 — where the traced policy is a static pass-through — the solve
    is the single-model solve on identical operands.

    ``active`` (optional (K,) bool) masks offline learners out of EVERY
    model's row (one physical fleet: a churned learner serves nobody);
    ``e_budget`` (optional (K,) joules, energy-aware schemes only)
    tightens each model's static budget, e.g. with a battery charge
    state. Returns ``(tau, d, w)`` with tau/d ``(S, K)`` int64 and ``w``
    the (S,) split weights actually applied."""
    problems = list(problems)
    s = len(problems)
    k = problems[0].num_learners
    stacked = [policy_problem_args(p) for p in problems]
    T1 = np.concatenate([a[0] for a in stacked])
    total1 = np.concatenate([a[1] for a in stacked])
    lo1 = np.concatenate([a[2] for a in stacked])
    hi1 = np.concatenate([a[3] for a in stacked])
    valid1 = np.concatenate([a[4] for a in stacked])
    energy1 = None
    if scheme in ENERGY_SCHEMES:
        rows = [policy_energy_args(p) for p in problems]
        e2r, e1r, e0r, ebr = (
            np.concatenate([r[i] for r in rows]) for i in range(4)
        )
        if e_budget is not None:
            ebr = np.minimum(
                ebr, np.asarray(e_budget, np.float64).reshape(1, k)
            )
        energy1 = (e2r, e1r, e0r, ebr)
    elif e_budget is not None:
        raise ValueError(
            f"e_budget needs an energy-aware scheme "
            f"({' | '.join(sorted(ENERGY_SCHEMES))}); scheme {scheme!r} "
            "cannot honor it"
        )
    if active is not None:
        act = np.broadcast_to(np.asarray(active, bool).reshape(1, k), (s, k))
        if not act.any():
            z = np.zeros((s, k), np.int64)
            return z, z.copy(), np.full(s, 1.0 / s)
    policy = _jitted_multimodel(scheme, split, float(share_floor))
    with enable_x64():
        deficits_j = jnp.asarray(np.asarray(deficits, np.float64))
        total_j, lo_j, hi_j, valid_j = (
            jnp.asarray(total1), jnp.asarray(lo1),
            jnp.asarray(hi1), jnp.asarray(valid1),
        )
        if active is not None:
            total_j, lo_j, hi_j, valid_j = apply_active_mask(
                total_j, lo_j, hi_j, valid_j, jnp.asarray(act)
            )
        row = lambda r: jnp.broadcast_to(
            jnp.asarray(np.asarray(r, np.float64))[None], (s, k)
        )
        base_args = (
            row(c2r), row(c1r), row(c0r), jnp.asarray(T1), total_j,
            lo_j, hi_j, valid_j,
        )
        if energy1 is not None:
            en_j = tuple(jnp.asarray(e) for e in energy1)
            tau, d, ok, w = policy(deficits_j, *base_args, en_j)
        else:
            tau, d, ok, w = policy(deficits_j, *base_args)
        tau = np.asarray(tau)
        d = np.asarray(d)
        ok = np.asarray(ok, bool)
        w = np.asarray(w, np.float64)
    if not ok.all():
        raise ValueError(
            "infeasible: even with tau=0 the deadline T cannot absorb "
            f"d samples (model {int(np.argmin(ok))} at {label})"
        )
    return tau.astype(np.int64), d.astype(np.int64), w


def _broadcast(value, s: int, name: str) -> list:
    """Per-model sequence from a shared value or an S-sequence."""
    if isinstance(value, (list, tuple)):
        if len(value) != s:
            raise ValueError(f"{name} needs 1 or {s} entries, got {len(value)}")
        return list(value)
    return [value] * s


class MultiModelEngine:
    """S models time-sharing one K-learner fleet under deficit-driven
    cross-model allocation (see module docstring).

    Parameters
    ----------
    cfgs : one ``AsyncConfig`` (shared) or a sequence of S. Per-model
        server knobs (mode, alpha, staleness discount, aggregation,
        buffer size, lr) may differ; scheduler-level knobs (scheme,
        reallocate, barrier, every fault knob) must agree — there is one
        clock and one fault process.
    problems : sequence of S ``AllocationProblem`` sharing one
        ``TimeModel`` and one deadline ``T`` (the physical fleet and its
        per-cycle budget being split); totals, d-boxes and energy budgets
        are per model.
    loss_fns : one callable (shared) or a sequence of S — models may
        have entirely different architectures.
    init_params : ONE pytree shared by every model, or a *tuple* of S
        per-model pytrees (tuple marks the container; lists are pytrees).
    split : cross-model split policy (``core.solver_batched
        .SPLIT_POLICIES``): ``"deficit"`` (FedAST-style behind-ness) or
        ``"equal"``.
    share_floor : minimum share per model under the deficit split (keeps
        a far-ahead model from starving entirely).
    seed, drift : as in ``AsyncFedEngine`` — ONE drift/availability
        process gates all S models.
    """

    def __init__(
        self,
        cfgs,
        problems,
        loss_fns,
        init_params,
        *,
        seed: int = 0,
        drift: CapacityDrift | None = None,
        split: str = "deficit",
        share_floor: float = 0.0,
    ):
        if isinstance(problems, AllocationProblem):
            problems = [problems]
        self.problems = list(problems)
        s = len(self.problems)
        if s < 1:
            raise ValueError("need at least one model")
        self.num_models = s
        self.cfgs = _broadcast(cfgs, s, "cfgs")
        self.loss_fns = _broadcast(loss_fns, s, "loss_fns")
        # a params pytree can itself be a list, so the per-model container
        # is marked by TYPE: a tuple holds S per-model pytrees; any other
        # value (a list included) is ONE pytree shared by every model
        if isinstance(init_params, tuple):
            if len(init_params) != s:
                raise ValueError(
                    f"init_params tuple needs {s} per-model pytrees, got "
                    f"{len(init_params)}; pass a non-tuple to share one"
                )
            self.params = list(init_params)
        else:
            self.params = [init_params] * s
        if split not in SPLIT_POLICIES:
            raise ValueError(
                f"unknown split {split!r}: {' | '.join(SPLIT_POLICIES)}"
            )
        self.split = split
        self.share_floor = float(share_floor)
        cfg0 = self.cfgs[0]
        for field in _SHARED_CFG_FIELDS:
            vals = {getattr(c, field) for c in self.cfgs}
            if len(vals) > 1:
                raise ValueError(
                    f"AsyncConfig.{field} is scheduler-level (one clock, "
                    f"one fault process): all models must agree, got {vals}"
                )
        self.cfg = cfg0                       # the shared scheduler view
        p0 = self.problems[0]
        k = p0.num_learners
        tm0 = p0.time_model
        for i, p in enumerate(self.problems[1:], start=1):
            if p.num_learners != k or p.T != p0.T:
                raise ValueError(
                    "all models share one physical fleet and one budget: "
                    f"model {i} has (K={p.num_learners}, T={p.T}), model 0 "
                    f"(K={k}, T={p0.T})"
                )
            tm = p.time_model
            if not all(
                np.array_equal(getattr(tm, f), getattr(tm0, f))
                for f in ("c2", "c1", "c0")
            ):
                raise ValueError(
                    f"model {i}'s TimeModel differs from model 0's — the "
                    "capacities describe the shared fleet hardware"
                )
        self.rng = np.random.default_rng(seed)
        self.drift = drift
        self.buffer_sizes = []
        for i, c in enumerate(self.cfgs):
            m = c.buffer_size or k
            if not (1 <= m <= k):
                raise ValueError(f"model {i}: buffer_size must be in [1, K={k}]")
            if c.barrier and m != k:
                raise ValueError(
                    "the cycle barrier gates on the whole fleet: it requires "
                    f"buffer_size == K (= {k}); M < K is the event-driven "
                    "buffered regime"
                )
            if c.quorum > m:
                raise ValueError(
                    f"model {i}: quorum (= {c.quorum}) must be <= "
                    f"buffer_size (= {m}): a full buffer flushes on its own"
                )
            self.buffer_sizes.append(m)
        if has_availability(drift):
            if cfg0.barrier:
                raise ValueError(
                    "availability churn has no barrier regime (one offline "
                    "learner would gate every round forever); use the "
                    "event-driven modes"
                )
            coupled = capacity_state_coupled(drift)
        else:
            coupled = is_state_coupled(drift)
        if coupled and not cfg0.reallocate:
            raise ValueError(
                "state-coupled drift ties capacities to the dispatched "
                "allocations; the engine supports it only with "
                "reallocate=True (per-block re-solves drive the state)"
            )
        if coupled and s > 1:
            raise ValueError(
                "state-coupled drift has no multi-model rollout: its "
                "capacity rows depend on the dispatched allocations, which "
                "here depend on deficits known only at dispatch time; run "
                "S = 1 or use an exogenous/availability drift"
            )
        # up-front feasibility of every UNSPLIT problem (and the numpy
        # allocations the S = 1 barrier path replays bitwise)
        self.allocations = [SCHEMES[cfg0.scheme](p) for p in self.problems]
        # (block, deficits) -> ((S, K) tau, (S, K) d) — deficit-keyed,
        # unlike the single-model per-block cache, because the split
        # changes with the models' relative progress
        self._alloc_cache: dict = {}
        self._block_masks: np.ndarray | None = None
        self._avail_ebud: list | None = None
        self.fault_counters: dict = _zero_fault_counters()
        self.energy_ledger: dict = {
            "per_learner": np.zeros(k), "violations": 0,
        }
        self.energy_ledgers: list[dict] = [
            {"per_learner": np.zeros(k), "violations": 0} for _ in range(s)
        ]
        self.split_weight_log: list[np.ndarray] = []

    # -- allocation ----------------------------------------------------------
    def _deficit_key(self, versions) -> tuple:
        """The dispatch-time deficit vector (FedAST behind-ness): how many
        aggregations each model trails the front-runner by. Computed from
        server versions only — model-value-free by construction."""
        v = np.asarray(versions, np.float64)
        return tuple((v.max() - v).tolist())

    def _solve_row_multi(self, c2r, c1r, c0r, deficits, *, label,
                         active=None, e_budget=None):
        """(S, K) allocation on one capacity row. S = 1 routes through the
        single-model ``solve_policy_row`` — the SAME call the single-model
        engine makes, so the unit-split equivalence is literal code
        sharing; S > 1 is the one-call multi-model solve."""
        if self.num_models == 1:
            tau, d = solve_policy_row(
                self.cfg.scheme, c2r, c1r, c0r, self.problems[0],
                label=label, active=active, e_budget=e_budget,
            )
            return tau[None], d[None], np.ones(1)
        return solve_multimodel_rows(
            self.cfg.scheme, c2r, c1r, c0r, self.problems, deficits,
            split=self.split, share_floor=self.share_floor, label=label,
            active=active, e_budget=e_budget,
        )

    def _rollout_availability(self, nblocks: int):
        """Joint rollout of capacity rows, online masks AND per-block
        uniform-deficit allocations under an availability process — the
        multi-model twin of ``orchestrator.solve_rows_availability`` (at
        S = 1 it IS that loop: same per-block masked ``solve_policy_row``,
        same state advance). The availability state is driven by the
        fleet's aggregate work — per-learner max tau and summed d across
        models. Dispatch-time solves with nonzero deficits re-solve
        against the stored per-block masks/budgets."""
        drift = self.drift
        tm = self.problems[0].time_model
        k = tm.num_learners
        budgeted = (self.cfg.scheme in ENERGY_SCHEMES
                    and hasattr(drift, "budget_at"))
        c2s = np.empty((nblocks, k))
        c1s = np.empty((nblocks, k))
        c0s = np.empty((nblocks, k))
        masks = np.zeros((nblocks, k), bool)
        self._avail_ebud = [None] * nblocks
        uniform = (0.0,) * self.num_models
        state = drift.state_init(k)
        for c in range(nblocks):
            mask = np.asarray(drift.online_at(c, k, state))
            with enable_x64():
                clock, rate = drift.factors_at(c, k, state)
                clock = np.asarray(clock, np.float64)
                rate = np.asarray(rate, np.float64)
            c2r = tm.c2 / clock
            c1r = tm.c1 / rate
            c0r = tm.c0 / rate
            e_b = drift.budget_at(c, k, state) if budgeted else None
            tau, d, _ = self._solve_row_multi(
                c2r, c1r, c0r, uniform,
                label=f"capacities at drift block {c}",
                active=mask, e_budget=e_b,
            )
            state = drift.state_update(
                c, state,
                jnp.asarray(tau.max(axis=0)), jnp.asarray(d.sum(axis=0)),
            )
            masks[c] = mask
            c2s[c], c1s[c], c0s[c] = c2r, c1r, c0r
            self._avail_ebud[c] = e_b
            self._alloc_cache[(c, uniform)] = (tau, d)
        return (c2s, c1s, c0s), masks

    def _block_rows(self, nblocks: int):
        """(C, K) capacity rows per drift block, mirroring the
        single-model engine's ``_block_rows`` regime split (frozen vs
        adaptive, exogenous vs availability vs state-coupled)."""
        drift = self.drift
        self._block_masks = None
        self._avail_ebud = None
        uniform = (0.0,) * self.num_models
        if has_availability(drift):
            if self.cfg.reallocate:
                rows, masks = self._rollout_availability(nblocks)
                self._block_masks = masks
                return rows
            tau0, d0, _ = self._alloc_static(uniform)
            self._block_masks = availability_masks(
                drift, self.problems[0].num_learners, nblocks,
                tau=tau0.max(axis=0), d=d0.sum(axis=0),
            )
            return coefficient_rows(self.problems[0], drift.base, nblocks)
        if is_state_coupled(drift):
            # S = 1 only (rejected in __init__ otherwise): prefill the
            # cache with the SAME joint rollout the single-model engine uses
            rows, (taus, ds) = solve_rows_state_coupled(
                self.cfg.scheme, drift, self.problems[0], nblocks,
                label="capacities at drift block {}",
            )
            for b in range(nblocks):
                self._alloc_cache[(b, uniform)] = (taus[b][None], ds[b][None])
            return rows
        return coefficient_rows(self.problems[0], drift, nblocks)

    def _alloc_static(self, deficits: tuple):
        """Static (base-capacity) allocation for one deficit vector."""
        key = ("static", deficits)
        hit = self._alloc_cache.get(key)
        if hit is None:
            tm = self.problems[0].time_model
            tau, d, w = self._solve_row_multi(
                tm.c2.astype(np.float64), tm.c1.astype(np.float64),
                tm.c0.astype(np.float64), deficits, label="base capacities",
            )
            hit = (tau, d)
            self._alloc_cache[key] = hit
            self.split_weight_log.append(np.asarray(w))
        return hit[0], hit[1], None

    def _alloc_for_block(self, block: int, deficits: tuple, rows, realloc):
        """(S, K) allocation for one (drift block, deficit vector) pair,
        cached — the multi-model generalization of the single-model
        per-block cache (the deficit key collapses to a single entry at
        S = 1, reproducing the per-block granularity)."""
        if not realloc:
            tau, d, _ = self._alloc_static(deficits)
            return tau, d
        key = (block, deficits)
        hit = self._alloc_cache.get(key)
        if hit is None:
            c2s, c1s, c0s = rows
            mask = (self._block_masks[block]
                    if self._block_masks is not None else None)
            e_b = (self._avail_ebud[block]
                   if self._avail_ebud is not None else None)
            tau, d, w = self._solve_row_multi(
                c2s[block], c1s[block], c0s[block], deficits,
                label=f"capacities at drift block {block}",
                active=mask, e_budget=e_b,
            )
            hit = (tau, d)
            self._alloc_cache[key] = hit
            self.split_weight_log.append(np.asarray(w))
        return hit

    # -- schedule ------------------------------------------------------------
    def _build_schedules(self, parts, horizon: float, max_events: int):
        """ONE host simulation of the S interleaved event systems: a
        shared heap, a shared fault rng, shared availability masks, and
        per-model version/buffer/flush bookkeeping. Returns one
        ``_Schedule`` per model (so each model's replay stages tensors at
        its own d_cap/max_tau) plus shared fault counters.

        Every structural decision mirrors ``AsyncFedEngine
        ._build_schedule`` — at S = 1 the loop IS that loop: identical
        event ordering, identical rng consumption (one partitioner seed
        was drawn by the caller, the fault seed is drawn here only under
        ``cfg.has_faults``), identical allocation calls."""
        cfg, probs = self.cfg, self.problems
        s = self.num_models
        p0 = probs[0]
        k_fleet, T = p0.num_learners, p0.T
        nblocks = max(int(np.ceil(horizon / T)) + 1, 1)
        rows = self._block_rows(nblocks)
        masks = self._block_masks
        realloc = cfg.reallocate and self.drift is not None
        frng = (np.random.default_rng(int(self.rng.integers(2**31)))
                if cfg.has_faults else None)
        counters = _zero_fault_counters()
        e_rows = [p.energy_rows() for p in probs]
        energy_spent = np.zeros((s, k_fleet))
        energy_violations = np.zeros(s, np.int64)
        heap: list = []
        seq = 0
        versions = np.zeros(s, np.int64)
        arrivals: list[list[_Arrival]] = [[] for _ in range(s)]
        groups: list[list[_Arrival]] = [[] for _ in range(s)]
        flush_ids = np.zeros(s, np.int64)
        next_did = 0
        dstate: dict[int, str] = {}
        open_gids = np.full(s, -1, np.int64)
        gid_counter = 0
        n_arrivals = 0

        def push(t: float, kind: int, payload) -> None:
            nonlocal seq
            heapq.heappush(heap, (t, kind, seq, payload))
            seq += 1

        def dispatch(si: int, k: int, t: float, attempt: int = 0) -> None:
            nonlocal next_did
            block = min(int(t // T), nblocks - 1)
            if masks is not None:
                b = block
                while b < nblocks and not masks[b][k]:
                    b += 1
                if b >= nblocks or b * T > horizon:
                    counters["offline_churned"] += 1
                    return
                if b != block:
                    counters["offline_deferrals"] += 1
                    block, t = b, b * T
            deficits = self._deficit_key(versions)
            tau_a, d_a = self._alloc_for_block(block, deficits, rows, realloc)
            tau_k, d_k = int(tau_a[si][k]), int(d_a[si][k])
            if masks is not None and d_k == 0:
                # the masked solve starved this (online) learner — the
                # budget fit inside the rest of the fleet; try next block
                if (block + 1) * T <= horizon and block + 1 < nblocks:
                    dispatch(si, k, (block + 1) * T, attempt)
                else:
                    counters["offline_churned"] += 1
                return
            if d_k == 0:
                # S > 1: this model's share on learner k rounded to
                # nothing this round — park the chain at the next block
                # boundary, where the deficit is re-read AFTER any
                # intervening aggregations (a typed event, not recursion)
                if (block + 1) * T <= horizon and block + 1 < nblocks:
                    push((block + 1) * T, _EV_REDISPATCH, (si, k, attempt))
                else:
                    counters["offline_churned"] += 1
                return
            idx = parts[si].draw_indices(d_k)
            c2, c1, c0 = (r[block, k] for r in rows)
            cost = float(c2 * tau_k * d_k + c1 * d_k + c0)
            counters["dispatches"] += 1
            energy_j = 0.0
            if e_rows[si] is not None:
                e2k, e1k, e0k, ebk = (row[k] for row in e_rows[si])
                energy_j = float(e2k * tau_k * d_k + e1k * d_k + e0k)
                energy_spent[si][k] += energy_j
                if energy_j > ebk * (1 + 1e-9):
                    energy_violations[si] += 1
            dropped = False
            if frng is not None:
                # fixed per-dispatch draw order: straggle -> delay -> drop
                if (cfg.straggler_rate > 0
                        and frng.random() < cfg.straggler_rate):
                    counters["stragglers"] += 1
                    cost *= cfg.straggler_factor
                if cfg.delay_rate > 0 and frng.random() < cfg.delay_rate:
                    counters["delays"] += 1
                    cost += float(frng.exponential(cfg.delay_mean))
                dropped = cfg.drop_rate > 0 and frng.random() < cfg.drop_rate
            did = next_did
            next_did += 1
            dstate[did] = "pending"
            if dropped:
                counters["drops"] += 1
            else:
                push(t + cost, _EV_ARRIVE,
                     (si, did, k, t, int(versions[si]), tau_k, d_k, idx,
                      attempt, energy_j))
            if cfg.deadline > 0:
                push(t + cfg.deadline, _EV_DEADLINE, (si, did, k, attempt))

        def close_group(si: int, t_flush: float, timer: bool) -> None:
            """Flush model si's open buffered group (arrival-triggered at
            M_si, or a quorum timer) — per-model staleness knobs."""
            nonlocal gid_counter
            c = self.cfgs[si]
            group = groups[si]
            taus = np.array([g.tau for g in group], float)
            ds = np.array([g.d for g in group], float)
            phi = staleness_factor(
                np.array([g.staleness for g in group], float),
                kind=c.staleness_fn, a=c.staleness_a, b=c.staleness_b,
            )
            base = (fedavg_weights(ds)
                    if c.aggregation == "fedavg" else
                    staleness_weights(taus, ds, gamma=c.staleness_gamma))
            w = base * phi
            w = w / w.sum()
            for g, wg in zip(group, w):
                g.weight = float(wg)
                g.flush_id = int(flush_ids[si])
            closer = group[-1]
            closer.flush = True
            closer.timer_flush = timer
            closer.flush_t = t_flush
            closer.keep = 0.0
            closer.group_weights = np.asarray(w, np.float64)
            versions[si] += 1
            closer.version_after = int(versions[si])
            flush_ids[si] += 1
            groups[si] = []
            open_gids[si] = -1

        for k in range(k_fleet):
            for si in range(s):
                dispatch(si, k, 0.0)

        while heap and n_arrivals < max_events:
            t_e, kind, _, payload = heapq.heappop(heap)
            if t_e > horizon:
                break
            if kind == _EV_REDISPATCH:
                si, k, attempt = payload
                dispatch(si, k, t_e, attempt)
                continue
            if kind == _EV_DEADLINE:
                si, did, k, attempt = payload
                if dstate.get(did) != "pending":
                    continue
                dstate[did] = "cancelled"
                counters["deadline_misses"] += 1
                counters["retries"] += 1
                backoff = min(cfg.retry_backoff * (2.0 ** attempt),
                              cfg.retry_backoff_cap)
                dispatch(si, k, t_e + backoff, attempt + 1)
                continue
            if kind == _EV_QUORUM:
                si, gid, extended = payload
                if gid != open_gids[si] or not groups[si]:
                    continue
                if len(groups[si]) >= cfg.quorum:
                    counters["quorum_flushes"] += 1
                    close_group(si, t_e, timer=True)
                elif not extended:
                    counters["quorum_extensions"] += 1
                    push(t_e + cfg.flush_timeout, _EV_QUORUM, (si, gid, True))
                else:
                    counters["quorum_degradations"] += 1
                    close_group(si, t_e, timer=True)
                continue
            si, did, k, t_disp, v_disp, tau_k, d_k, idx, attempt, e_j = payload
            if dstate.get(did) == "cancelled":
                counters["late_discards"] += 1
                continue
            dstate[did] = "arrived"
            c = self.cfgs[si]
            a = _Arrival(
                seq=len(arrivals[si]), learner=k, t=t_e, tau=tau_k, d=d_k,
                idx=idx, dispatch_t=t_disp, dispatch_version=v_disp,
                staleness=int(versions[si]) - v_disp, energy=e_j,
            )
            groups[si].append(a)
            arrivals[si].append(a)
            n_arrivals += 1
            if c.mode == "fedasync":
                phi = staleness_factor(
                    np.array([a.staleness], float),
                    kind=c.staleness_fn, a=c.staleness_a, b=c.staleness_b,
                )
                w = np.array([c.alpha]) * phi
                a.weight = float(w[0])
                a.flush_id = int(flush_ids[si])
                a.flush = True
                a.flush_t = t_e
                a.keep = 1.0 - float(w[0])
                a.group_weights = np.asarray(w, np.float64)
                versions[si] += 1
                a.version_after = int(versions[si])
                flush_ids[si] += 1
                groups[si] = []
            elif len(groups[si]) == self.buffer_sizes[si]:
                close_group(si, t_e, timer=False)
            else:
                if cfg.quorum > 0 and len(groups[si]) == 1:
                    gid_counter += 1
                    open_gids[si] = gid_counter
                    push(t_e + cfg.flush_timeout, _EV_QUORUM,
                         (si, gid_counter, False))
                a.version_after = int(versions[si])
            dispatch(si, k, t_e)   # immediate redispatch, current server

        self.server_versions = versions.copy()
        scheds = [
            _Schedule(
                arrivals=arrivals[si], n_flushes=int(flush_ids[si]),
                d_cap=max([a.d for a in arrivals[si]], default=1),
                max_tau=max([a.tau for a in arrivals[si]] + [1]),
                counters=counters,
                energy_spent=energy_spent[si],
                energy_violations=int(energy_violations[si]),
            )
            for si in range(s)
        ]
        return scheds, counters

    # -- run prep ------------------------------------------------------------
    def _prep_run(self, trains, eval_fns, eval_batches):
        s = self.num_models
        trains = _broadcast(trains, s, "trains")
        eval_fns = _broadcast(eval_fns, s, "eval_fns")
        eval_batches = _broadcast(eval_batches, s, "eval_batches")
        for i, (fn, b) in enumerate(zip(eval_fns, eval_batches)):
            if fn is not None and b is None:
                raise ValueError(f"model {i}: eval_fn needs eval_batch=(x, y)")
        # per-model partitioner seeds drawn in MODEL ORDER from the engine
        # rng (one draw at S = 1 — the single-model engine's stream)
        parts = [
            FederatedPartitioner(tr, seed=int(self.rng.integers(2**31)))
            for tr in trains
        ]
        return trains, eval_fns, eval_batches, parts

    def _set_ledgers(self, scheds) -> None:
        self.energy_ledgers = [
            {"per_learner": sc.energy_spent, "violations": sc.energy_violations}
            for sc in scheds
        ]
        self.energy_ledger = {
            "per_learner": sum(sc.energy_spent for sc in scheds),
            "violations": int(sum(sc.energy_violations for sc in scheds)),
        }

    # -- eager event loop ----------------------------------------------------
    def run(
        self,
        trains,
        horizon: float | None = None,
        *,
        cycles: int | None = None,
        eval_fns=None,
        eval_batches=None,
        max_events: int = 100_000,
    ) -> list[list[dict]]:
        """Simulate to virtual time ``horizon``; returns one history list
        per model (each row as in ``AsyncFedEngine.run``, plus a
        ``"model"`` index). With ``cfg.barrier=True`` the run is
        round-gated instead (pass ``cycles``) and at S = 1 reproduces
        ``Orchestrator.run`` exactly for the same seed."""
        if self.cfg.barrier:
            return self._run_barrier(
                trains, horizon=horizon, cycles=cycles,
                eval_fns=eval_fns, eval_batches=eval_batches,
            )
        if horizon is None:
            raise ValueError("event mode needs a virtual-time horizon")
        self.fault_counters = _zero_fault_counters()
        trains, eval_fns, eval_batches, parts = self._prep_run(
            trains, eval_fns, eval_batches
        )
        scheds, counters = self._build_schedules(parts, horizon, max_events)
        self.fault_counters = counters
        self._set_ledgers(scheds)
        histories: list[list[dict]] = []
        for si in range(self.num_models):
            evalj, ex, ey = self._eval_triplet(eval_fns[si], eval_batches[si])
            self.params[si], hist = _replay_eager_schedule(
                self.params[si], scheds[si], trains[si],
                mode=self.cfgs[si].mode, lr=self.cfgs[si].lr,
                num_learners=self.problems[0].num_learners,
                loss_fn=self.loss_fns[si], evalj=evalj, ex=ex, ey=ey,
            )
            for rec in hist:
                rec["model"] = si
            histories.append(hist)
        return histories

    # -- event-indexed device-resident fast path ------------------------------
    def run_events(
        self,
        trains,
        horizon: float,
        *,
        eval_fns=None,
        eval_batches=None,
        use_pallas: bool = False,
        interpret: bool = False,
        max_events: int = 100_000,
    ) -> list[list[dict]]:
        """``run`` as S jitted ``lax.scan`` programs — ONE shared host
        schedule build, then each model's jagged event segments replay
        through ``async_engine._run_group_program`` with that model's own
        staged tensors and param pytree (models may differ in
        architecture, so the scans are per model). History rows match
        ``run``'s bitwise (shared schedule); params to float tolerance."""
        if self.cfg.barrier:
            raise ValueError(
                "the barrier (cycle-gated) regime is the eager paper "
                "scheme; run_events is the event-driven fast path"
            )
        self.fault_counters = _zero_fault_counters()
        trains, eval_fns, eval_batches, parts = self._prep_run(
            trains, eval_fns, eval_batches
        )
        scheds, counters = self._build_schedules(parts, horizon, max_events)
        self.fault_counters = counters
        self._set_ledgers(scheds)
        histories: list[list[dict]] = []
        for si in range(self.num_models):
            segments = _event_segments(scheds[si].arrivals)
            if not segments:
                histories.append([])
                continue
            self.params[si], hist = _run_group_program(
                self.params[si], segments, scheds[si], trains[si],
                mode=self.cfgs[si].mode, lr=self.cfgs[si].lr,
                num_learners=self.problems[0].num_learners,
                loss_fn=self.loss_fns[si], eval_fn=eval_fns[si],
                eval_batch=eval_batches[si],
                use_pallas=use_pallas, interpret=interpret,
            )
            for rec in hist:
                rec["model"] = si
            histories.append(hist)
        return histories

    # -- barrier (paper-scheme) rounds ---------------------------------------
    def _run_barrier(self, trains, *, horizon, cycles, eval_fns,
                     eval_batches):
        """Cycle-gated rounds for all S models: per cycle ONE cross-model
        solve fixes every model's (tau, d) (all versions advance together
        under the barrier, so the deficit vector stays uniform), then each
        model trains and aggregates its own fleet-wide round. At S = 1
        the static allocation is the numpy ``SCHEMES`` solve — the
        bitwise ``Orchestrator.run`` anchor."""
        cfg, probs = self.cfg, self.problems
        s = self.num_models
        p0 = probs[0]
        if cycles is None:
            if horizon is None:
                raise ValueError("barrier mode needs cycles or horizon")
            cycles = int(np.floor(horizon / p0.T + 1e-9))
        trains, eval_fns, eval_batches, parts = self._prep_run(
            trains, eval_fns, eval_batches
        )
        self.fault_counters = _zero_fault_counters()
        e_rows = [p.energy_rows() for p in probs]
        k = p0.num_learners
        energy_spent = np.zeros((s, k))
        energy_violations = np.zeros(s, np.int64)
        evals = [
            self._eval_triplet(fn, b)
            for fn, b in zip(eval_fns, eval_batches)
        ]
        rows = (self._block_rows(cycles)
                if cfg.reallocate and self.drift is not None else None)
        uniform = (0.0,) * s
        histories: list[list[dict]] = [[] for _ in range(s)]
        for c in range(cycles):
            if rows is not None:
                tau_all, d_all = self._alloc_for_block(c, uniform, rows, True)
            elif s == 1:
                tau_all = np.asarray(self.allocations[0].tau)[None]
                d_all = np.asarray(self.allocations[0].d)[None]
            else:
                tau_all, d_all, _ = self._alloc_static(uniform)
            for si in range(s):
                tau = np.asarray(tau_all[si])
                d = np.asarray(d_all[si])
                ci = self.cfgs[si]
                shards = parts[si].draw(d)
                feat = trains[si].x.shape[1]
                x, y, msk = _stage_shards(shards, int(d.max()), feat)
                locals_ = local_train(
                    self.params[si], jnp.asarray(x), jnp.asarray(y),
                    jnp.asarray(msk), jnp.asarray(tau),
                    jnp.asarray(ci.lr, jnp.float32),
                    max_tau=max(int(tau.max()), 1), loss_fn=self.loss_fns[si],
                )
                if ci.aggregation == "staleness":
                    w = staleness_weights(tau, d, gamma=ci.staleness_gamma)
                else:
                    w = fedavg_weights(d)
                self.params[si] = aggregate(locals_, jnp.asarray(w))
                if e_rows[si] is not None:
                    e2r, e1r, e0r, ebr = e_rows[si]
                    e_c = np.where(d > 0, e2r * tau * d + e1r * d + e0r, 0.0)
                    energy_spent[si] += e_c
                    energy_violations[si] += int(np.sum(e_c > ebr * (1 + 1e-9)))
                else:
                    e_c = np.zeros(k)
                rec = {
                    "event": c,
                    "t": (c + 1) * p0.T,
                    "mode": "cycle",
                    "server_version": c + 1,
                    "learners": list(range(k)),
                    "tau": tau.copy(),
                    "d": d.copy(),
                    "staleness_list": [0] * k,
                    "version_staleness_max": 0,
                    "version_staleness_mean": 0.0,
                    "weights": np.asarray(w, np.float64),
                    "keep": 0.0,
                    "energy": e_c,
                    "max_staleness": max_staleness(tau),
                    "avg_staleness": avg_staleness(tau),
                    "cycle": c,
                    "elapsed_s": (c + 1) * p0.T,
                    "wall_clock_s": p0.T,
                    "model": si,
                }
                evalj, ex, ey = evals[si]
                if evalj is not None:
                    rec["accuracy"] = float(evalj(self.params[si], ex, ey))
                histories[si].append(rec)
        self.energy_ledgers = [
            {"per_learner": energy_spent[si],
             "violations": int(energy_violations[si])}
            for si in range(s)
        ]
        self.energy_ledger = {
            "per_learner": energy_spent.sum(axis=0),
            "violations": int(energy_violations.sum()),
        }
        return histories

    # -- shared pieces -------------------------------------------------------
    @staticmethod
    def _eval_triplet(eval_fn, eval_batch):
        if eval_fn is None:
            return None, None, None
        if eval_batch is None:
            raise ValueError("eval_fn needs eval_batch=(x, y)")
        return (jax.jit(eval_fn), jnp.asarray(eval_batch[0]),
                jnp.asarray(eval_batch[1]))
