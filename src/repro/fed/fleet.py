"""Two-tier fleet-of-fleets federation over the device mesh.

Everything below ``fed/fleet.py`` simulates ONE K<=10 edge fleet. This
module is the population-scale layer: F fleets x K learners live as sharded
fleet tensors — an ``(F, K)`` ``BatchedProblems`` struct for the allocation
problems/capacities, ``(F, K, d_cap, feat)`` staged sample tensors, and a
params-per-fleet pytree with a leading F axis — laid out over a mesh from
``launch.mesh`` with the ``sharding.rules.FLEET_RULES`` logical axis. Each
global round is ONE jitted XLA program wrapped in ``compat.shard_map``:

  1. every fleet runs its paper-scheme cycle — masked ``local_train`` to
     the fleet-wide max tau, vmapped over the local fleet shard, then the
     fleet server's staleness-weighted aggregation (``aggregate``'s exact
     contraction, vmapped);
  2. the global server merges the round's SAMPLED fleets (FedAST-style
     partial participation, arxiv 2406.00302): each sampled fleet's model
     is weighted by its data volume times the version-staleness discount
     ``staleness_factor(g - pull_version)`` — fleets that trained on an
     old pull are trusted less on arrival — normalized by a ``psum`` over
     the mesh axes the fleet dim is split over, and mixed into the global
     model at ``server_mix``;
  3. the next dispatch is solved for the sampled fleets with ONE
     ``batched_policy`` call on the sampling-masked ``(F, K)`` problem
     tensors (``apply_sampling_mask``: a sampled-out fleet is exactly an
     all-offline fleet is exactly a row of padded slots), while unsampled
     fleets keep training on their stale dispatch.

Exactness discipline (pinned by ``tests/test_fleet.py``): with F = 1, full
participation, and a 1-device mesh, every stage above degenerates bitwise
to the single-fleet path — the vmap has one slice, the merge weight is
exactly 1.0, ``server_mix=1`` selects the merged model unblended — so the
fleet engine reproduces ``Orchestrator.run`` results exactly, record for
record. Fleet f's partitioner seed is drawn from the engine rng in fleet
order, so fleet 0 consumes the same ``(seed, draw-index)``-keyed shard
draws the orchestrator's partitioner does.

Scale: ``host_mesh()`` gives the (2, 4) ``"test"`` mesh under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the fleet-scale CI
step) and the 1-device ``"cpu"`` mesh elsewhere; ``benchmarks/fleet_scale``
drives 10^4 trained and 10^6 solved learners per virtual-time unit through
the same two programs.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.solver_batched import (
    BatchedProblems,
    TRACED_POLICIES,
    apply_active_mask,
    apply_sampling_mask,
    batched_avg_staleness,
    batched_max_staleness,
    batched_policy,
    cross_model_weights,
)
from repro.core.staleness import STALENESS_FNS, staleness_factor
from repro.data.pipeline import Dataset, FederatedPartitioner
from repro.fed.orchestrator import ENERGY_SCHEMES, _weights_traced, local_train
from repro.launch.mesh import host_mesh
from repro.sharding.rules import fleet_partition_axes

__all__ = ["FleetConfig", "FleetEngine", "build_fleet_problems"]

# fold-in stream tag for the per-round fleet-sampling keys (disjoint from
# partitioner draw keys, which live under per-fleet seeds)
_SAMPLE_STREAM = 0x5AB5


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs of the two-tier engine (per-fleet problem knobs live in the
    ``BatchedProblems`` struct passed to ``FleetEngine``)."""

    lr: float = 0.1
    scheme: str = "kkt_sai"            # traced policy for the fleet solves
    aggregation: str = "staleness"     # intra-fleet: staleness | fedavg
    staleness_gamma: float = 1.0
    participation: float = 1.0         # fraction of fleets sampled per round
    server_mix: float = 1.0            # global-server mixing rate (1 = replace)
    staleness_fn: str = "poly"         # cross-tier discount on stale fleets
    staleness_a: float = 0.5
    staleness_b: float = 4.0

    def __post_init__(self):
        if self.scheme not in TRACED_POLICIES:
            raise ValueError(
                f"the fleet engine solves through batched_policy; scheme "
                f"{self.scheme!r} has none ({' | '.join(TRACED_POLICIES)})"
            )
        if self.aggregation not in ("staleness", "fedavg"):
            raise ValueError(f"unknown aggregation {self.aggregation!r}")
        if not (0.0 < self.participation <= 1.0):
            raise ValueError("participation must be in (0, 1]")
        if not (0.0 < self.server_mix <= 1.0):
            raise ValueError("server_mix must be in (0, 1]")
        if self.staleness_fn not in STALENESS_FNS:
            raise ValueError(
                f"unknown staleness fn {self.staleness_fn!r}: "
                + " | ".join(STALENESS_FNS)
            )


def build_fleet_problems(
    f: int,
    k: int = 8,
    *,
    T: float = 6.0,
    total_samples: int = 60,
    seed: int = 0,
    jitter: float = 0.25,
) -> BatchedProblems:
    """An (F, K) fleet population around the hand-tuned spread coefficients:
    every draw comes from one generator keyed by ``seed`` drawing whole
    (F, K) tensors at once (no per-fleet iteration-order dependence), so
    the population is reproducible across processes."""
    base_c2 = np.array([0.050, 0.031, 0.022, 0.045, 0.027, 0.038, 0.019, 0.042])
    base_c1 = np.array([0.004, 0.006, 0.003, 0.005, 0.002, 0.004, 0.006, 0.003])
    base_c0 = np.array([0.40, 0.55, 0.30, 0.25, 0.45, 0.35, 0.50, 0.28])
    if k > base_c2.size:
        reps = -(-k // base_c2.size)
        base_c2, base_c1, base_c0 = (
            np.tile(a, reps) for a in (base_c2, base_c1, base_c0)
        )
    rng = np.random.default_rng(np.random.SeedSequence((seed, f, k)))
    scale = np.exp(jitter * rng.standard_normal((3, f, k)))
    c2 = base_c2[:k][None] * scale[0]
    c1 = base_c1[:k][None] * scale[1]
    c0 = base_c0[:k][None] * scale[2]
    return BatchedProblems(
        c2=c2, c1=c1, c0=c0,
        T=np.full(f, float(T)),
        total=np.full(f, int(total_samples), np.int64),
        d_lo=np.full((f, k), float(max(1, total_samples // (2 * k)))),
        d_hi=np.full((f, k), float(min(total_samples, 2 * total_samples // k))),
        valid=np.ones((f, k), bool),
    )


def _fleet_spec(axes: tuple[str, ...], extra: int = 0) -> P:
    """PartitionSpec for a tensor whose LEADING dim is the fleet axis and
    whose remaining ``extra`` dims are per-fleet payload (unsharded)."""
    if not axes:
        lead = None
    elif len(axes) == 1:
        lead = axes[0]
    else:
        lead = axes
    return P(lead, *([None] * extra))


def _tree_fleet_specs(tree, axes):
    return jax.tree_util.tree_map(
        lambda leaf: _fleet_spec(axes, extra=leaf.ndim - 1), tree
    )


def _psum(x, axes):
    return jax.lax.psum(x, axes) if axes else x


def _wsum(leaf, w):
    """``core.aggregation.aggregate``'s exact weighted contraction over the
    leading axis (bitwise-shared so the fleet server matches the eager
    orchestrator's aggregation)."""
    ww = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
    return (leaf * ww).sum(axis=0)


@functools.partial(
    jax.jit, static_argnames=("scheme", "mesh", "fleet_axes"),
)
def _fleet_solve(c2, c1, c0, T, total, d_lo, d_hi, valid, sampled, *en,
                 scheme: str, mesh, fleet_axes):
    """ONE ``batched_policy`` call for every fleet's (tau, d), sharded over
    the fleet axis under ``shard_map``; sampled-out fleets get the padded
    -slot projection and solve to zeros. Run under ``enable_x64`` with f64
    rows for exact integer allocations. Energy-aware schemes take four
    trailing (F, K) rows — ``(e2, e1, e0, e_budget)`` — sharded like the
    other per-learner tensors."""
    policy = batched_policy(scheme)

    def body(c2, c1, c0, T, total, d_lo, d_hi, valid, sampled, *en):
        tot_m, lo_m, hi_m, valid_m = apply_sampling_mask(
            total, d_lo, d_hi, valid, sampled
        )
        if en:
            return policy(c2, c1, c0, T, tot_m, lo_m, hi_m, valid_m, en)
        return policy(c2, c1, c0, T, tot_m, lo_m, hi_m, valid_m)

    row = _fleet_spec(fleet_axes, extra=1)
    vec = _fleet_spec(fleet_axes)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(row, row, row, vec, vec, row, row, row, vec)
        + (row,) * len(en),
        out_specs=(row, row, vec),
    )(c2, c1, c0, T, total, d_lo, d_hi, valid, sampled, *en)


@functools.partial(
    jax.jit,
    static_argnames=("max_tau", "loss_fn", "eval_fn", "aggregation",
                     "scheme", "mesh", "fleet_axes", "use_pallas",
                     "interpret"),
)
def _fleet_round(g, fleet_params, x, y, m, tau, d, base_w, sampled, mix, lr,
                 gamma, c2, c1, c0, T, total, d_lo, d_hi, valid, ex, ey, *en,
                 max_tau: int, loss_fn, eval_fn, aggregation: str,
                 scheme: str, mesh, fleet_axes, use_pallas: bool = False,
                 interpret: bool = False):
    """One global round as one XLA program (see module docstring): vmapped
    per-fleet train+aggregate, psum-normalized two-tier merge of the
    sampled fleets, and the next dispatch's sampling-masked policy solve.
    Must run under ``enable_x64`` (f64 solve/weight math, f32 training).

    Returns ``(new_global, new_fleet_params, tau', d', feasible, acc)``.
    """
    policy = batched_policy(scheme)
    row = _fleet_spec(fleet_axes, extra=1)
    vec = _fleet_spec(fleet_axes)
    rep = P()

    def body(g, fleet_params, x, y, m, tau, d, base_w, sampled,
             mix, lr, gamma, c2, c1, c0, T, total, d_lo, d_hi, valid,
             ex, ey, *en):
        # -- tier 1: each fleet trains its K learners and aggregates ------
        def fleet_step(fp, xf, yf, mf, tf, df):
            w = _weights_traced(tf, df, aggregation=aggregation, gamma=gamma)
            if use_pallas:
                from repro.kernels import ops

                kf = xf.shape[0]
                disp = jax.tree_util.tree_map(
                    lambda leaf: jnp.broadcast_to(leaf, (kf,) + leaf.shape),
                    fp,
                )
                new, _ = ops.train_agg_step(
                    disp, xf, yf, mf, tf, w, lr, loss_fn=loss_fn,
                    max_tau=max_tau, use_pallas=True, interpret=interpret,
                )
                return new
            locals_ = local_train(
                fp, xf, yf, mf, tf, lr, max_tau=max_tau, loss_fn=loss_fn
            )
            return jax.tree_util.tree_map(
                functools.partial(_wsum, w=w), locals_
            )

        fleet_new = jax.vmap(fleet_step)(fleet_params, x, y, m, tau, d)

        # -- tier 2: staleness-discounted merge of the sampled fleets -----
        bw = jnp.where(sampled, base_w, 0.0)
        norm = _psum(bw.sum(), fleet_axes)
        any_sampled = norm > 0.0
        wg = (bw / jnp.where(any_sampled, norm, 1.0)).astype(jnp.float32)
        merged = jax.tree_util.tree_map(
            lambda leaf: _psum(_wsum(leaf, wg), fleet_axes), fleet_new
        )
        # server_mix == 1 SELECTS the merged model (no 0*g + 1*m blend:
        # that would flip signed zeros and break the F=1 bitwise contract)
        full = (mix == jnp.ones((), mix.dtype)) & any_sampled

        def mix_leaf(mleaf, gleaf):
            blend = ((1.0 - mix) * gleaf + mix * mleaf).astype(gleaf.dtype)
            out = jnp.where(full, mleaf, blend)
            return jnp.where(any_sampled, out, gleaf)

        new_g = jax.tree_util.tree_map(mix_leaf, merged, g)

        # -- next dispatch: ONE masked policy solve for sampled fleets ----
        tot_m, lo_m, hi_m, valid_m = apply_sampling_mask(
            total, d_lo, d_hi, valid, sampled
        )
        if en:
            tau_n, d_n, feas = policy(
                c2, c1, c0, T, tot_m, lo_m, hi_m, valid_m, en
            )
        else:
            tau_n, d_n, feas = policy(c2, c1, c0, T, tot_m, lo_m, hi_m, valid_m)
        tau_out = jnp.where(sampled[:, None], tau_n, tau)
        d_out = jnp.where(sampled[:, None], d_n, d)

        # sampled fleets pull the new global; the rest keep training stale
        def pull_leaf(fn_leaf, g_leaf):
            keep = sampled.reshape((-1,) + (1,) * g_leaf.ndim)
            return jnp.where(keep, g_leaf[None], fn_leaf)

        fleet_out = jax.tree_util.tree_map(pull_leaf, fleet_new, new_g)

        acc = (eval_fn(new_g, ex, ey).astype(jnp.float32)
               if eval_fn is not None else jnp.float32(0))
        return new_g, fleet_out, tau_out, d_out, feas, acc

    g_specs = jax.tree_util.tree_map(lambda _: rep, g)
    fp_specs = _tree_fleet_specs(fleet_params, fleet_axes)
    in_specs = (
        g_specs, fp_specs,
        _fleet_spec(fleet_axes, 3), _fleet_spec(fleet_axes, 2),
        _fleet_spec(fleet_axes, 2),                       # x, y, m
        row, row, vec, vec,                               # tau, d, base_w, sampled
        rep, rep, rep,                                    # mix, lr, gamma
        row, row, row, vec, vec, row, row, row,           # problem tensors
        rep, rep,                                         # eval batch
    ) + (row,) * len(en)                                  # energy rows
    out_specs = (g_specs, fp_specs, row, row, vec, rep)
    return compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(g, fleet_params, x, y, m, tau, d, base_w, sampled, mix, lr, gamma,
      c2, c1, c0, T, total, d_lo, d_hi, valid, ex, ey, *en)


class FleetEngine:
    """F fleets x K learners, two-tier servers, one XLA program per round.

    ``problems`` is the (F, K) ``BatchedProblems`` population (build one
    with ``build_fleet_problems``). ``mesh`` defaults to ``host_mesh()``
    — the 8-fake-device ``"test"`` mesh when the process has one, else
    the 1-device ``"cpu"`` mesh. F is padded up to a multiple of the mesh
    device count with all-invalid fleets (never sampled, zero weight, zero
    work: the ``BatchedProblems`` padded-slot semantics lifted one axis
    up) so the fleet dim always splits evenly."""

    def __init__(self, cfg: FleetConfig, problems: BatchedProblems, loss_fn,
                 init_params, *, seed: int = 0, mesh=None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.global_params = init_params
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.mesh = host_mesh() if mesh is None else mesh
        n_dev = int(np.prod(list(self.mesh.shape.values())))

        self.num_fleets = problems.num_problems
        f_pad = -(-self.num_fleets // n_dev) * n_dev
        self.problems = self._pad_problems(problems, f_pad)
        self.fleet_axes = fleet_partition_axes(f_pad, self.mesh)
        self._real = np.zeros(f_pad, bool)
        self._real[: self.num_fleets] = True

        self.global_version = 0
        self.pull_version = np.zeros(f_pad, np.int64)
        self.rounds_run = 0
        self.tau, self.d = self._solve(self._real)
        self._check_feasible(self._real, self._last_feasible, "initial dispatch")
        # every fleet starts from the global model (version-0 dispatch)
        self.fleet_params = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (f_pad,) + p.shape),
            init_params,
        )

    @staticmethod
    def _pad_problems(bp: BatchedProblems, f_pad: int) -> BatchedProblems:
        f = bp.num_problems
        if f == f_pad:
            return bp
        pad = lambda a, fill: np.concatenate(
            [np.asarray(a),
             np.full((f_pad - f,) + np.asarray(a).shape[1:], fill,
                     np.asarray(a).dtype)]
        )
        energy = {}
        if bp.has_energy:
            # padded fleets are free: zero coefficients, infinite budget
            k = np.asarray(bp.c2).shape[1]
            e2, e1, e0, eb = bp.energy_rows()
            energy = dict(
                e2=pad(e2, 0.0), e1=pad(e1, 0.0), e0=pad(e0, 0.0),
                e_budget=pad(np.broadcast_to(eb, (f, k)), np.inf),
            )
        return BatchedProblems(
            c2=pad(bp.c2, 1.0), c1=pad(bp.c1, 1.0), c0=pad(bp.c0, 0.0),
            T=pad(bp.T, 1.0), total=pad(bp.total, 0),
            d_lo=pad(bp.d_lo, 0.0), d_hi=pad(bp.d_hi, 0.0),
            valid=pad(bp.valid, False), **energy,
        )

    # -- allocation ---------------------------------------------------------
    def _solve_args(self):
        bp = self.problems
        return (
            jnp.asarray(bp.c2, jnp.float64), jnp.asarray(bp.c1, jnp.float64),
            jnp.asarray(bp.c0, jnp.float64), jnp.asarray(bp.T, jnp.float64),
            jnp.asarray(bp.total, jnp.int64),
            jnp.asarray(bp.d_lo, jnp.float64),
            jnp.asarray(bp.d_hi, jnp.float64),
            jnp.asarray(bp.valid),
        )

    def _energy_args(self) -> tuple:
        """Trailing ``(e2, e1, e0, e_budget)`` policy rows — only for
        energy-aware schemes (problems without an energy model get zero
        coefficients and infinite budgets, reproducing ``kkt_sai``)."""
        if self.cfg.scheme not in ENERGY_SCHEMES:
            return ()
        f_pad, k = np.asarray(self.problems.c2).shape
        rows = self.problems.energy_rows()
        e2, e1, e0, eb = (np.broadcast_to(r, (f_pad, k)) for r in rows)
        return tuple(jnp.asarray(r, jnp.float64) for r in (e2, e1, e0, eb))

    def _solve(self, sampled: np.ndarray):
        """(tau, d) int64 host arrays for the sampled fleets (zeros in the
        rest) — one sharded batched_policy call."""
        with enable_x64():
            tau, d, feas = _fleet_solve(
                *self._solve_args(), jnp.asarray(sampled, bool),
                *self._energy_args(),
                scheme=self.cfg.scheme, mesh=self.mesh,
                fleet_axes=self.fleet_axes,
            )
            tau = np.asarray(tau, np.int64)
            d = np.asarray(d, np.int64)
            self._last_feasible = np.asarray(feas, bool)
        return tau, d

    def solve_multimodel(self, deficits, *, split: str = "deficit",
                         share_floor: float = 0.0, sampled=None):
        """(tau, d, w) for S tenant models time-sharing the whole (F, K)
        population — the fleet-scale face of the cross-model allocation
        layer (``core.solver_batched.multimodel_policy``).

        ``deficits`` is the (S,) global progress-deficit signal (one per
        tenant's GLOBAL server); ``cross_model_weights`` turns it into
        shares ``w`` splitting every fleet's deadline ``T_f`` (and joule
        budgets, for energy-aware schemes), per-model sample budgets are
        scaled by ``round(w_s * total_f)``, and cells whose share cannot
        cover ``d_lo`` at tau = 0 degrade to padded slots — semantics
        mirroring ``multimodel_policy`` exactly, lifted one axis up. The
        S x F problems are flattened model-major to ``(S * F_pad, K)``
        and solved with ONE sharded ``_fleet_solve`` call
        (``fleet_partition_axes`` falls back to replication when the
        flattened dim does not divide the mesh).

        Returns ``(tau, d, w)`` with tau/d ``(S, F_pad, K)`` int64.
        S = 1 short-circuits to ``_solve`` — the SAME call the
        single-tenant rounds make, bitwise."""
        sampled = self._real if sampled is None else np.asarray(sampled, bool)
        deficits = np.asarray(deficits, np.float64)
        s = int(deficits.shape[0])
        if s == 1:
            tau, d = self._solve(sampled)
            return tau[None], d[None], np.ones(1)
        f_pad, k = np.asarray(self.problems.c2).shape
        axes = fleet_partition_axes(s * f_pad, self.mesh)
        en = self._energy_args()
        with enable_x64():
            w = cross_model_weights(
                jnp.asarray(deficits), policy=split, share_floor=share_floor
            )
            c2, c1, c0, T, total, lo, hi, valid = self._solve_args()
            tile = lambda a: jnp.tile(a, (s,) + (1,) * (a.ndim - 1))
            w_f = jnp.repeat(w.astype(T.dtype), f_pad)        # (S*F_pad,)
            T_s = w_f * tile(T)
            total_s = jnp.round(
                w_f * tile(total).astype(T.dtype)
            ).astype(total.dtype)
            c2_t, c1_t, c0_t = tile(c2), tile(c1), tile(c0)
            lo_t, hi_t, valid_t = tile(lo), tile(hi), tile(valid)
            active = valid_t & (T_s[:, None] >= c0_t + c1_t * lo_t)
            total_s, lo_t, hi_t, valid_t = apply_active_mask(
                total_s, lo_t, hi_t, valid_t, active
            )
            if en:
                e2, e1, e0, eb = (tile(e) for e in en)
                eb = jnp.where(jnp.isinf(eb), eb, w_f[:, None] * eb)
                en = (e2, e1, e0, eb)
            tau, d, feas = _fleet_solve(
                c2_t, c1_t, c0_t, T_s, total_s, lo_t, hi_t, valid_t,
                jnp.asarray(np.tile(sampled, s)), *en,
                scheme=self.cfg.scheme, mesh=self.mesh, fleet_axes=axes,
            )
            tau = np.asarray(tau, np.int64).reshape(s, f_pad, k)
            d = np.asarray(d, np.int64).reshape(s, f_pad, k)
            feas = np.asarray(feas, bool).reshape(s, f_pad)
            w = np.asarray(w, np.float64)
        for si in range(s):
            self._check_feasible(sampled, feas[si],
                                 f"multimodel solve, model {si}")
        return tau, d, w

    def _check_feasible(self, sampled, feas, label: str):
        bad = self._real & np.asarray(sampled, bool) & ~np.asarray(feas, bool)
        if bad.any():
            raise ValueError(
                "infeasible: even with tau=0 the deadline T cannot absorb "
                f"d samples (fleet {int(np.argmax(bad))} at {label})"
            )

    # -- per-round host staging --------------------------------------------
    def _sample_mask(self, r: int) -> np.ndarray:
        f = self.num_fleets
        mask = np.zeros(self._real.size, bool)
        if self.cfg.participation >= 1.0:
            mask[:f] = True
            return mask
        n = max(1, int(round(self.cfg.participation * f)))
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _SAMPLE_STREAM, r))
        )
        mask[rng.choice(f, size=n, replace=False)] = True
        return mask

    def _stage(self, parts, train: Dataset, d_cap: int):
        f_pad, k = self.problems.c2.shape
        feat = train.x.shape[1]
        x = np.zeros((f_pad, k, d_cap, feat), np.float32)
        y = np.zeros((f_pad, k, d_cap), np.int32)
        m = np.zeros((f_pad, k, d_cap), np.float32)
        for f in range(self.num_fleets):
            row = self.d[f]
            idx = parts[f].draw_indices(int(row.sum()))
            off = 0
            for kk in range(k):
                dk = int(row[kk])
                if dk:
                    sl = idx[off:off + dk]
                    x[f, kk, :dk] = train.x[sl]
                    y[f, kk, :dk] = train.y[sl]
                    m[f, kk, :dk] = 1.0
                    off += dk
        return x, y, m

    # -- full run -----------------------------------------------------------
    def run(self, train: Dataset, rounds: int, *, eval_fn=None,
            eval_batch=None, use_pallas: bool = False,
            interpret: bool = False) -> list[dict]:
        """Run ``rounds`` global rounds; returns one history record per
        round. ``eval_fn`` must be jit-traceable ``(params, x, y) ->
        scalar`` (e.g. ``mlp.accuracy``) evaluated on ``eval_batch`` inside
        the round program. ``use_pallas`` routes each fleet's vmapped
        train+aggregate step through the ``ops.train_agg_step`` megakernel
        (``interpret=True`` emulates it on CPU); the default keeps the
        unfused ``local_train`` + ``_wsum`` tier-1 body. Repeated calls
        continue from the current state (fresh partitioners, like
        ``Orchestrator.run``)."""
        if eval_fn is not None and eval_batch is None:
            raise ValueError("eval_fn needs eval_batch=(x, y)")
        cfg = self.cfg
        parts = [
            FederatedPartitioner(train, seed=int(self.rng.integers(2**31)))
            for _ in range(self.num_fleets)
        ]
        ex, ey = ((jnp.asarray(eval_batch[0]), jnp.asarray(eval_batch[1]))
                  if eval_fn is not None
                  else (jnp.zeros((1, train.x.shape[1]), jnp.float32),
                        jnp.zeros((1,), jnp.int32)))
        t_round = float(self.problems.T[self._real].max())
        history: list[dict] = []
        for r in range(self.rounds_run, self.rounds_run + rounds):
            sampled = self._sample_mask(r)
            d_cap = max(1, int(self.d[self._real].max()))
            max_tau = max(1, int(self.tau[self._real].max()))
            x, y, m = self._stage(parts, train, d_cap)
            stale = np.maximum(self.global_version - self.pull_version, 0)
            phi = staleness_factor(
                stale, kind=cfg.staleness_fn, a=cfg.staleness_a,
                b=cfg.staleness_b,
            )
            n_f = self.d.sum(axis=1).astype(np.float64)
            base_w = np.where(self._real, n_f * phi, 0.0)
            with enable_x64():
                (g, fp, tau_n, d_n, feas, acc) = _fleet_round(
                    self.global_params, self.fleet_params,
                    jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
                    jnp.asarray(self.tau), jnp.asarray(self.d),
                    jnp.asarray(base_w, jnp.float64),
                    jnp.asarray(sampled),
                    jnp.asarray(cfg.server_mix, jnp.float32),
                    jnp.asarray(cfg.lr, jnp.float32),
                    jnp.asarray(cfg.staleness_gamma, jnp.float64),
                    *self._solve_args(), ex, ey, *self._energy_args(),
                    max_tau=max_tau, loss_fn=self.loss_fn, eval_fn=eval_fn,
                    aggregation=cfg.aggregation, scheme=cfg.scheme,
                    mesh=self.mesh, fleet_axes=self.fleet_axes,
                    use_pallas=use_pallas, interpret=interpret,
                )
                feas_h = np.asarray(feas, bool)
            self._check_feasible(sampled, feas_h, f"round {r}")
            self.global_params, self.fleet_params = g, fp
            tau_h = np.asarray(tau_n, np.int64)
            d_h = np.asarray(d_n, np.int64)
            merged = sampled & self._real
            rec = {
                "round": r,
                "cycle": r,
                "elapsed_s": (r + 1) * t_round,
                "wall_clock_s": t_round,
                "fleets": int(self.num_fleets),
                "sampled_fleets": int(merged.sum()),
                "tau": self.tau[self._real].copy(),
                "d": self.d[self._real].copy(),
                "max_staleness": batched_max_staleness(
                    self.tau[self._real], self.problems.valid[self._real]
                ),
                "avg_staleness": batched_avg_staleness(
                    self.tau[self._real], self.problems.valid[self._real]
                ),
                "fleet_staleness_max": int(stale[merged].max()) if merged.any() else 0,
                "fleet_staleness_mean": float(stale[merged].mean()) if merged.any() else 0.0,
            }
            if eval_fn is not None:
                rec["accuracy"] = float(acc)
            history.append(rec)
            # bookkeeping: merge bumps the global version; sampled fleets
            # pulled it and re-dispatched with the freshly solved (tau, d)
            self.global_version += 1
            self.pull_version[merged] = self.global_version
            self.tau, self.d = tau_h, d_h
        self.rounds_run += rounds
        return history
