"""Roofline model for TPU v5e-class hardware from dry-run artifacts.

Three terms, all in seconds (per training/serving step, per chip):

    compute    = HLO_FLOPs / PEAK_FLOPS
    memory     = HLO_bytes / HBM_BW
    collective = sum_c (bytes_c * factor_c) / ICI_BW

``cost_analysis`` on the compiled (already SPMD-partitioned) module reports
per-device FLOPs/bytes. Collective bytes come from parsing the compiled HLO
(see ``hlo_collectives``): cost_analysis does not count them.

Bandwidth factors per collective (ring algorithms, n >> 1):
  all-reduce ~ 2x payload, all-gather / reduce-scatter / all-to-all ~ 1x,
  collective-permute ~ 1x.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = [
    "HW",
    "Hardware",
    "hlo_collectives",
    "roofline_terms",
    "model_flops_per_step",
]


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link (~per chip eff.)


HW = Hardware()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<result>.*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def hlo_collectives(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the (partitioned) HLO.
    '-done' ops are skipped so async pairs are not double counted."""
    out: dict[str, dict] = {k: {"bytes": 0, "count": 0} for k in _COLL_FACTOR}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group("op")
        out[op]["bytes"] += _shape_bytes(m.group("result"))
        out[op]["count"] += 1
    return out


def roofline_terms(flops: float, bytes_accessed: float, collectives: dict, hw: Hardware = HW) -> dict:
    coll_bytes_eff = sum(
        v["bytes"] * _COLL_FACTOR[k] for k, v in collectives.items()
    )
    coll_bytes_raw = sum(v["bytes"] for v in collectives.values())
    terms = {
        "compute_s": flops / hw.peak_flops,
        "memory_s": bytes_accessed / hw.hbm_bw,
        "collective_s": coll_bytes_eff / hw.ici_bw,
        "collective_bytes": coll_bytes_raw,
    }
    dominant = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dominant.replace("_s", "")
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["compute_fraction_of_bound"] = (
        terms["compute_s"] / bound if bound > 0 else 0.0
    )
    return terms


def model_flops_per_step(cfg, shape, n_chips: int) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train; 2 N D for
    a single forward token batch in decode; per chip."""
    total, active = cfg.param_counts()
    if shape.kind == "train":
        mult, tokens = 6.0, shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        mult, tokens = 2.0, shape.global_batch * shape.seq_len
    else:
        mult, tokens = 2.0, shape.global_batch  # one token per sequence
    return mult * active * tokens / n_chips
