"""Loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` (HloCostAnalysis) counts each ``while`` body
ONCE, regardless of trip count — and this framework deliberately keeps
layer stacks, attention KV chunks, SSM time steps, and the chunked loss in
``lax.scan``s, so the built-in numbers undercount by the trip counts.

This module re-derives FLOPs / HBM bytes / collective bytes from
``compiled.as_text()`` with loops expanded:

  * computations are parsed into op lists with a local symbol table
    (op name -> result shape) so operand shapes resolve;
  * ``while`` ops multiply their body cost by the trip count taken from
    the ``backend_config={"known_trip_count":{"n":...}}`` XLA annotates
    (fallback: the s32 constant in the condition computation);
  * ``fusion``/``call`` ops add the called computation's *flops* but only
    the fusion's own operand/result *bytes* (the HloCostAnalysis fusion
    model: interior temporaries never touch HBM);
  * dots count 2 * prod(result) * prod(contracting dims); elementwise
    arithmetic counts 1 FLOP per output element;
  * bytes are operands + results of data-touching ops; layout-only ops
    (bitcast, reshape, tuple, get-tuple-element, ...) are free;
  * collectives are tallied by type, scaled by enclosing trip counts.

Validated against HloCostAnalysis on loop-free graphs and against
hand-unrolled scans in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]"
)

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.*)$"
)
_KIND_RE = re.compile(r"^(?P<shape>.*?)\s(?P<kind>[a-z][\w\-]*)\((?P<tail>.*)$")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?[\w.\-]+\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

# 1 flop per output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "maximum",
    "minimum", "compare", "select", "and", "or", "xor", "not", "sign",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "clamp",
}
# transcendental: count a few flops per element
_TRANSCENDENTAL = {
    "exponential", "log", "tanh", "sqrt", "rsqrt", "power", "logistic",
    "sine", "cosine", "atan2", "exponential-minus-one", "log-plus-one",
    "cbrt", "erf",
}
# pure layout / bookkeeping: free
_FREE = {
    "bitcast", "reshape", "tuple", "get-tuple-element", "parameter",
    "constant", "after-all", "token", "opt-barrier", "custom-call",
    "bitcast-convert", "partition-id", "replica-id", "domain",
}
_DATA_MOVERS = {
    "copy", "slice", "dynamic-slice", "dynamic-update-slice", "pad",
    "concatenate", "gather", "scatter", "transpose", "convert", "broadcast",
    "reverse", "iota", "reduce", "reduce-window", "sort", "select-and-scatter",
    "rng", "rng-bit-generator", "map", "copy-start", "copy-done",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: {"bytes": 0.0, "count": 0.0} for k in _COLLECTIVES}
    )

    def add(self, other: "HloCost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.collectives.items():
            self.collectives[k]["bytes"] += v["bytes"] * scale
            self.collectives[k]["count"] += v["count"] * scale


def _shape_bytes(text: str) -> float:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return float(total)


def _shape_elems(text: str) -> float:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0.0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return float(n)


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _parse_computations(text: str) -> dict[str, list[dict]]:
    comps: dict[str, list[dict]] = {}
    cur_name = None
    cur_ops: list[dict] = []
    for raw in text.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if cur_name is None:
            if _COMP_START_RE.match(ls):
                cur_name = ls.split("(", 1)[0].replace("ENTRY", "").strip().lstrip("%").strip()
                cur_ops = []
            continue
        if ls == "}":
            comps[cur_name] = cur_ops
            cur_name = None
            continue
        m = _OP_RE.match(ls)
        if not m:
            continue
        is_root = ls.startswith("ROOT")
        rest = m.group("rest")
        km = _KIND_RE.match(rest)
        if not km:
            continue
        # split args region from attributes: find matching close paren
        tail = km.group("tail")
        depth, idx = 1, 0
        for idx, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args = tail[:idx]
        attrs = tail[idx + 1 :]
        cur_ops.append(
            {
                "name": m.group("name"),
                "shape": km.group("shape").strip(),
                "kind": km.group("kind"),
                "args": args,
                "attrs": attrs,
                "line": ls,
                "root": is_root,
            }
        )
    return comps


def analyze_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    if not comps:
        return HloCost()

    # computations referenced as fusion/call/to_apply interiors or regions
    referenced: set[str] = set()
    for ops in comps.values():
        for op in ops:
            for mm in _CALLS_RE.finditer(op["attrs"]):
                referenced.add(mm.group(1))
            wm = _WHILE_RE.search(op["attrs"])
            if wm:
                referenced.update(wm.groups())
            bm = _BRANCHES_RE.search(op["attrs"])
            if bm:
                for b in _OPERAND_RE.findall(bm.group(1)):
                    referenced.add(b)

    memo: dict[str, HloCost] = {}

    def trip_count(op, cond_name: str) -> float:
        tm = _TRIP_RE.search(op["attrs"])
        if tm:
            return float(tm.group(1))
        best = 1.0
        for o in comps.get(cond_name, []):
            if o["kind"] == "constant" and o["shape"].startswith("s32"):
                mm = re.search(r"constant\((\d+)\)", o["line"])
                if mm:
                    best = max(best, float(mm.group(1)))
        return best

    def comp_cost(name: str, *, interior: bool) -> HloCost:
        key = f"{name}|{interior}"
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # guard recursion
        total = HloCost()
        symtab = {op["name"]: op["shape"] for op in comps.get(name, [])}

        def operand_bytes(op) -> float:
            b = 0.0
            for oname in _OPERAND_RE.findall(op["args"]):
                if oname in symtab:
                    b += _shape_bytes(symtab[oname])
            # inline-shaped operands (rare)
            if not _OPERAND_RE.findall(op["args"]):
                b += _shape_bytes(op["args"])
            return b

        def nth_operand_bytes(op, idx: int) -> float:
            names = _OPERAND_RE.findall(op["args"])
            if idx < len(names) and names[idx] in symtab:
                return _shape_bytes(symtab[names[idx]])
            return 0.0

        def fusion_io_bytes(callee: str, fusion_op) -> float:
            """HBM traffic of a fusion: per-parameter read = what interior
            consumers actually touch (a parameter consumed only through
            dynamic-slice reads one slice per call; a DUS destination is
            updated in place and reads ~nothing), output write = the update
            region when the root is a dynamic-update-slice, else the result.
            This mirrors HloCostAnalysis' optimized-fusion model and is what
            keeps loop-carried scan buffers from being charged in full on
            every trip."""
            callee_ops = comps.get(callee, [])
            ctab = {o["name"]: o for o in callee_ops}
            root = next((o for o in callee_ops if o["root"]), callee_ops[-1] if callee_ops else None)

            read = 0.0
            for o in callee_ops:
                if o["kind"] != "parameter":
                    continue
                pbytes = _shape_bytes(o["shape"])
                contrib = 0.0
                consumed = False
                for c in callee_ops:
                    names = _OPERAND_RE.findall(c["args"])
                    if o["name"] not in names:
                        continue
                    consumed = True
                    if c["kind"] in ("dynamic-slice", "slice", "gather"):
                        contrib = max(contrib, _shape_bytes(c["shape"]))
                    elif c["kind"] in ("dynamic-update-slice", "scatter") and names and names[0] == o["name"]:
                        # in-place destination: not read
                        contrib = max(contrib, 0.0)
                    else:
                        contrib = max(contrib, pbytes)
                read += contrib if consumed else 0.0

            if root is not None and root["kind"] == "dynamic-update-slice":
                names = _OPERAND_RE.findall(root["args"])
                upd = ctab.get(names[1]) if len(names) > 1 else None
                write = _shape_bytes(upd["shape"]) if upd else _shape_bytes(root["shape"])
            else:
                write = _shape_bytes(fusion_op["shape"])
            return read + write

        def touched_bytes(op) -> float:
            """HBM bytes actually moved: XLA performs slice updates in place,
            so (dynamic-)update-slice/scatter touch only the update region
            and (dynamic-)slice/gather only the extracted region — not the
            whole base buffer (which a naive operands+result model would
            charge once per loop iteration)."""
            kind = op["kind"]
            if kind == "dynamic-update-slice":
                return 2.0 * nth_operand_bytes(op, 1)
            if kind == "scatter":
                return 2.0 * nth_operand_bytes(op, 2) + nth_operand_bytes(op, 1)
            if kind in ("dynamic-slice", "slice", "gather"):
                return 2.0 * _shape_bytes(op["shape"])
            return _shape_bytes(op["shape"]) + operand_bytes(op)

        for op in comps.get(name, []):
            kind = op["kind"]
            if kind == "while":
                wm = _WHILE_RE.search(op["attrs"])
                if wm:
                    cond, body = wm.groups()
                    trips = trip_count(op, cond)
                    total.add(comp_cost(body, interior=False), scale=trips)
                    total.add(comp_cost(cond, interior=False), scale=trips)
                continue
            if kind == "conditional":
                bm = _BRANCHES_RE.search(op["attrs"])
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    costs = [comp_cost(b, interior=False) for b in branches]
                    if costs:
                        worst = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(worst)
                continue
            if kind in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(op["attrs"])
                if cm:
                    inner = comp_cost(cm.group(1), interior=True)
                    total.flops += inner.flops
                    for k, v in inner.collectives.items():
                        total.collectives[k]["bytes"] += v["bytes"]
                        total.collectives[k]["count"] += v["count"]
                if not interior:
                    if cm:
                        total.bytes += fusion_io_bytes(cm.group(1), op)
                    else:
                        total.bytes += _shape_bytes(op["shape"]) + operand_bytes(op)
                continue

            base = kind.removesuffix("-start").removesuffix("-done")
            if base in _COLLECTIVES:
                if kind.endswith("-done"):
                    continue
                total.collectives[base]["bytes"] += _shape_bytes(op["shape"])
                total.collectives[base]["count"] += 1
                if not interior:
                    total.bytes += touched_bytes(op)
                continue

            if kind == "dot" or kind == "convolution":
                out_elems = _shape_elems(op["shape"])
                contract = 1.0
                first_operand = _OPERAND_RE.search(op["args"])
                lhs_dims = (
                    _shape_dims(symtab.get(first_operand.group(1), ""))
                    if first_operand
                    else _shape_dims(op["args"])
                )
                cm = _LHS_CONTRACT_RE.search(op["attrs"])
                if cm and lhs_dims:
                    for d in cm.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
                elif kind == "convolution":
                    contract = max(contract, 1.0)
                total.flops += 2.0 * out_elems * contract
                if not interior:
                    total.bytes += touched_bytes(op)
                continue

            if kind in _ELEMENTWISE:
                total.flops += _shape_elems(op["shape"])
            elif kind in _TRANSCENDENTAL:
                total.flops += 4.0 * _shape_elems(op["shape"])
            elif kind in _FREE:
                continue
            elif kind in _DATA_MOVERS:
                pass
            # every non-free op in a non-interior context touches memory
            if not interior:
                total.bytes += touched_bytes(op)

        memo[key] = total
        return total

    entries = [n for n in comps if n not in referenced]
    result = HloCost()
    for e in entries:
        result.add(comp_cost(e, interior=False))
    return result
