"""Per-learner energy model of the MEL global cycle (arXiv 2012.00143).

The authors' sequel ("Task Allocation for Asynchronous Mobile Edge
Learning with Delay and Energy Constraints") extends the Eq. 5 time
family with a per-learner energy budget.  Each global cycle costs
learner ``k``:

  E_k^C  - compute energy of tau_k local updates over d_k samples:
           kappa * f_k^2 * C_m * tau_k * d_k  (CMOS switched-capacitance
           model: energy/clock = kappa * f_k^2, clocks = C_m * tau_k * d_k)
  E_k^S/R - transmit energy of the data + model transfers: the same
           bit volumes as Eq. 1/3 at transmit power P_k over rate R_k,
           i.e. P_k * t^{S,R}_k

Total:   E_k = e2_k * tau_k * d_k + e1_k * d_k + e0_k

with
  e2_k = kappa * f_k^2 * C_m                  (compute, J per sample-update)
  e1_k = P_k * (F * P_d + 2 P_m S_d) / R_k    (per-sample transfer)
  e0_k = P_k * 2 P_m S_m / R_k                (model down + up)

— the exact energy mirror of ``TimeModel``'s (C2, C1, C0): same
hyperbolic structure in (tau, d), so the KKT water-filling pipeline
absorbs the budget as one more per-learner cap on the (tau_k, d_k) box
(``solver_kkt.solve_energy`` / ``batched_policy("kkt_energy")``).

``BatteryDrift`` closes the loop with client state: dispatched work
drains a per-learner battery, a seeded recharge process refills it, and
an empty battery takes the learner offline through the same
``online_at`` protocol as the churn processes in ``availability.py``.

Everything is plain numpy float math (host-side), with jax appearing
only inside ``BatteryDrift``'s drift-protocol methods — the same split
as ``time_model.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.time_model import CapacityDrift, LearnerProfile

__all__ = [
    "BatteryDrift",
    "EnergyModel",
]


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Vectorized energy coefficients (e2, e1, e0) for K learners.

    Attributes
    ----------
    e2, e1, e0 : np.ndarray shape (K,)
        Quadratic / linear / constant coefficients of the per-cycle
        energy ``E_k = e2 tau d + e1 d + e0`` (joules).
    """

    e2: np.ndarray
    e1: np.ndarray
    e0: np.ndarray

    @property
    def num_learners(self) -> int:
        return int(self.e2.shape[0])

    @staticmethod
    def build(
        profiles: Sequence[LearnerProfile],
        *,
        model_complexity_flops: float,     # C_m: clocks (~= FLOPs) per sample per epoch
        model_size_bits: float,            # P_m * S_m, full serialized model
        kappa: float = 1e-28,              # effective switched capacitance (J / (clock * Hz^2))
        features_per_sample: int = 784,    # F
        data_precision_bits: int = 32,     # P_d
        sample_model_scaling_bits: float = 0.0,  # P_m * S_d
        task_parallelization: bool = True,
    ) -> "EnergyModel":
        """Build (e2, e1, e0) from the SAME learner profiles and workload
        constants ``TimeModel.build`` consumes, plus ``kappa``.

        ``kappa ~ 1e-28`` puts a 2.4 GHz edge node at ~1e-3 J per
        sample-update for an MLP-class C_m — a few joules per cycle, the
        regime where single-digit budgets bind (2012.00143 Sec. V).
        """
        k = len(profiles)
        e2 = np.empty(k)
        e1 = np.empty(k)
        e0 = np.empty(k)
        for i, p in enumerate(profiles):
            rate = p.channel.rate_bps()
            power = p.channel.tx_power_w
            e2[i] = kappa * p.clock_hz**2 * model_complexity_flops
            data_bits = features_per_sample * data_precision_bits if task_parallelization else 0.0
            e1[i] = power * (data_bits + 2.0 * sample_model_scaling_bits) / rate
            e0[i] = power * 2.0 * model_size_bits / rate
        return EnergyModel(e2=e2, e1=e1, e0=e0)

    def cycle_energy(self, tau: np.ndarray, d: np.ndarray) -> np.ndarray:
        """E_k for each learner (joules), zero where d_k = 0 (an idle
        learner transfers and computes nothing)."""
        tau = np.asarray(tau, dtype=float)
        d = np.asarray(d, dtype=float)
        e = self.e2 * tau * d + self.e1 * d + self.e0
        return np.where(d > 0, e, 0.0)

    def min_dispatch_energy(self) -> np.ndarray:
        """(K,) joules of the smallest dispatchable task (tau=1, d=1) —
        the battery floor below which a learner cannot accept work."""
        return self.e2 + self.e1 + self.e0

    def rows(self, e_budget=None) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(e2, e1, e0, eb) float64 rows for the solver layers; ``eb``
        broadcasts a scalar budget to (K,) and defaults to +inf (the
        unconstrained regime, decision-identical to ``kkt_sai``)."""
        k = self.num_learners
        if e_budget is None:
            eb = np.full(k, np.inf)
        else:
            eb = np.broadcast_to(np.asarray(e_budget, float), (k,)).copy()
        return (
            self.e2.astype(np.float64),
            self.e1.astype(np.float64),
            self.e0.astype(np.float64),
            eb.astype(np.float64),
        )


# ---------------------------------------------------------------------------
# Battery-drain drift (state-coupled availability)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatteryDrift:
    """State-coupled battery process: dispatched work drains the battery,
    a seeded recharge process refills it, an empty battery is offline.

    Follows the SAME ``state_init / state_update / factors_at`` +
    ``online_at`` protocol as the churn processes in ``availability.py``
    (so it routes through ``solve_rows_availability`` and composes with
    ``apply_active_mask`` exactly like Markov churn), with one extra
    method — ``budget_at`` — that exposes the current charge as a
    per-dispatch energy budget so an energy-aware scheme never dispatches
    a task the battery cannot finish:

      * **state** — a ``(K,)`` float32 charge vector (joules), starting
        full at ``capacity_j``;
      * **drain** (``state_update``) — the served allocation costs
        ``E_k(tau_k, d_k)`` from the :class:`EnergyModel` (zero where
        ``d_k = 0``: an idle or masked-out learner spends nothing);
      * **recharge** — per cycle each learner is plugged in i.i.d.
        Bernoulli(``p_plugged``) (seeded ``fold_in`` draw, the
        availability discipline) and recovers ``recharge_j`` joules,
        clipped at ``capacity_j``;
      * **offline** (``online_at``) — charge below the learner's
        ``min_dispatch_energy`` means it cannot accept ANY task; the
        solve masks it out via the padded-slot semantics and its budget
        flows to the charged learners.

    All battery arithmetic is elementwise float32 with no
    transcendentals (the ``QueueDrift`` discipline); composing a ``base``
    :class:`~repro.core.time_model.CapacityDrift` re-introduces that
    class's 1-f32-ULP pow caveat on the capacity rows only.
    """

    energy: EnergyModel = None
    capacity_j: float = 50.0     # full-charge energy (joules)
    recharge_j: float = 2.0      # joules recovered per plugged-in cycle
    p_plugged: float = 0.5       # P(a learner is on charge in a cycle)
    seed: int = 0
    base: CapacityDrift | None = None

    def __post_init__(self):
        if self.energy is None:
            raise ValueError("BatteryDrift needs an EnergyModel")
        if self.capacity_j <= 0:
            raise ValueError("capacity_j must be > 0")
        if self.recharge_j < 0:
            raise ValueError("recharge_j must be >= 0")
        if not (0.0 <= self.p_plugged <= 1.0):
            raise ValueError("p_plugged must be a probability in [0, 1]")

    # -- drift protocol -------------------------------------------------
    def state_init(self, k: int):
        """Initial (K,) float32 charge: every battery full."""
        import jax.numpy as jnp

        if k != self.energy.num_learners:
            raise ValueError(
                f"energy model covers {self.energy.num_learners} learners, "
                f"fleet has {k}"
            )
        return jnp.full((k,), jnp.float32(self.capacity_j))

    def factors_at(self, cycle, k: int, state):
        """(clock_factor, rate_factor) — battery level does not change
        capacities (a drained phone is offline, not slow); delegates to
        the composed ``base`` drift when present."""
        import jax.numpy as jnp

        if self.base is not None:
            return self.base.factors_at(cycle, k)
        ones = jnp.ones((k,), jnp.float32)
        return ones, ones

    def state_update(self, cycle, state, tau, d):
        """Next (K,) float32 charge after serving allocation ``(tau, d)``:
        drain by the allocation's energy, then apply the cycle's seeded
        recharge draw, clipped into [0, capacity_j]."""
        import jax
        import jax.numpy as jnp

        q = jnp.asarray(state, jnp.float32)
        tau_f = jnp.asarray(tau).astype(jnp.float32)
        d_f = jnp.asarray(d).astype(jnp.float32)
        e2 = jnp.asarray(self.energy.e2, jnp.float32)
        e1 = jnp.asarray(self.energy.e1, jnp.float32)
        e0 = jnp.asarray(self.energy.e0, jnp.float32)
        cost = e2 * tau_f * d_f + e1 * d_f + e0
        drain = jnp.where(d_f > 0, cost, jnp.float32(0.0))
        key = jax.random.fold_in(jax.random.key(self.seed), cycle + 1)
        u = jax.random.uniform(key, q.shape, jnp.float32)
        plugged = (u < jnp.float32(self.p_plugged)).astype(jnp.float32)
        q = q - drain + jnp.float32(self.recharge_j) * plugged
        return jnp.clip(q, 0.0, jnp.float32(self.capacity_j))

    # -- availability ---------------------------------------------------
    def online_at(self, cycle, k: int, state):
        """``(K,)`` bool: a learner is online iff its charge covers at
        least the smallest dispatchable task (tau=1, d=1)."""
        import jax.numpy as jnp

        floor = jnp.asarray(
            self.energy.min_dispatch_energy(), jnp.float32
        )
        return jnp.asarray(state, jnp.float32) >= floor

    # -- energy budget ---------------------------------------------------
    def budget_at(self, cycle, k: int, state) -> np.ndarray:
        """``(K,)`` float64 joules available for the NEXT dispatch — the
        current charge, which an energy-aware solve passes as ``e_budget``
        so no task is ever dispatched that the battery cannot finish."""
        del cycle, k
        return np.asarray(state, np.float64)
