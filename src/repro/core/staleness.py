"""Staleness metrics (paper Eqs. 6 and 13).

Staleness between learners k and l is |tau_k - tau_l|: the gap in the
number of local updates performed inside one global cycle.
"""

from __future__ import annotations

import numpy as np

__all__ = ["pair_matrix", "max_staleness", "avg_staleness", "staleness_profile"]


def pair_matrix(k: int) -> np.ndarray:
    """The paper's matrix c in R^{N x 2}, N = C(K,2) (Eq. 10): all (k, l)
    index pairs with l > k, 0-based."""
    pairs = [(a, b) for a in range(k) for b in range(a + 1, k)]
    return np.asarray(pairs, dtype=np.int64).reshape(-1, 2)


def max_staleness(tau: np.ndarray) -> int:
    """s = max_{k<l} |tau_k - tau_l|  (Eq. 6, max over all pairs)."""
    tau = np.asarray(tau)
    if tau.size < 2:
        return 0
    return int(np.max(tau) - np.min(tau))


def avg_staleness(tau: np.ndarray) -> float:
    """s_avg = (1/N) sum_n |tau_{c_n,1} - tau_{c_n,2}|  (Eq. 13)."""
    tau = np.asarray(tau, dtype=float)
    if tau.size < 2:
        return 0.0
    diff = np.abs(tau[:, None] - tau[None, :])
    n = tau.size
    return float(diff[np.triu_indices(n, k=1)].mean())


def staleness_profile(tau: np.ndarray) -> dict:
    return {
        "max": max_staleness(tau),
        "avg": avg_staleness(tau),
        "tau_min": int(np.min(tau)) if np.asarray(tau).size else 0,
        "tau_max": int(np.max(tau)) if np.asarray(tau).size else 0,
    }
