"""Staleness metrics (paper Eqs. 6 and 13) + model-version staleness.

Two notions of staleness coexist in this repo:

* **update staleness** (the paper's): within one global cycle, the gap
  |tau_k - tau_l| in local updates between learners — ``max_staleness`` /
  ``avg_staleness`` below.
* **version staleness** (FedAsync, Xie et al. arXiv:1903.03934): in a
  truly event-driven server, each upload was computed against the global
  model version it was dispatched with; its staleness is
  ``server_version - dispatch_version`` — the number of aggregations the
  server performed while the learner was working. ``version_staleness``,
  ``staleness_factor`` (the constant / hinge / polynomial discount
  functions s(t - tau) of the FedAsync paper) and
  ``version_staleness_profile`` cover this regime; the event engine in
  ``repro.fed.async_engine`` consumes them.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "pair_matrix",
    "max_staleness",
    "avg_staleness",
    "staleness_profile",
    "version_staleness",
    "staleness_factor",
    "version_staleness_profile",
    "STALENESS_FNS",
]


def pair_matrix(k: int) -> np.ndarray:
    """The paper's matrix c in R^{N x 2}, N = C(K,2) (Eq. 10): all (k, l)
    index pairs with l > k, 0-based."""
    pairs = [(a, b) for a in range(k) for b in range(a + 1, k)]
    return np.asarray(pairs, dtype=np.int64).reshape(-1, 2)


def max_staleness(tau: np.ndarray) -> int:
    """s = max_{k<l} |tau_k - tau_l|  (Eq. 6, max over all pairs)."""
    tau = np.asarray(tau)
    if tau.size < 2:
        return 0
    return int(np.max(tau) - np.min(tau))


def avg_staleness(tau: np.ndarray) -> float:
    """s_avg = (1/N) sum_n |tau_{c_n,1} - tau_{c_n,2}|  (Eq. 13)."""
    tau = np.asarray(tau, dtype=float)
    if tau.size < 2:
        return 0.0
    diff = np.abs(tau[:, None] - tau[None, :])
    n = tau.size
    return float(diff[np.triu_indices(n, k=1)].mean())


def staleness_profile(tau: np.ndarray) -> dict:
    return {
        "max": max_staleness(tau),
        "avg": avg_staleness(tau),
        "tau_min": int(np.min(tau)) if np.asarray(tau).size else 0,
        "tau_max": int(np.max(tau)) if np.asarray(tau).size else 0,
    }


# ---------------------------------------------------------------------------
# model-version staleness (event-driven asynchronous federation)
# ---------------------------------------------------------------------------

def version_staleness(server_version, dispatch_version):
    """s = server_version - dispatch_version: how many aggregations the
    server performed while this upload was in flight. Elementwise over
    arrays; never negative (an upload cannot be fresher than the server)."""
    s = np.asarray(server_version) - np.asarray(dispatch_version)
    return np.maximum(s, 0)


#: staleness discount functions s -> (0, 1] of FedAsync (arXiv:1903.03934
#: Sec. 5.2); ``a``/``b`` are the paper's hyper-parameters.
STALENESS_FNS = ("constant", "hinge", "poly")


def staleness_factor(s, *, kind: str = "poly", a: float = 0.5, b: float = 4.0):
    """FedAsync's s(t - tau): the server's trust in an upload of version
    staleness ``s``.

      constant   1                         (plain async SGD)
      hinge      1 if s <= b else 1 / (a (s - b) + 1)
      poly       (1 + s)^(-a)

    All three are 1.0 exactly at s = 0 (a fresh upload is mixed at the full
    server rate alpha) and non-increasing in s. Elementwise over arrays."""
    s = np.maximum(np.asarray(s, dtype=float), 0.0)
    if kind == "constant":
        return np.ones_like(s) if s.shape else 1.0
    if kind == "hinge":
        # denominator only ever used where s > b (there it is > 1); the
        # where-guard keeps the masked branch from dividing by zero at
        # s == b - 1/a
        den = np.where(s > b, a * (s - b) + 1.0, 1.0)
        out = np.where(s <= b, 1.0, 1.0 / den)
        return out if s.shape else float(out)
    if kind == "poly":
        out = (1.0 + s) ** (-a)
        return out if s.shape else float(out)
    raise ValueError(f"unknown staleness fn {kind!r}; choose from {STALENESS_FNS}")


def version_staleness_profile(staleness: np.ndarray) -> dict:
    """Summary of the per-aggregation version-staleness sequence an async
    run produced (one entry per aggregated upload)."""
    s = np.asarray(staleness, dtype=float)
    if s.size == 0:
        return {"mean": 0.0, "max": 0, "p50": 0.0, "p90": 0.0, "p99": 0.0,
                "frac_stale": 0.0, "count": 0}
    return {
        "mean": float(s.mean()),
        "max": int(s.max()),
        "p50": float(np.percentile(s, 50)),
        "p90": float(np.percentile(s, 90)),
        "p99": float(np.percentile(s, 99)),
        "frac_stale": float((s > 0).mean()),
        "count": int(s.size),
    }
