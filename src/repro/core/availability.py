"""Per-learner availability (client churn) processes.

The paper's allocator assumes every learner that is handed a task will
return it; real edge fleets churn.  This module models *who is online*
as a first-class process behind the same ``state_init / state_update /
factors_at`` drift protocol that :class:`~repro.core.time_model.QueueDrift`
uses, plus one extra method, ``online_at(cycle, k, state) -> (K,) bool``.
Three processes are provided:

- :class:`MarkovAvailability` — seeded two-state Markov chain per
  learner (P(online -> offline) = ``p_drop``, P(offline -> online) =
  ``p_join``), the classic intermittent-client model.
- :class:`ActiveRateAvailability` — each learner draws a persistent
  active rate from a clipped lognormal once, then is online i.i.d.
  Bernoulli(rate) per block: a heavy-tailed "some phones are almost
  never plugged in" fleet.
- :class:`TraceAvailability` — an explicit ``(C, K)`` boolean schedule,
  wrapped periodically, for replaying measured uptime traces.

Each process optionally wraps a *base* capacity drift
(:class:`~repro.core.time_model.CapacityDrift` or
:class:`~repro.core.time_model.QueueDrift`): ``factors_at`` delegates to
the base so churn composes with time-varying capacity.  The joint state
is the pytree ``(avail_state, base_state)``.

Masks are drawn with ``jax.random.fold_in`` keyed on the cycle index, in
float32, with no transcendentals on the comparison path — the same
discipline as ``CapacityDrift`` — so host and traced consumers see
bitwise-identical masks.

An offline learner is *masked out of the allocation solve* (see
``apply_active_mask`` in ``solver_batched``) rather than making the
fleet infeasible: its slot gets the ``BatchedProblems`` padded-slot
semantics (``d_lo = d_hi = 0``, ``valid=False``) and the sample budget
is clipped into the live fleet's box, so tau/d budget flows to the
learners that can actually absorb it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.time_model import CapacityDrift, QueueDrift, is_state_coupled

__all__ = [
    "MarkovAvailability",
    "ActiveRateAvailability",
    "TraceAvailability",
    "availability_masks",
    "capacity_state_coupled",
    "has_availability",
]

BaseDrift = Union[CapacityDrift, QueueDrift, None]


def has_availability(drift) -> bool:
    """True when ``drift`` models client availability (has ``online_at``)."""
    return drift is not None and hasattr(drift, "online_at")


def capacity_state_coupled(drift) -> bool:
    """Whether the *capacity* rows of ``drift`` depend on past allocations.

    For an availability process this looks through to the wrapped base
    drift: churn alone does not couple capacities to allocations, so a
    frozen (``reallocate=False``) schedule is still well defined under a
    Markov on/off fleet — but not under a queue-backlogged one.
    """
    if has_availability(drift):
        return is_state_coupled(drift.base)
    return is_state_coupled(drift)


class _AvailabilityBase:
    """Protocol plumbing shared by the concrete availability processes.

    Subclasses implement ``_avail_init(k)``, ``_avail_update(cycle,
    avail)`` and ``_online(cycle, k, avail)``; this mixin composes that
    per-learner on/off state with an optional base capacity drift.
    """

    base: BaseDrift

    # -- drift protocol -------------------------------------------------
    def state_init(self, k: int):
        if is_state_coupled(self.base):
            base_state = self.base.state_init(k)
        else:
            base_state = jnp.zeros((0,), jnp.float32)
        return (self._avail_init(k), base_state)

    def state_update(self, cycle: int, state, tau, d):
        avail, base_state = state
        if is_state_coupled(self.base):
            base_state = self.base.state_update(cycle, base_state, tau, d)
        return (self._avail_update(cycle, avail), base_state)

    def factors_at(self, cycle: int, k: int, state):
        _, base_state = state
        if self.base is None:
            ones = jnp.ones((k,), jnp.float32)
            return ones, ones
        if is_state_coupled(self.base):
            return self.base.factors_at(cycle, k, base_state)
        return self.base.factors_at(cycle, k)

    # -- availability ---------------------------------------------------
    def online_at(self, cycle: int, k: int, state):
        """``(K,)`` bool: who is online during drift block ``cycle``."""
        avail, _ = state
        return self._online(cycle, k, avail)


@dataclasses.dataclass(frozen=True)
class MarkovAvailability(_AvailabilityBase):
    """Two-state Markov on/off chain per learner, all online at block 0.

    ``state_update(c, ...)`` draws block ``c + 1``'s occupancy from the
    chain, so the mask a solve sees for block ``c`` is exactly the state
    that entered it.
    """

    p_drop: float = 0.1
    p_join: float = 0.5
    seed: int = 0
    base: BaseDrift = None

    def __post_init__(self):
        if not (0.0 <= self.p_drop <= 1.0):
            raise ValueError("p_drop must be in [0, 1]")
        if not (0.0 <= self.p_join <= 1.0):
            raise ValueError("p_join must be in [0, 1]")

    def _avail_init(self, k: int):
        return jnp.ones((k,), jnp.float32)

    def _avail_update(self, cycle: int, avail):
        key = jax.random.fold_in(jax.random.key(self.seed), cycle + 1)
        u = jax.random.uniform(key, avail.shape, jnp.float32)
        on = avail > 0.5
        nxt = jnp.where(on, u >= jnp.float32(self.p_drop), u < jnp.float32(self.p_join))
        return nxt.astype(jnp.float32)

    def _online(self, cycle: int, k: int, avail):
        return avail > 0.5


@dataclasses.dataclass(frozen=True)
class ActiveRateAvailability(_AvailabilityBase):
    """Persistent per-learner active rates, lognormal around ``median``.

    Each learner draws ``rate_k = clip(median * exp(sigma * z_k), floor,
    1)`` once (seeded), then is online i.i.d. Bernoulli(``rate_k``) per
    block — occupancy is independent across blocks but heterogeneous
    across the fleet.
    """

    median: float = 0.8
    sigma: float = 0.5
    floor: float = 0.05
    seed: int = 0
    base: BaseDrift = None

    def __post_init__(self):
        if not (0.0 < self.median <= 1.0):
            raise ValueError("median must be in (0, 1]")
        if self.sigma < 0.0:
            raise ValueError("sigma must be >= 0")
        if not (0.0 < self.floor <= 1.0):
            raise ValueError("floor must be in (0, 1]")

    def rates(self, k: int):
        """``(K,)`` f32 persistent active rates, clipped to [floor, 1]."""
        key = jax.random.fold_in(jax.random.key(self.seed), 2**31 - 1)
        z = jax.random.normal(key, (k,), jnp.float32)
        r = jnp.float32(self.median) * jnp.exp(jnp.float32(self.sigma) * z)
        return jnp.clip(r, jnp.float32(self.floor), jnp.float32(1.0))

    def _mask(self, cycle: int, k: int):
        key = jax.random.fold_in(jax.random.key(self.seed), cycle)
        u = jax.random.uniform(key, (k,), jnp.float32)
        return (u < self.rates(k)).astype(jnp.float32)

    def _avail_init(self, k: int):
        return self._mask(0, k)

    def _avail_update(self, cycle: int, avail):
        return self._mask(cycle + 1, avail.shape[-1])

    def _online(self, cycle: int, k: int, avail):
        return avail > 0.5


@dataclasses.dataclass(frozen=True)
class TraceAvailability(_AvailabilityBase):
    """Replay an explicit ``(C, K)`` boolean uptime trace, wrapped
    periodically past its horizon."""

    trace: np.ndarray = None
    base: BaseDrift = None

    def __post_init__(self):
        tr = np.asarray(self.trace, bool)
        if tr.ndim != 2 or tr.shape[0] < 1:
            raise ValueError("trace must be a (cycles, K) boolean schedule")
        object.__setattr__(self, "trace", tr)

    def _avail_init(self, k: int):
        if k != self.trace.shape[1]:
            raise ValueError(
                f"trace covers {self.trace.shape[1]} learners, fleet has {k}"
            )
        return jnp.zeros((0,), jnp.float32)  # mask is read from the trace

    def _avail_update(self, cycle: int, avail):
        return avail

    def _online(self, cycle: int, k: int, avail):
        return self.trace[int(cycle) % self.trace.shape[0]]


def availability_masks(drift, k: int, cycles: int, *, tau=None, d=None):
    """``(cycles, K)`` bool mask rollout under a *frozen* allocation.

    Steps the availability state with the given static ``(tau, d)``
    (zeros by default — only a queue-coupled base ever reads them), for
    the ``reallocate=False`` regime where the schedule is fixed up front
    and churn evolves on its own.  For a joint masked-solve rollout use
    ``solve_rows_availability`` in the orchestrator.
    """
    tau = np.zeros((k,), np.int64) if tau is None else np.asarray(tau)
    d = np.zeros((k,), np.int64) if d is None else np.asarray(d)
    tau_j, d_j = jnp.asarray(tau), jnp.asarray(d)
    masks = np.zeros((cycles, k), bool)
    state = drift.state_init(k)
    for c in range(cycles):
        masks[c] = np.asarray(drift.online_at(c, k, state))
        state = drift.state_update(c, state, tau_j, d_j)
    return masks
