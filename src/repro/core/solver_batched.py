"""Fleet-scale batched allocation engine: device-resident KKT water-filling
bisection + integer SAI repair for B allocation problems at once.

``solver_kkt`` solves one ``AllocationProblem`` with a NumPy bisection and a
Python greedy-repair loop — fine for a single fleet, hopeless when a
scheduling tick must re-solve (tau_k, d_k) for thousands of fleets (FedAST /
FedAsync-style servers re-allocate continuously as models return). This
module turns that O(B)-Python-solves path into **one XLA program**:

  * ``BatchedProblems`` — the shared (B, K) problem layout: coefficient
    tensors ``c2/c1/c0`` and per-learner bounds ``d_lo/d_hi`` of shape
    (B, K), per-fleet scalars ``T``/``total`` of shape (B,), and a
    ``valid`` mask so fleets of different sizes batch together (padded
    learner slots carry ``d_lo = d_hi = 0`` and never receive work).
  * ``solve_kkt_batched`` — lockstep bisection on the shared water level
    tau* across all B fleets (the inner residual
    ``sum_k clip((T - c0)/(c2 tau* + c1), d_l, d_u) - d`` is one
    ``kernels.ops.waterfill_residual`` call per step, with a Pallas TPU
    kernel behind ``use_pallas=True``), followed by a vmapped
    largest-remainder integerization and a vmapped SAI greedy repair, both
    as bounded ``lax.while_loop``s.
  * ``solve_eta_batched`` — the equal-task baseline in the same layout.
  * ``batched_max_staleness`` / ``batched_avg_staleness`` /
    ``batched_summary`` — (B,)-vectorized fleet metrics.

Numerical contract: with ``x64=True`` (default) every branch of the
bisection, the stable-sort tie-breaks of the largest-remainder rounding and
the greedy SAI moves replicate ``solver_kkt.solve`` decision-for-decision,
so per-problem outputs match the NumPy path exactly up to reduction-order
ULP noise in the residual sum (which can shift tau* within the bisection
tolerance and, extremely rarely, move one sample between two learners tied
at the same remainder — the documented tie-break tolerance).
``x64=False`` is the float32 device-resident fast path for hardware
without f64.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.allocation import Allocation, AllocationProblem
from repro.core.time_model import TimeModel

__all__ = [
    "BatchedProblems",
    "BatchedAllocation",
    "TRACED_POLICIES",
    "SPLIT_POLICIES",
    "batched_policy",
    "cross_model_weights",
    "cross_model_split",
    "multimodel_policy",
    "solve_kkt_batched",
    "solve_eta_batched",
    "solve_energy_batched",
    "batched_max_staleness",
    "batched_avg_staleness",
    "batched_summary",
    "apply_active_mask",
    "apply_energy_mask",
    "apply_sampling_mask",
]

_INT_SENTINEL = 2**31 - 1


# ---------------------------------------------------------------------------
# problem / solution containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchedProblems:
    """B allocation problems in one (B, K) tensor layout (K = widest fleet).

    ``d_lo``/``d_hi`` are per-learner so heterogeneous fleets and padding
    share one code path; for real problems every valid learner of fleet b
    carries that problem's scalar (d_lower, d_upper).

    Mask semantics for padded slots (``valid[b, k] == False`` — learner k
    does not exist in fleet b): every solver in this module and
    ``solver_numeric.solve_pgd_batched`` honors the same contract —

      * padded slots carry ``d_lo = d_hi = 0`` so any bound clip pins them
        to zero work; ``from_problems`` builds them that way and hand-built
        structs must too (a padded slot with a non-zero box is undefined);
      * coefficients of padded slots are ignored (``from_problems`` writes
        c2 = c1 = 1, c0 = 0 so divides stay finite);
      * solver outputs carry ``tau = d = 0`` in padded slots, and padded
        slots never enter staleness objectives/metrics or the sum
        constraint (sum_k d_k = total ranges over valid slots only, which
        the zero box enforces).

    The optional energy rows ``e2/e1/e0`` + per-learner budgets
    ``e_budget`` (arXiv 2012.00143; see ``core/energy.py``) default to
    None — the energy-blind layout every pre-energy call site builds.
    ``energy_rows()`` materializes the zero-coefficient / infinite-budget
    rows in that case, under which ``kkt_energy`` is decision-identical
    to ``kkt_sai``.
    """

    c2: np.ndarray        # (B, K)
    c1: np.ndarray        # (B, K)
    c0: np.ndarray        # (B, K)
    T: np.ndarray         # (B,)
    total: np.ndarray     # (B,) int
    d_lo: np.ndarray      # (B, K)
    d_hi: np.ndarray      # (B, K)
    valid: np.ndarray     # (B, K) bool
    e2: np.ndarray | None = None        # (B, K) optional energy rows
    e1: np.ndarray | None = None        # (B, K)
    e0: np.ndarray | None = None        # (B, K)
    e_budget: np.ndarray | None = None  # (B, K) joules, +inf = unconstrained

    @property
    def num_problems(self) -> int:
        return int(self.c2.shape[0])

    @property
    def max_learners(self) -> int:
        return int(self.c2.shape[1])

    @property
    def has_energy(self) -> bool:
        return self.e2 is not None

    def energy_rows(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(e2, e1, e0, e_budget) float64 rows; zero coefficients and +inf
        budgets when the struct carries no energy model (the regime where
        ``kkt_energy`` reproduces ``kkt_sai``)."""
        b, k = self.c2.shape
        if self.e2 is None:
            z = np.zeros((b, k))
            return z, z.copy(), z.copy(), np.full((b, k), np.inf)
        eb = (np.full((b, k), np.inf) if self.e_budget is None
              else np.asarray(self.e_budget, np.float64))
        return (np.asarray(self.e2, np.float64),
                np.asarray(self.e1, np.float64),
                np.asarray(self.e0, np.float64), eb)

    @staticmethod
    def from_problems(problems: "list[AllocationProblem]") -> "BatchedProblems":
        b = len(problems)
        k = max(p.num_learners for p in problems)
        c2 = np.ones((b, k)); c1 = np.ones((b, k)); c0 = np.zeros((b, k))
        d_lo = np.zeros((b, k)); d_hi = np.zeros((b, k))
        valid = np.zeros((b, k), bool)
        T = np.zeros(b); total = np.zeros(b, np.int64)
        any_energy = any(p.energy is not None for p in problems)
        if any_energy:
            # padded slots: zero cost, infinite budget (never binding)
            e2 = np.zeros((b, k)); e1 = np.zeros((b, k)); e0 = np.zeros((b, k))
            eb = np.full((b, k), np.inf)
        for i, p in enumerate(problems):
            n = p.num_learners
            tm = p.time_model
            c2[i, :n], c1[i, :n], c0[i, :n] = tm.c2, tm.c1, tm.c0
            d_lo[i, :n] = p.d_lower
            d_hi[i, :n] = p.d_upper
            valid[i, :n] = True
            T[i] = p.T
            total[i] = p.total_samples
            if any_energy and p.energy is not None:
                er2, er1, er0, erb = p.energy_rows()
                e2[i, :n], e1[i, :n], e0[i, :n] = er2, er1, er0
                eb[i, :n] = erb
        if not any_energy:
            return BatchedProblems(c2, c1, c0, T, total, d_lo, d_hi, valid)
        return BatchedProblems(c2, c1, c0, T, total, d_lo, d_hi, valid,
                               e2, e1, e0, eb)

    def problem(self, i: int) -> AllocationProblem:
        """Reconstruct the i-th (unpadded) AllocationProblem."""
        from repro.core.energy import EnergyModel

        v = self.valid[i]
        tm = TimeModel(c2=self.c2[i, v], c1=self.c1[i, v], c0=self.c0[i, v])
        energy = e_budget = None
        if self.has_energy:
            energy = EnergyModel(
                e2=self.e2[i, v], e1=self.e1[i, v], e0=self.e0[i, v]
            )
            if self.e_budget is not None:
                e_budget = self.e_budget[i, v]
        return AllocationProblem(
            time_model=tm,
            T=float(self.T[i]),
            total_samples=int(self.total[i]),
            d_lower=int(round(float(self.d_lo[i, v].min()))),
            d_upper=int(round(float(self.d_hi[i, v].max()))),
            energy=energy,
            e_budget=e_budget,
        )


@dataclasses.dataclass(frozen=True)
class BatchedAllocation:
    """Batched solver output; padded slots hold tau = d = 0."""

    tau: np.ndarray           # (B, K) int
    d: np.ndarray             # (B, K) int
    feasible: np.ndarray      # (B,) bool
    valid: np.ndarray         # (B, K) bool
    method: str = ""
    relaxed_tau: np.ndarray | None = None   # (B, K)
    relaxed_d: np.ndarray | None = None     # (B, K)
    tau_star: np.ndarray | None = None      # (B,)

    @property
    def num_problems(self) -> int:
        return int(self.tau.shape[0])

    def allocation(self, i: int) -> Allocation:
        """Per-problem Allocation (strips padding); raises on infeasible."""
        if not self.feasible[i]:
            raise ValueError(f"problem {i} infeasible: deadline cannot absorb d")
        v = self.valid[i]
        return Allocation(
            tau=self.tau[i, v].astype(np.int64),
            d=self.d[i, v].astype(np.int64),
            method=self.method,
            relaxed_tau=None if self.relaxed_tau is None else self.relaxed_tau[i, v],
            relaxed_d=None if self.relaxed_d is None else self.relaxed_d[i, v],
        )

    def summary(self, bp: BatchedProblems) -> dict:
        return batched_summary(bp, self.tau, self.d)


# ---------------------------------------------------------------------------
# batched metrics
# ---------------------------------------------------------------------------

def batched_max_staleness(tau: np.ndarray, valid: np.ndarray | None = None) -> np.ndarray:
    """(B,) max-pair staleness  max_k tau - min_k tau  over valid learners."""
    tau = np.asarray(tau)
    if valid is None:
        valid = np.ones(tau.shape, bool)
    tmax = np.where(valid, tau, -1).max(axis=1)
    tmin = np.where(valid, tau, _INT_SENTINEL).min(axis=1)
    n = valid.sum(axis=1)
    return np.where(n >= 2, tmax - tmin, 0).astype(np.int64)


def batched_avg_staleness(tau: np.ndarray, valid: np.ndarray | None = None) -> np.ndarray:
    """(B,) mean |tau_k - tau_l| over valid pairs k < l (paper Eq. 13)."""
    tau = np.asarray(tau, dtype=float)
    if valid is None:
        valid = np.ones(tau.shape, bool)
    k = tau.shape[1]
    diff = np.abs(tau[:, :, None] - tau[:, None, :])
    pair = (valid[:, :, None] & valid[:, None, :]) & np.triu(np.ones((k, k), bool), 1)
    n = valid.sum(axis=1)
    denom = n * (n - 1) / 2.0
    return np.where(denom > 0, (diff * pair).sum(axis=(1, 2)) / np.maximum(denom, 1.0), 0.0)


def batched_summary(bp: BatchedProblems, tau: np.ndarray, d: np.ndarray) -> dict:
    """Vectorized twin of ``Allocation.summary``: dict of (B,) arrays."""
    tau = np.asarray(tau); d = np.asarray(d)
    v = bp.valid
    t = bp.c2 * tau * d + bp.c1 * d + bp.c0
    n = np.maximum(v.sum(axis=1), 1)
    return {
        "max_staleness": batched_max_staleness(tau, v),
        "avg_staleness": batched_avg_staleness(tau, v),
        "total_updates": np.where(v, tau * d, 0).sum(axis=1).astype(np.int64),
        "min_tau": np.where(v, tau, _INT_SENTINEL).min(axis=1).astype(np.int64),
        "max_tau": np.where(v, tau, -1).max(axis=1).astype(np.int64),
        "utilization": np.where(v, t / bp.T[:, None], 0.0).sum(axis=1) / n,
    }


# ---------------------------------------------------------------------------
# jit building blocks (all shapes per problem unless noted)
# ---------------------------------------------------------------------------

def _max_tau_of_d(d, c2, c1, c0, T):
    """Largest integer tau with t_k <= T at integer d (TimeModel.max_tau)."""
    df = d.astype(c2.dtype)
    t = jnp.floor((T - c0 - c1 * df) / (c2 * df))
    t = jnp.where(d > 0, t, 0.0)
    return jnp.maximum(t, 0.0).astype(d.dtype)


#: "unbounded tau" sentinel of the energy cap. Finite (``floor(inf) ->
#: int`` is undefined) and exactly representable in float32 — 2**31 - 1
#: would round UP to 2**31 and overflow int32 on the f32 fast path. Far
#: above any deadline-feasible tau, so ``min(time_cap, _TAU_BIG)`` is the
#: time cap whenever the budget does not bind.
_TAU_BIG = 2**30


def _max_tau_energy(d, e2, e1, e0, eb):
    """Largest integer tau with E_k <= eb at integer d — the energy twin
    of ``_max_tau_of_d``. Unbounded where compute is free (e2 = 0) or the
    budget is infinite; 0 where even tau = 0 busts the budget (the
    affordability mask removes such learners before any solve)."""
    df = d.astype(e2.dtype)
    num = eb - e0 - e1 * df
    den = e2 * df
    raw = jnp.where(
        den > 0, num / jnp.where(den > 0, den, 1.0),
        jnp.where(num >= 0, jnp.inf, -1.0),
    )
    t = jnp.floor(raw)
    t = jnp.where(jnp.isfinite(t), t, float(_TAU_BIG))
    t = jnp.where(d > 0, t, 0.0)
    return jnp.maximum(t, 0.0).astype(d.dtype)


def _relaxed_batched(c2, c1, c0, T, total_f, d_lo, d_hi, *, tol, max_iter,
                     use_pallas, interpret):
    """Lockstep water-filling bisection over the (B,) batch. Mirrors
    ``solver_kkt.solve_relaxed`` branch-for-branch per problem."""
    from repro.kernels import ops

    def resid(tau_star):
        return ops.waterfill_residual(
            tau_star, c2, c1, c0, T, d_lo, d_hi, total_f,
            use_pallas=use_pallas, interpret=interpret,
        )

    b = c2.shape[0]
    zero = jnp.zeros((b,), c2.dtype)
    feasible = resid(zero) >= -1e-9

    # grow hi per problem until the absorbed data drops below total
    def gcond(state):
        _, it, r = state
        return jnp.any(r > 0) & (it < 200)

    def gbody(state):
        hi, it, r = state
        hi = jnp.where(r > 0, hi * 2.0, hi)
        return hi, it + 1, resid(hi)

    hi0 = jnp.ones((b,), c2.dtype)
    hi0, _, _ = jax.lax.while_loop(gcond, gbody, (hi0, 0, resid(hi0)))

    # bisection; per-problem convergence latches via `done`
    def bcond(state):
        lo, hi, steps, done = state
        return jnp.any(~done) & (steps < max_iter)

    def bbody(state):
        lo, hi, steps, done = state
        mid = 0.5 * (lo + hi)
        r = resid(mid)
        upd = ~done
        lo = jnp.where(upd & (r > 0), mid, lo)
        hi = jnp.where(upd & (r <= 0), mid, hi)
        done = done | (hi - lo < tol * jnp.maximum(1.0, hi))
        return lo, hi, steps + 1, done

    lo = jnp.zeros((b,), c2.dtype)
    lo, hi, steps, _ = jax.lax.while_loop(
        bcond, bbody, (lo, hi0, 0, jnp.zeros((b,), bool))
    )
    tau_star = 0.5 * (lo + hi)

    d = jnp.clip((T[:, None] - c0) / (c2 * tau_star[:, None] + c1), d_lo, d_hi)
    # spread the bisection's residual gap over unclamped learners
    free = (d > d_lo + 1e-9) & (d < d_hi - 1e-9)
    gap = total_f - d.sum(axis=-1)
    fsum = jnp.sum(jnp.where(free, d, 0.0), axis=-1)
    add = jnp.where(
        free & (fsum > 0)[:, None],
        gap[:, None] * d / jnp.where(fsum > 0, fsum, 1.0)[:, None],
        0.0,
    )
    d = jnp.clip(d + add, d_lo, d_hi)
    tau = jnp.where(
        d > 0, jnp.maximum((T[:, None] - c0 - c1 * d) / (c2 * d), 0.0), 0.0
    )
    return feasible, tau_star, tau, d, steps


def _relaxed_energy_batched(c2, c1, c0, T, e2, e1, e0, eb, total_f, d_lo,
                            d_hi, *, tol, max_iter, use_pallas=False,
                            interpret=False):
    """Energy-budgeted lockstep water-filling (arXiv 2012.00143): the same
    bisection as ``_relaxed_batched`` on the residual

        sum_k clip(min(d_time(tau*), d_energy(tau*)), d_lo, d_hi) - total

    where ``d_time = (T - c0)/(c2 tau* + c1)`` is the deadline hyperbola
    and ``d_energy = (eb - e0)/(e2 tau* + e1)`` the budget hyperbola — the
    most data each learner can absorb at water level tau* under BOTH
    constraints. The time branch replicates ``waterfill_residual_ref``'s
    op order exactly, and IEEE inf arithmetic makes ``min(d_time, inf)``
    select the time curve bitwise, so the whole stage degenerates to
    ``_relaxed_batched`` when no budget binds (eb = +inf). Each bisection
    step is one ``kernels.ops.waterfill_energy_residual`` call — the
    Pallas TPU kernel behind ``use_pallas=True`` (float32 only)."""
    from repro.kernels import ops

    def resid(tau_star):
        return ops.waterfill_energy_residual(
            tau_star, c2, c1, c0, T, e2, e1, e0, eb, d_lo, d_hi, total_f,
            use_pallas=use_pallas, interpret=interpret,
        )

    b = c2.shape[0]
    zero = jnp.zeros((b,), c2.dtype)
    feasible = resid(zero) >= -1e-9

    def gcond(state):
        _, it, r = state
        return jnp.any(r > 0) & (it < 200)

    def gbody(state):
        hi, it, r = state
        hi = jnp.where(r > 0, hi * 2.0, hi)
        return hi, it + 1, resid(hi)

    hi0 = jnp.ones((b,), c2.dtype)
    hi0, _, _ = jax.lax.while_loop(gcond, gbody, (hi0, 0, resid(hi0)))

    def bcond(state):
        lo, hi, steps, done = state
        return jnp.any(~done) & (steps < max_iter)

    def bbody(state):
        lo, hi, steps, done = state
        mid = 0.5 * (lo + hi)
        r = resid(mid)
        upd = ~done
        lo = jnp.where(upd & (r > 0), mid, lo)
        hi = jnp.where(upd & (r <= 0), mid, hi)
        done = done | (hi - lo < tol * jnp.maximum(1.0, hi))
        return lo, hi, steps + 1, done

    lo = jnp.zeros((b,), c2.dtype)
    lo, hi, steps, _ = jax.lax.while_loop(
        bcond, bbody, (lo, hi0, 0, jnp.zeros((b,), bool))
    )
    tau_star = 0.5 * (lo + hi)

    dt = (T[:, None] - c0) / (c2 * tau_star[:, None] + c1)
    de = (eb - e0) / (e2 * tau_star[:, None] + e1)
    d = jnp.clip(jnp.minimum(dt, de), d_lo, d_hi)
    free = (d > d_lo + 1e-9) & (d < d_hi - 1e-9)
    gap = total_f - d.sum(axis=-1)
    fsum = jnp.sum(jnp.where(free, d, 0.0), axis=-1)
    add = jnp.where(
        free & (fsum > 0)[:, None],
        gap[:, None] * d / jnp.where(fsum > 0, fsum, 1.0)[:, None],
        0.0,
    )
    d = jnp.clip(d + add, d_lo, d_hi)
    # tau is the tightest of the two per-learner caps at the final d
    tau_t = (T[:, None] - c0 - c1 * d) / (c2 * d)
    tau_e = (eb - e0 - e1 * d) / (e2 * d)
    tau = jnp.where(d > 0, jnp.maximum(jnp.minimum(tau_t, tau_e), 0.0), 0.0)
    return feasible, tau_star, tau, d, steps


def _integerize_one(d_real, total_i, lo_i, hi_i):
    """Largest-remainder rounding to exact sum within bounds — the
    ``solver_kkt._integerize_d`` loop as a bounded while_loop."""
    k = d_real.shape[0]
    base = jnp.clip(jnp.floor(d_real), lo_i.astype(d_real.dtype),
                    hi_i.astype(d_real.dtype)).astype(total_i.dtype)
    rema = d_real - jnp.floor(d_real)
    order_add = jnp.argsort(-rema, stable=True)
    order_sub = jnp.argsort(rema, stable=True)
    deficit0 = total_i - base.sum()
    pos = deficit0 > 0
    order = jnp.where(pos, order_add, order_sub)
    step = jnp.where(pos, 1, -1).astype(base.dtype)

    def cond(state):
        _, deficit, i = state
        return (deficit != 0) & (i < 10 * k + jnp.abs(total_i) + 1)

    def body(state):
        base, deficit, i = state
        kk = order[i % k]
        ok = jnp.where(pos, base[kk] < hi_i[kk], base[kk] > lo_i[kk])
        delta = jnp.where(ok, step, jnp.asarray(0, base.dtype))
        return base.at[kk].add(delta), deficit - delta, i + 1

    base, deficit, _ = jax.lax.while_loop(cond, body, (base, deficit0, 0))
    return base, deficit


def _sai_one(d0, c2, c1, c0, T, lo_i, hi_i, valid, *, max_rounds,
             energy=None):
    """Greedy suggest-and-improve repair (``solver_kkt.suggest_and_improve``)
    as a bounded while_loop: move samples from the min-tau learner to the
    highest-tau learner with headroom while staleness improves.

    With ``energy = (e2, e1, e0, eb)`` rows, every tau is additionally
    capped by the budget (``_max_tau_energy``), so any d within the
    energy-tightened box yields a budget-respecting (tau, d) by
    construction — SAI moves can never overspend."""

    int_dtype = d0.dtype
    neg_one = jnp.asarray(-1, int_dtype)
    sentinel = jnp.asarray(_INT_SENTINEL, int_dtype)

    def tau_of(d):
        t = _max_tau_of_d(d, c2, c1, c0, T)
        if energy is None:
            return t
        return jnp.minimum(t, _max_tau_energy(d, *energy))

    def stats(tau):
        tmax = jnp.max(jnp.where(valid, tau, neg_one))
        tmin = jnp.min(jnp.where(valid, tau, sentinel))
        return tmax, tmin

    def body(state):
        d, tau, rounds, _ = state
        tmax, tmin = stats(tau)
        s = tmax - tmin

        hi0 = jnp.argmax(jnp.where(valid, tau, neg_one))
        # min-tau learner freeing the most tau per sample removed (max c2)
        lo = jnp.argmax(jnp.where(valid & (tau == tmin), c2, -jnp.inf))
        give = d[lo] - lo_i[lo]
        room_k = jnp.minimum(hi_i - d, give)
        room0 = room_k[hi0]
        # fallback: next-highest-tau learner (above the min) with room
        elig = valid & (tau > tmin) & (room_k > 0)
        any_elig = jnp.any(elig)
        hi1 = jnp.argmax(jnp.where(elig, tau, neg_one))
        fallback = room0 <= 0
        hi = jnp.where(fallback, hi1, hi0)
        room = jnp.where(fallback, room_k[hi1], room0)
        has_target = jnp.where(fallback, any_elig, True)

        tau_sum = jnp.sum(jnp.where(valid, tau, 0))

        def try_move(m):
            d2 = d.at[hi].add(m).at[lo].add(-m)
            tau2 = tau_of(d2)
            tmax2, tmin2 = stats(tau2)
            s2 = tmax2 - tmin2
            better = (s2 < s) | (
                (s2 == s) & (jnp.sum(jnp.where(valid, tau2, 0)) > tau_sum)
            )
            return d2, tau2, better

        m_big = jnp.maximum(jnp.asarray(1, int_dtype), room // 8)
        d2a, tau2a, acc_a = try_move(m_big)
        d2b, tau2b, acc_b = try_move(jnp.asarray(1, int_dtype))
        retry = (~acc_a) & (m_big > 1) & acc_b

        do_move = (s > 0) & has_target & (acc_a | retry)
        d_new = jnp.where(do_move, jnp.where(acc_a, d2a, d2b), d)
        tau_new = jnp.where(do_move, jnp.where(acc_a, tau2a, tau2b), tau)
        return d_new, tau_new, rounds + 1, ~do_move

    def cond(state):
        return (~state[3]) & (state[2] < max_rounds)

    tau0 = tau_of(d0)
    d, tau, rounds, _ = jax.lax.while_loop(cond, body, (d0, tau0, 0, False))
    return tau, d, rounds


def _sai_one_energy(d0, c2, c1, c0, T, lo_i, hi_i, valid, e2, e1, e0, eb, *,
                    max_rounds):
    """``_sai_one`` with the energy rows as vmappable positional args."""
    return _sai_one(d0, c2, c1, c0, T, lo_i, hi_i, valid,
                    max_rounds=max_rounds, energy=(e2, e1, e0, eb))


def _integerize_and_repair(d_r, feasible, c2, c1, c0, T, total_i, d_lo, d_hi,
                           valid, *, max_rounds, energy=None):
    """Shared integer tail of every batched policy: largest-remainder
    rounding to the exact sum, then greedy SAI repair (both vmapped bounded
    while_loops). Returns (tau, d, feasible, sai_rounds). ``energy`` rows
    (if given) cap every tau the SAI stage assigns by the budget."""
    lo_i = jnp.round(d_lo).astype(total_i.dtype)
    hi_i = jnp.round(d_hi).astype(total_i.dtype)
    # neutralize infeasible rows so the integer repair loops terminate fast
    total_safe = jnp.where(feasible, total_i, lo_i.sum(axis=-1))
    d_r_safe = jnp.where(feasible[:, None], d_r, d_lo)

    d_int, leftover = jax.vmap(_integerize_one)(d_r_safe, total_safe, lo_i, hi_i)
    # repair that exhausted its bound without hitting the sum (possible only
    # for hand-built structs whose box is infeasible — AllocationProblem
    # rejects those up front) must not masquerade as a solution
    feasible = feasible & (leftover == 0)
    if energy is None:
        tau, d, rounds = jax.vmap(
            functools.partial(_sai_one, max_rounds=max_rounds)
        )(d_int, c2, c1, c0, T, lo_i, hi_i, valid)
    else:
        tau, d, rounds = jax.vmap(
            functools.partial(_sai_one_energy, max_rounds=max_rounds)
        )(d_int, c2, c1, c0, T, lo_i, hi_i, valid, *energy)
    return tau, d, feasible, rounds


def _kkt_batched_core(c2, c1, c0, T, total_i, d_lo, d_hi, valid, *,
                      tol, max_iter, max_rounds, use_pallas, interpret):
    """Traced KKT water-filling + SAI pipeline — callable from inside other
    traced programs (the orchestrator's in-scan reallocation) as well as
    from the jitted host entry point."""
    total_f = total_i.astype(c2.dtype)
    feasible, tau_star, tau_r, d_r, _ = _relaxed_batched(
        c2, c1, c0, T, total_f, d_lo, d_hi,
        tol=tol, max_iter=max_iter, use_pallas=use_pallas, interpret=interpret,
    )
    tau, d, feasible, rounds = _integerize_and_repair(
        d_r, feasible, c2, c1, c0, T, total_i, d_lo, d_hi, valid,
        max_rounds=max_rounds,
    )
    return dict(
        tau=tau, d=d, feasible=feasible,
        relaxed_tau=tau_r, relaxed_d=d_r, tau_star=tau_star, sai_rounds=rounds,
    )


@functools.partial(
    jax.jit,
    static_argnames=("tol", "max_iter", "max_rounds", "use_pallas", "interpret"),
)
def _solve_kkt_batched_impl(c2, c1, c0, T, total_i, d_lo, d_hi, valid, *,
                            tol, max_iter, max_rounds, use_pallas, interpret):
    return _kkt_batched_core(
        c2, c1, c0, T, total_i, d_lo, d_hi, valid,
        tol=tol, max_iter=max_iter, max_rounds=max_rounds,
        use_pallas=use_pallas, interpret=interpret,
    )


def apply_energy_mask(total_i, d_lo, d_hi, valid, energy):
    """Project a ``(B, K)`` policy problem onto its *affordable* sub-fleet.

    The budget at tau = 0 caps each learner's data at ``(eb - e0) / e1``
    samples; the upper bound is tightened to that cap, and a learner whose
    cap cannot even cover its ``d_lo`` is masked out entirely through
    ``apply_active_mask`` — the padded-slot semantics, exactly like an
    offline learner under churn. The per-fleet budget is clipped into the
    surviving fleet's box (feasible-or-degraded; an all-unaffordable
    fleet degrades to a zero budget rather than going infeasible).

    IEEE inf arithmetic makes an infinite budget a bitwise no-op: the cap
    is +inf, ``min(inf, d_hi) = d_hi``, every learner affordable. Only
    elementwise ``jnp``, so traced or host, like ``apply_active_mask``.

    Returns ``(total, d_lo, d_hi, valid)``.
    """
    e2, e1, e0, eb = energy
    lo = jnp.asarray(d_lo)
    hi = jnp.asarray(d_hi)
    room = eb - e0
    capf = jnp.where(
        e1 > 0, room / jnp.where(e1 > 0, e1, 1.0),
        jnp.where(room >= 0, jnp.inf, -1.0),
    )
    hi_e = jnp.clip(jnp.minimum(jnp.floor(capf), hi), 0.0, hi)
    affordable = hi_e >= lo
    return apply_active_mask(total_i, lo, hi_e, valid, affordable)


def _kkt_energy_core(c2, c1, c0, T, total_i, d_lo, d_hi, valid, energy, *,
                     tol, max_iter, max_rounds, use_pallas=False,
                     interpret=False):
    """Traced energy-budgeted KKT pipeline (``scheme="kkt_energy"``):
    affordability mask -> budgeted water-filling -> integerize -> SAI with
    energy-capped taus. Every stage keeps ``E_k(tau, d) <= eb_k`` by
    construction (integer d never exceeds the tau=0 cap, integer tau never
    exceeds the energy cap at that d), so solutions carry ZERO budget
    violations — the property the energy tests pin."""
    e2, e1, e0, eb = (jnp.asarray(x) for x in energy)
    energy = (e2, e1, e0, eb)
    total_i, d_lo, d_hi, valid = apply_energy_mask(
        total_i, d_lo, d_hi, valid, energy
    )
    total_f = total_i.astype(c2.dtype)
    feasible, tau_star, tau_r, d_r, _ = _relaxed_energy_batched(
        c2, c1, c0, T, e2, e1, e0, eb, total_f, d_lo, d_hi,
        tol=tol, max_iter=max_iter, use_pallas=use_pallas,
        interpret=interpret,
    )
    tau, d, feasible, rounds = _integerize_and_repair(
        d_r, feasible, c2, c1, c0, T, total_i, d_lo, d_hi, valid,
        max_rounds=max_rounds, energy=energy,
    )
    return dict(
        tau=tau, d=d, feasible=feasible,
        relaxed_tau=tau_r, relaxed_d=d_r, tau_star=tau_star, sai_rounds=rounds,
    )


@functools.partial(
    jax.jit,
    static_argnames=("tol", "max_iter", "max_rounds", "use_pallas", "interpret"),
)
def _solve_energy_batched_impl(c2, c1, c0, T, total_i, d_lo, d_hi, valid,
                               energy, *, tol, max_iter, max_rounds,
                               use_pallas=False, interpret=False):
    return _kkt_energy_core(
        c2, c1, c0, T, total_i, d_lo, d_hi, valid, energy,
        tol=tol, max_iter=max_iter, max_rounds=max_rounds,
        use_pallas=use_pallas, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# host entry points
# ---------------------------------------------------------------------------

def _as_batched(problems) -> BatchedProblems:
    if isinstance(problems, BatchedProblems):
        return problems
    return BatchedProblems.from_problems(list(problems))


def solve_kkt_batched(
    problems,
    *,
    x64: bool = True,
    use_pallas: bool = False,
    interpret: bool = False,
    tol: float = 1e-10,
    max_iter: int = 200,
    max_rounds: int = 10_000,
) -> BatchedAllocation:
    """Solve B problems (list[AllocationProblem] or BatchedProblems) with the
    paper's KKT water-filling + SAI pipeline as one jitted XLA program.

    ``x64=True`` reproduces ``solve_kkt_sai`` per problem exactly (modulo
    the documented remainder-tie tolerance); ``x64=False`` runs float32 for
    device-resident scheduling. ``use_pallas=True`` routes every bisection
    residual through the Pallas TPU kernel (``interpret=True`` on CPU); the
    kernel computes in float32, so it requires ``x64=False``.
    """
    if use_pallas and x64:
        raise ValueError("use_pallas=True computes residuals in float32; "
                         "pass x64=False (the exact-equivalence path is "
                         "jnp-reference only)")
    bp = _as_batched(problems)
    fdt = np.float64 if x64 else np.float32
    idt = np.int64 if x64 else np.int32
    ctx = enable_x64() if x64 else contextlib.nullcontext()
    with ctx:
        out = _solve_kkt_batched_impl(
            jnp.asarray(bp.c2, fdt), jnp.asarray(bp.c1, fdt),
            jnp.asarray(bp.c0, fdt), jnp.asarray(bp.T, fdt),
            jnp.asarray(bp.total, idt),
            jnp.asarray(bp.d_lo, fdt), jnp.asarray(bp.d_hi, fdt),
            jnp.asarray(bp.valid),
            tol=tol, max_iter=max_iter, max_rounds=max_rounds,
            use_pallas=use_pallas, interpret=interpret,
        )
        out = {k: np.asarray(v) for k, v in out.items()}
    return BatchedAllocation(
        tau=out["tau"].astype(np.int64),
        d=out["d"].astype(np.int64),
        feasible=out["feasible"],
        valid=np.asarray(bp.valid, bool),
        method="kkt_sai_batched",
        relaxed_tau=out["relaxed_tau"],
        relaxed_d=out["relaxed_d"],
        tau_star=out["tau_star"],
    )


def _eta_one(total_i, lo_i, hi_i, valid, c2, c1, c0, T):
    k = lo_i.shape[0]
    n_valid = jnp.maximum(valid.sum(), 1)
    base = total_i // n_valid
    rem = total_i - base * n_valid
    rank = jnp.cumsum(valid.astype(total_i.dtype)) - 1
    d = jnp.where(valid, base + (rank < rem).astype(total_i.dtype), 0)
    d = jnp.clip(d, lo_i, hi_i)
    order = jnp.argsort(-d, stable=True)

    def cond(state):
        _, gap, i = state
        return (gap != 0) & (i < 100 * k + jnp.abs(total_i) + 1)

    def body(state):
        d, gap, i = state
        kk = order[i % k]
        delta = jnp.where(
            (gap > 0) & (d[kk] < hi_i[kk]), 1,
            jnp.where((gap < 0) & (d[kk] > lo_i[kk]), -1, 0),
        ).astype(d.dtype)
        return d.at[kk].add(delta), gap - delta, i + 1

    d, gap, _ = jax.lax.while_loop(cond, body, (d, total_i - d.sum(), 0))
    tau = _max_tau_of_d(d, c2, c1, c0, T)
    return tau, d, gap == 0


@jax.jit
def _solve_eta_batched_impl(c2, c1, c0, T, total_i, lo_i, hi_i, valid):
    return jax.vmap(_eta_one)(total_i, lo_i, hi_i, valid, c2, c1, c0, T)


# ---------------------------------------------------------------------------
# traced allocation policies (the orchestrator's in-scan reallocation API)
# ---------------------------------------------------------------------------

def _kkt_policy(c2, c1, c0, T, total_i, d_lo, d_hi, valid, *, tol, max_iter,
                max_rounds, use_pallas, interpret):
    out = _kkt_batched_core(
        c2, c1, c0, T, total_i, d_lo, d_hi, valid,
        tol=tol, max_iter=max_iter, max_rounds=max_rounds,
        use_pallas=use_pallas, interpret=interpret,
    )
    return out["tau"], out["d"], out["feasible"]


def _kkt_energy_policy(c2, c1, c0, T, total_i, d_lo, d_hi, valid, energy, *,
                       tol, max_iter, max_rounds, use_pallas, interpret):
    """The ``kkt_energy`` traced policy: the standard 8-arg policy
    signature plus a 9th traced argument — the ``(e2, e1, e0, eb)`` tuple
    of (B, K) energy rows (traced data, NOT baked into the closure, so
    one cached callable serves every budget)."""
    out = _kkt_energy_core(
        c2, c1, c0, T, total_i, d_lo, d_hi, valid, energy,
        tol=tol, max_iter=max_iter, max_rounds=max_rounds,
        use_pallas=use_pallas, interpret=interpret,
    )
    return out["tau"], out["d"], out["feasible"]


def _eta_policy(c2, c1, c0, T, total_i, d_lo, d_hi, valid):
    lo_i = jnp.round(d_lo).astype(total_i.dtype)
    hi_i = jnp.round(d_hi).astype(total_i.dtype)
    tau, d, ok = jax.vmap(_eta_one)(total_i, lo_i, hi_i, valid, c2, c1, c0, T)
    return tau, d, ok


def _pgd_policy(c2, c1, c0, T, total_i, d_lo, d_hi, valid, energy=None, *,
                steps, max_rounds):
    """The ``pgd`` traced policy. The optional 9th argument mirrors
    ``kkt_energy``'s: ``(e2, e1, e0, eb)`` rows project the problem onto
    the energy-budget box (affordability mask) before the gradient stage
    and cap every SAI tau by the budget — with ``eb = +inf`` all of it is
    decision-inert and the energy-blind path is reproduced exactly."""
    from repro.core import solver_numeric
    from repro.kernels import ops

    if energy is not None:
        energy = tuple(jnp.asarray(x) for x in energy)
        total_i, d_lo, d_hi, valid = apply_energy_mask(
            total_i, d_lo, d_hi, valid, energy
        )
    total_f = total_i.astype(c2.dtype)
    if energy is None:
        feasible = ops.waterfill_residual(
            jnp.zeros_like(T), c2, c1, c0, T, d_lo, d_hi, total_f
        ) >= -1e-9
    else:
        feasible = ops.waterfill_energy_residual(
            jnp.zeros_like(T), c2, c1, c0, T, *energy, d_lo, d_hi, total_f
        ) >= -1e-9
    n_valid = jnp.maximum(valid.sum(axis=-1, keepdims=True), 1)
    d0 = jnp.clip(
        jnp.where(valid, total_f[:, None] / n_valid, 0.0), d_lo, d_hi
    )
    tau_r, d_r = jax.vmap(
        lambda d0_, c2_, c1_, c0_, T_, lo_, hi_, tot_, v_:
            solver_numeric._pgd_run(d0_, c2_, c1_, c0_, T_, lo_, hi_, tot_,
                                    steps, v_)
    )(d0, c2, c1, c0, T, d_lo, d_hi, total_f, valid)
    tau, d, feasible, _ = _integerize_and_repair(
        d_r, feasible, c2, c1, c0, T, total_i, d_lo, d_hi, valid,
        max_rounds=max_rounds, energy=energy,
    )
    return tau, d, feasible


#: schemes with a traced in-scan policy (see ``batched_policy``)
TRACED_POLICIES = ("kkt_sai", "eta", "pgd", "kkt_energy")


@functools.lru_cache(maxsize=None)
def batched_policy(
    name: str,
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
    max_rounds: int = 10_000,
    use_pallas: bool = False,
    interpret: bool = False,
    pgd_steps: int = 600,
):
    """A traced allocation policy — the in-scan re-solve hook of the fused
    orchestrator and the per-(re)dispatch solve of the async engine.

    Parameters
    ----------
    name : one of ``TRACED_POLICIES``: ``"kkt_sai"`` (the paper's
        water-filling + SAI pipeline), ``"eta"`` (equal-task baseline),
        ``"pgd"`` (relaxed projected-gradient + the same integerize/SAI
        tail) or ``"kkt_energy"`` (the budgeted pipeline of arXiv
        2012.00143 — same signature plus a 9th traced argument, the
        ``(e2, e1, e0, e_budget)`` tuple of (B, K) energy rows; with
        ``e_budget = +inf`` it reproduces ``kkt_sai`` decision for
        decision).
    tol, max_iter : bisection stop criteria (kkt_sai).
    max_rounds : SAI repair bound (kkt_sai, pgd).
    use_pallas, interpret : route bisection residuals through the Pallas
        TPU kernel (float32 only; ``interpret=True`` emulates on CPU).
    pgd_steps : inner gradient steps (pgd).

    Returns
    -------
    A pure traced callable ``fn(c2, c1, c0, T, total_i, d_lo, d_hi, valid)
    -> (tau, d, feasible)`` safe to call inside ``jit``/``scan``/``vmap``:

    * inputs — ``c2/c1/c0/d_lo/d_hi``: (B, K) float capacity rows and box
      bounds; ``T``: (B,) float deadlines; ``total_i``: (B,) int sample
      budgets; ``valid``: (B, K) bool fleet mask (``BatchedProblems``
      padding semantics: padded slots carry ``d_lo = d_hi = 0``);
    * outputs — ``tau, d``: (B, K) int allocations (0 in padded slots);
      ``feasible``: (B,) bool, False where even tau = 0 cannot absorb the
      budget (outputs in such rows are neutralized, not meaningful).

    Run under ``enable_x64`` with f64 inputs to reproduce the NumPy
    solvers decision-for-decision; f32 inputs give the device-resident
    fast path. The returned callable is cached per option set so jit
    caches keyed on it stay warm."""
    if name == "kkt_sai":
        return functools.partial(
            _kkt_policy, tol=tol, max_iter=max_iter, max_rounds=max_rounds,
            use_pallas=use_pallas, interpret=interpret,
        )
    if name == "eta":
        return _eta_policy
    if name == "pgd":
        return functools.partial(
            _pgd_policy, steps=pgd_steps, max_rounds=max_rounds,
        )
    if name == "kkt_energy":
        return functools.partial(
            _kkt_energy_policy, tol=tol, max_iter=max_iter,
            max_rounds=max_rounds, use_pallas=use_pallas,
            interpret=interpret,
        )
    raise ValueError(
        f"no batched/traced policy for scheme {name!r}; "
        f"choose from {' | '.join(TRACED_POLICIES)}"
    )


def solve_eta_batched(problems, *, x64: bool = True) -> BatchedAllocation:
    """Equal-task-allocation baseline (``baselines.solve_eta``) over a batch:
    d_k = d/K spread by index, bound-clipped, integer-sum repaired, then
    tau_k maximal per learner."""
    bp = _as_batched(problems)
    fdt = np.float64 if x64 else np.float32
    idt = np.int64 if x64 else np.int32
    ctx = enable_x64() if x64 else contextlib.nullcontext()
    with ctx:
        tau, d, ok = _solve_eta_batched_impl(
            jnp.asarray(bp.c2, fdt), jnp.asarray(bp.c1, fdt),
            jnp.asarray(bp.c0, fdt), jnp.asarray(bp.T, fdt),
            jnp.asarray(bp.total, idt),
            jnp.asarray(np.round(bp.d_lo), idt), jnp.asarray(np.round(bp.d_hi), idt),
            jnp.asarray(bp.valid),
        )
        tau, d, ok = np.asarray(tau), np.asarray(d), np.asarray(ok)
    return BatchedAllocation(
        tau=tau.astype(np.int64), d=d.astype(np.int64), feasible=ok,
        valid=np.asarray(bp.valid, bool), method="eta_batched",
    )


def solve_energy_batched(
    problems,
    *,
    x64: bool = True,
    use_pallas: bool = False,
    interpret: bool = False,
    tol: float = 1e-10,
    max_iter: int = 200,
    max_rounds: int = 10_000,
) -> BatchedAllocation:
    """Solve B energy-budgeted problems (arXiv 2012.00143) with the
    ``kkt_energy`` pipeline as one jitted XLA program. Problems without an
    energy model get zero-coefficient rows and infinite budgets, under
    which the decisions coincide with ``solve_kkt_batched``; with budgets,
    every returned allocation satisfies ``E_k(tau, d) <= e_budget_k`` by
    construction (learners whose budget cannot cover ``d_lower`` are
    degraded to the padded-slot semantics, like offline learners).
    ``use_pallas=True`` routes every budgeted bisection residual through
    the Pallas TPU kernel (float32 only — requires ``x64=False``;
    ``interpret=True`` emulates on CPU)."""
    if use_pallas and x64:
        raise ValueError("use_pallas=True computes residuals in float32; "
                         "pass x64=False (the exact-equivalence path is "
                         "jnp-reference only)")
    bp = _as_batched(problems)
    e2, e1, e0, eb = bp.energy_rows()
    fdt = np.float64 if x64 else np.float32
    idt = np.int64 if x64 else np.int32
    ctx = enable_x64() if x64 else contextlib.nullcontext()
    with ctx:
        out = _solve_energy_batched_impl(
            jnp.asarray(bp.c2, fdt), jnp.asarray(bp.c1, fdt),
            jnp.asarray(bp.c0, fdt), jnp.asarray(bp.T, fdt),
            jnp.asarray(bp.total, idt),
            jnp.asarray(bp.d_lo, fdt), jnp.asarray(bp.d_hi, fdt),
            jnp.asarray(bp.valid),
            (jnp.asarray(e2, fdt), jnp.asarray(e1, fdt),
             jnp.asarray(e0, fdt), jnp.asarray(eb, fdt)),
            tol=tol, max_iter=max_iter, max_rounds=max_rounds,
            use_pallas=use_pallas, interpret=interpret,
        )
        out = {k: np.asarray(v) for k, v in out.items()}
    return BatchedAllocation(
        tau=out["tau"].astype(np.int64),
        d=out["d"].astype(np.int64),
        feasible=out["feasible"],
        valid=np.asarray(bp.valid, bool),
        method="kkt_energy_batched",
        relaxed_tau=out["relaxed_tau"],
        relaxed_d=out["relaxed_d"],
        tau_star=out["tau_star"],
    )


def apply_active_mask(total_i, d_lo, d_hi, valid, active):
    """Project a ``(B, K)`` policy problem onto its online sub-fleet.

    Offline slots get the padded-slot semantics of ``BatchedProblems``
    (``d_lo = d_hi = 0``, ``valid=False``) so the policies skip them,
    and the per-fleet sample budget is clipped into the live fleet's box
    ``[sum d_lo, sum d_hi]`` — a thinned fleet serves what it can absorb
    instead of going infeasible; an all-offline fleet degrades to a zero
    budget.  Elementwise ``jnp`` only, so it is usable traced or on host
    (run under ``enable_x64`` when exact integer budgets matter).

    Returns ``(total, d_lo, d_hi, valid)`` with the same shapes/dtypes
    as the inputs.
    """
    act = jnp.asarray(active, bool)
    lo = jnp.where(act, d_lo, jnp.zeros((), jnp.asarray(d_lo).dtype))
    hi = jnp.where(act, d_hi, jnp.zeros((), jnp.asarray(d_hi).dtype))
    v = jnp.asarray(valid, bool) & act
    total = jnp.asarray(total_i)
    tot = jnp.clip(
        total.astype(lo.dtype), jnp.sum(lo, axis=-1), jnp.sum(hi, axis=-1)
    )
    return tot.astype(total.dtype), lo, hi, v


def apply_sampling_mask(total_i, d_lo, d_hi, valid, sampled):
    """Project a fleet-axis policy problem onto the round's sampled fleets.

    ``sampled`` is a per-fleet ``(B,)`` bool mask (FedAST-style partial
    participation: only a subset of fleets is served each round). A
    sampled-out fleet is treated exactly like an all-offline fleet, which
    in turn is exactly a row of ``BatchedProblems`` padded slots: zero
    boxes, ``valid=False`` everywhere, budget degraded to zero — so the
    policies solve tau = d = 0 for it without going infeasible. This is
    ``apply_active_mask`` with the mask broadcast over the learner axis;
    the equivalence of the three maskings is pinned by the fleet property
    tests. Traced or host, same as ``apply_active_mask``.
    """
    act = jnp.asarray(sampled, bool)[..., None] & jnp.asarray(valid, bool)
    return apply_active_mask(total_i, d_lo, d_hi, valid, act)


# ---------------------------------------------------------------------------
# cross-model allocation layer (FedAST-style multi-tenant split)
# ---------------------------------------------------------------------------

#: cross-model budget-split policies (see ``cross_model_weights``)
SPLIT_POLICIES = ("deficit", "equal")

#: split weights are floored onto this binary grid so their exact sum is a
#: representable float <= 1.0 — the budget-conservation guarantee cannot be
#: eaten by rounding in the normalization divides.
_SPLIT_GRID = float(2**20)


def cross_model_weights(deficits, *, policy: str = "deficit",
                        share_floor: float = 0.0):
    """Per-model budget-split weights ``w`` of shape (S,) from a (S,)
    progress-deficit signal (FedAST-style behind-ness: how far each tenant
    model trails its round target — model-value-free, so the schedule stays
    bit-reproducible).

    ``policy="deficit"`` splits proportionally to ``max(deficits, 0)``
    (equal split when all deficits are zero); ``policy="equal"`` is the
    uniform 1/S baseline. ``share_floor`` mixes a uniform floor in
    (``w = (1 - S*floor) p + floor``) so no tenant is fully starved;
    requires ``share_floor * S <= 1``.

    Guarantees, pinned by the multimodel property tests:

    * weights are floored onto a 2^-20 binary grid, so ``w.sum()`` is an
      EXACTLY-representable float ``<= 1.0`` — per-learner budgets split
      as ``w_s * T_k`` can never over-commit the pool by more than one
      product-rounding ULP per model;
    * S = 1 returns exactly 1.0 (statically — no grid, no arithmetic), so
      ``w * T == T`` bitwise: the single-tenant engine is a fixed point;
    * permutation-equivariant across models, and each model's weight is
      monotone non-decreasing in its own deficit (elementwise normalize +
      monotone floor).
    """
    if policy not in SPLIT_POLICIES:
        raise ValueError(
            f"no cross-model split policy {policy!r}; "
            f"choose from {' | '.join(SPLIT_POLICIES)}"
        )
    deficits = jnp.asarray(deficits)
    s = int(deficits.shape[0])
    dtype = (deficits.dtype if jnp.issubdtype(deficits.dtype, jnp.floating)
             else jnp.result_type(float))
    if s == 1:
        return jnp.ones((1,), dtype)
    if share_floor < 0 or share_floor * s > 1.0:
        raise ValueError(f"share_floor={share_floor} must satisfy "
                         f"0 <= share_floor * S <= 1 (S={s})")
    if policy == "equal":
        p = jnp.full((s,), 1.0 / s, dtype)
    else:
        c = jnp.maximum(deficits.astype(dtype), 0.0)
        tot = c.sum()
        p = jnp.where(tot > 0, c / jnp.where(tot > 0, tot, 1.0), 1.0 / s)
    if share_floor > 0.0:
        p = (1.0 - s * share_floor) * p + share_floor
    return jnp.floor(p * _SPLIT_GRID) / _SPLIT_GRID


def cross_model_split(deficits, T, e_budget=None, *, policy: str = "deficit",
                      share_floor: float = 0.0):
    """Split shared budgets across S tenant models: ``(w, T_split,
    eb_split)`` where ``T_split = w * T`` ((S,) per-model deadlines from a
    scalar or (S,) shared deadline) and ``eb_split = w[:, None] *
    e_budget`` ((S, K) per-model per-learner joule budgets; infinite
    budgets stay infinite rather than going 0 * inf = nan). With
    ``w.sum() <= 1.0`` exact (see ``cross_model_weights``), each learner's
    summed time/energy commitment across tenants stays within its single-
    tenant budget."""
    w = cross_model_weights(deficits, policy=policy, share_floor=share_floor)
    T = jnp.asarray(T)
    w = w.astype(T.dtype)
    T_split = w * T
    eb_split = None
    if e_budget is not None:
        eb = jnp.asarray(e_budget)
        eb_split = jnp.where(jnp.isinf(eb), eb, w[:, None] * eb)
    return w, T_split, eb_split


def multimodel_policy(name: str, *, split: str = "deficit",
                      share_floor: float = 0.0, **policy_kwargs):
    """The cross-model allocation layer: a traced policy over the (S, K)
    multi-tenant problem tensor (S models sharing one K-learner pool).

    Every (re)dispatch first splits each learner's deadline ``T`` (and
    per-learner energy budgets, for ``name="kkt_energy"``) across models
    with ``cross_model_split`` on the progress-deficit signal, scales each
    model's per-round sample budget by its share, degrades (model,
    learner) cells whose share cannot even cover ``d_lo`` at tau = 0 to
    the padded-slot semantics (``apply_active_mask`` — feasible-or-
    degraded, like offline learners under churn), then solves all S
    per-model (tau, d) rows with ONE ``batched_policy(name)`` call on the
    (S, K) batch.

    Returns a traced callable

        fn(deficits, c2, c1, c0, T, total_i, d_lo, d_hi, valid[, energy])
        -> (tau, d, feasible, w)

    with ``deficits``: (S,); ``c2/c1/c0/d_lo/d_hi/valid``: (S, K);
    ``T``: (S,) per-model full deadlines (normally all equal to the shared
    learner deadline); ``total_i``: (S,) per-model sample budgets;
    ``energy``: optional (e2, e1, e0, eb) rows of shape (S, K).

    Exactness anchor: S = 1 is a STATIC pass-through — the unit split
    leaves every input untouched (no mask, no scaling), so the underlying
    ``batched_policy`` sees bitwise-identical operands and the multi-
    tenant engine reproduces the single-tenant one record-for-record."""
    base = batched_policy(name, **policy_kwargs)

    def fn(deficits, c2, c1, c0, T, total_i, d_lo, d_hi, valid, energy=None):
        s = int(c2.shape[0])
        if s == 1:
            w = jnp.ones((1,), jnp.asarray(T).dtype)
            if energy is None:
                tau, d, ok = base(c2, c1, c0, T, total_i, d_lo, d_hi, valid)
            else:
                tau, d, ok = base(c2, c1, c0, T, total_i, d_lo, d_hi, valid,
                                  energy)
            return tau, d, ok, w
        eb = energy[3] if energy is not None else None
        w, T_s, eb_s = cross_model_split(
            deficits, T, eb, policy=split, share_floor=share_floor
        )
        total_s = jnp.round(w * total_i.astype(c2.dtype)).astype(total_i.dtype)
        active = jnp.asarray(valid, bool) & (T_s[:, None] >= c0 + c1 * d_lo)
        total_s, lo, hi, v = apply_active_mask(
            total_s, d_lo, d_hi, valid, active
        )
        if energy is None:
            tau, d, ok = base(c2, c1, c0, T_s, total_s, lo, hi, v)
        else:
            e2, e1, e0, _ = energy
            tau, d, ok = base(c2, c1, c0, T_s, total_s, lo, hi, v,
                              (e2, e1, e0, eb_s))
        return tau, d, ok, w

    return fn
