"""The task-allocation problem container (paper Sec. III, Eq. 7/8).

    min_{tau, d}  max_{k<l} |tau_k - tau_l|
    s.t.          C2_k tau_k d_k + C1_k d_k + C0_k = T     (all k)
                  sum_k d_k = d
                  d_l <= d_k <= d_u,   tau_k, d_k integer >= 0

plus — when an :class:`~repro.core.energy.EnergyModel` is attached — the
per-learner energy budget of the authors' sequel (arXiv 2012.00143):

                  e2_k tau_k d_k + e1_k d_k + e0_k <= e_budget_k

``AllocationProblem`` holds the data; solvers return an ``Allocation``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.staleness import avg_staleness, max_staleness
from repro.core.time_model import TimeModel

__all__ = ["AllocationProblem", "Allocation"]


@dataclasses.dataclass(frozen=True)
class AllocationProblem:
    time_model: TimeModel
    T: float                      # global cycle clock (s)
    total_samples: int            # d
    d_lower: int                  # d_l
    d_upper: int                  # d_u
    energy: "object | None" = None       # optional EnergyModel (e2, e1, e0)
    e_budget: "float | np.ndarray | None" = None  # per-learner joule budget

    def __post_init__(self):
        k = self.time_model.num_learners
        if self.d_lower * k > self.total_samples:
            raise ValueError(
                f"infeasible: K*d_l = {k * self.d_lower} > d = {self.total_samples}"
            )
        if self.d_upper * k < self.total_samples:
            raise ValueError(
                f"infeasible: K*d_u = {k * self.d_upper} < d = {self.total_samples}"
            )
        if self.energy is not None and self.energy.num_learners != k:
            raise ValueError(
                f"energy model covers {self.energy.num_learners} learners, "
                f"time model has {k}"
            )
        if self.e_budget is not None:
            if self.energy is None:
                raise ValueError("e_budget needs an energy model")
            eb = np.broadcast_to(np.asarray(self.e_budget, float), (k,))
            if np.any(eb <= 0):
                raise ValueError("e_budget must be positive (joules)")

    @property
    def num_learners(self) -> int:
        return self.time_model.num_learners

    def energy_rows(self) -> "tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray] | None":
        """(e2, e1, e0, eb) float64 rows when an energy model is attached
        (budget defaulting to +inf — the unconstrained regime), else None."""
        if self.energy is None:
            return None
        return self.energy.rows(self.e_budget)


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A solution: integer tau, d per learner plus bookkeeping."""

    tau: np.ndarray               # (K,) int
    d: np.ndarray                 # (K,) int
    method: str = ""
    relaxed_tau: np.ndarray | None = None   # pre-floor continuous solution
    relaxed_d: np.ndarray | None = None
    solver_iters: int = 0

    def validate(self, prob: AllocationProblem, *, require_full_time: bool = False) -> None:
        """Raise ``ValueError`` when the allocation violates the problem's
        constraints (plain raises, not ``assert``, so the contract holds
        under ``python -O`` too)."""
        tau, d = self.tau, self.d
        k = prob.num_learners
        if tau.shape != (k,) or d.shape != (k,):
            raise ValueError(
                f"shape mismatch: tau {tau.shape}, d {d.shape}, expected ({k},)"
            )
        if not (np.all(tau >= 0) and np.all(d >= 0)):
            raise ValueError("tau and d must be non-negative")
        if int(d.sum()) != prob.total_samples:
            raise ValueError(
                f"sample budget violated: {(int(d.sum()), prob.total_samples)}"
            )
        if not (np.all(d >= prob.d_lower) and np.all(d <= prob.d_upper)):
            raise ValueError(
                f"d outside [{prob.d_lower}, {prob.d_upper}]: {d}"
            )
        t = prob.time_model.cycle_time(tau, d)
        if not np.all(t <= prob.T * (1 + 1e-9)):
            raise ValueError(f"deadline violated: {t} > {prob.T}")
        if require_full_time and not np.allclose(t, prob.T, rtol=1e-6):
            raise ValueError(f"cycle time does not fill the budget: {t} != {prob.T}")
        rows = prob.energy_rows()
        if rows is not None:
            e2, e1, e0, eb = rows
            e = np.where(d > 0, e2 * tau * d + e1 * d + e0, 0.0)
            if not np.all(e <= eb * (1 + 1e-9)):
                raise ValueError(f"energy budget violated: {e} > {eb}")

    def summary(self, prob: AllocationProblem) -> dict:
        t = prob.time_model.cycle_time(self.tau, self.d)
        return {
            "method": self.method,
            "max_staleness": max_staleness(self.tau),
            "avg_staleness": avg_staleness(self.tau),
            "total_updates": int((self.tau * self.d).sum()),
            "min_tau": int(self.tau.min()),
            "max_tau": int(self.tau.max()),
            "utilization": float((t / prob.T).mean()),
            "solver_iters": self.solver_iters,
        }
