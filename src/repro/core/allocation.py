"""The task-allocation problem container (paper Sec. III, Eq. 7/8).

    min_{tau, d}  max_{k<l} |tau_k - tau_l|
    s.t.          C2_k tau_k d_k + C1_k d_k + C0_k = T     (all k)
                  sum_k d_k = d
                  d_l <= d_k <= d_u,   tau_k, d_k integer >= 0

``AllocationProblem`` holds the data; solvers return an ``Allocation``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.staleness import avg_staleness, max_staleness
from repro.core.time_model import TimeModel

__all__ = ["AllocationProblem", "Allocation"]


@dataclasses.dataclass(frozen=True)
class AllocationProblem:
    time_model: TimeModel
    T: float                      # global cycle clock (s)
    total_samples: int            # d
    d_lower: int                  # d_l
    d_upper: int                  # d_u

    def __post_init__(self):
        k = self.time_model.num_learners
        if self.d_lower * k > self.total_samples:
            raise ValueError(
                f"infeasible: K*d_l = {k * self.d_lower} > d = {self.total_samples}"
            )
        if self.d_upper * k < self.total_samples:
            raise ValueError(
                f"infeasible: K*d_u = {k * self.d_upper} < d = {self.total_samples}"
            )

    @property
    def num_learners(self) -> int:
        return self.time_model.num_learners


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A solution: integer tau, d per learner plus bookkeeping."""

    tau: np.ndarray               # (K,) int
    d: np.ndarray                 # (K,) int
    method: str = ""
    relaxed_tau: np.ndarray | None = None   # pre-floor continuous solution
    relaxed_d: np.ndarray | None = None
    solver_iters: int = 0

    def validate(self, prob: AllocationProblem, *, require_full_time: bool = False) -> None:
        tau, d = self.tau, self.d
        k = prob.num_learners
        assert tau.shape == (k,) and d.shape == (k,)
        assert np.all(tau >= 0) and np.all(d >= 0)
        assert int(d.sum()) == prob.total_samples, (int(d.sum()), prob.total_samples)
        assert np.all(d >= prob.d_lower) and np.all(d <= prob.d_upper)
        t = prob.time_model.cycle_time(tau, d)
        assert np.all(t <= prob.T * (1 + 1e-9)), f"deadline violated: {t} > {prob.T}"
        if require_full_time:
            assert np.allclose(t, prob.T, rtol=1e-6)

    def summary(self, prob: AllocationProblem) -> dict:
        t = prob.time_model.cycle_time(self.tau, self.d)
        return {
            "method": self.method,
            "max_staleness": max_staleness(self.tau),
            "avg_staleness": avg_staleness(self.tau),
            "total_updates": int((self.tau * self.d).sum()),
            "min_tau": int(self.tau.min()),
            "max_tau": int(self.tau.max()),
            "utilization": float((t / prob.T).mean()),
            "solver_iters": self.solver_iters,
        }
