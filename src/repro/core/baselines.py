"""Baseline allocation schemes the paper compares against.

* ``solve_synchronous`` — the synchronous optimized scheme of ref [9]:
  every learner performs the *same* number of updates tau, tau maximized
  subject to every learner finishing within T. Some learners idle.
* ``solve_eta`` — equal task allocation (staleness-aware async-SGD setting
  of ref [10]): d_k = d / K for all learners; each learner then performs as
  many updates as fit in T (so staleness is whatever heterogeneity causes).
"""

from __future__ import annotations

import numpy as np

from repro.core.allocation import Allocation, AllocationProblem

__all__ = ["solve_synchronous", "solve_eta"]


def _integer_sum_fix(d: np.ndarray, prob: AllocationProblem) -> np.ndarray:
    d = np.clip(np.floor(d).astype(np.int64), prob.d_lower, prob.d_upper)
    gap = prob.total_samples - int(d.sum())
    i = 0
    order = np.argsort(-d, kind="stable")  # deterministic tie-break (solver_batched mirrors it)
    while gap != 0:
        k = order[i % len(order)]
        if gap > 0 and d[k] < prob.d_upper:
            d[k] += 1
            gap -= 1
        elif gap < 0 and d[k] > prob.d_lower:
            d[k] -= 1
            gap += 1
        i += 1
        if i > 100 * len(order) + prob.total_samples:
            raise RuntimeError("could not fix integer sum")
    return d


def solve_synchronous(prob: AllocationProblem) -> Allocation:
    """Ref [9]: common tau for all learners, maximized; d_k optimized so
    everyone meets the deadline. For a common tau the most data the system
    absorbs is sum_k clip(d_k(tau), d_l, d_u); pick the largest integer tau
    that still absorbs all d samples, then distribute d by the same
    water-filling and let every learner run exactly tau updates."""
    tm = prob.time_model

    def capacity(tau: float) -> float:
        d = (prob.T - tm.c0) / (tm.c2 * tau + tm.c1)
        return float(np.clip(d, prob.d_lower, prob.d_upper).sum())

    if capacity(0.0) < prob.total_samples:
        raise ValueError("infeasible even at tau=0")
    tau = 0
    while capacity(float(tau + 1)) >= prob.total_samples:
        tau += 1
        if tau > 10**7:
            raise RuntimeError("tau diverged")
    d_real = np.clip(
        (prob.T - tm.c0) / (tm.c2 * float(tau) + tm.c1), prob.d_lower, prob.d_upper
    )
    # distribute exactly d samples (respecting that adding samples must keep
    # t_k <= T at the common tau -> only add below the unclipped capacity)
    d = _integer_sum_fix(d_real, prob)
    # adding the rounding residue may push t_k over T at tau; back off tau if so
    while tau > 0 and np.any(tm.cycle_time(np.full_like(d, tau), d) > prob.T * (1 + 1e-12)):
        tau -= 1
    alloc = Allocation(
        tau=np.full(prob.num_learners, tau, dtype=np.int64),
        d=d,
        method="synchronous",
        relaxed_d=d_real,
    )
    alloc.validate(prob)
    return alloc


def solve_eta(prob: AllocationProblem) -> Allocation:
    """Ref [10] adapted: equal task allocation d_k = d/K; each learner runs
    the maximum number of updates that fits in T (asynchronous in updates)."""
    k = prob.num_learners
    d = np.full(k, prob.total_samples // k, dtype=np.int64)
    d[: prob.total_samples - int(d.sum())] += 1
    d = np.clip(d, prob.d_lower, prob.d_upper)
    # clip can break the sum if d/K is outside the box; repair
    if int(d.sum()) != prob.total_samples:
        d = _integer_sum_fix(d.astype(float), prob)
    tau = prob.time_model.max_tau(d, prob.T)
    alloc = Allocation(tau=tau, d=d, method="eta")
    alloc.validate(prob)
    return alloc
