"""Analytic solver: KKT/Lagrangian structure + suggest-and-improve (SAI).

Paper Sec. IV: the relaxed QCLP (Eq. 8) is non-convex, but its KKT system
(Theorem 1, Eqs. 11-12) pins down the optimal structure. Eliminating tau_k
via the active time constraint t_k = T gives

    tau_k(d_k) = (T - C0_k)/(C2_k d_k) - C1_k/C2_k   (monotone decreasing in d_k)

Stationarity (Eq. 15) for any learner whose d_k is strictly inside
[d_l, d_u] (nu_k = nu'_k = 0) reads

    lambda_k (C2_k tau_k + C1_k) + omega = 0
      =>  tau_k = -(lambda_k C1_k + omega) / (lambda_k C2_k)   [Eq. 11]

with a *shared* multiplier omega for the sum constraint: all interior
learners share one tau*.  Learners clamped at d_l (resp. d_u) sit above
(resp. below) tau*.  Hence the optimum is a water-filling in tau*:

    d_k(tau*) = clip( (T - C0_k) / (C2_k tau* + C1_k), d_l, d_u )

and tau* is the unique root of  sum_k d_k(tau*) = d  (the left side is
continuous and strictly decreasing wherever some learner is unclamped).
``solve_relaxed`` bisects that root — this *is* the KKT solution with the
complementary-slackness cases enumerated, not a heuristic.

``suggest_and_improve`` then floors to integers and greedily repairs /
improves, mirroring the paper's SAI step.
"""

from __future__ import annotations

import numpy as np

from repro.core.allocation import Allocation, AllocationProblem
from repro.core.staleness import max_staleness

__all__ = [
    "solve_relaxed",
    "suggest_and_improve",
    "solve",
    "solve_energy",
    "variable_upper_bounds",
    "kkt_multipliers",
    "stationarity_residual",
]


def variable_upper_bounds(prob: AllocationProblem) -> tuple[np.ndarray, np.ndarray]:
    """Upper bounds on the optimal variables (paper Sec. IV-B): tau_k is
    maximized when d_k is at its lower bound; d_k is bounded by d_u and by
    the time budget at tau = 0."""
    tm = prob.time_model
    tau_ub = np.maximum(tm.tau_of_d(np.full(prob.num_learners, prob.d_lower), prob.T), 0.0)
    d_time_cap = (prob.T - tm.c0) / tm.c1  # d with tau = 0
    d_ub = np.minimum(np.full(prob.num_learners, float(prob.d_upper)), d_time_cap)
    return tau_ub, d_ub


def _d_of_tau_clipped(prob: AllocationProblem, tau_star: float) -> np.ndarray:
    tm = prob.time_model
    with np.errstate(over="ignore", invalid="ignore"):
        d = (prob.T - tm.c0) / (tm.c2 * tau_star + tm.c1)
    return np.clip(d, prob.d_lower, prob.d_upper)


def solve_relaxed(
    prob: AllocationProblem, *, tol: float = 1e-10, max_iter: int = 200
) -> tuple[np.ndarray, np.ndarray, float, int]:
    """Water-filling/KKT solution of the relaxed problem (Eq. 8).

    Returns (tau, d, tau_star, iters); tau/d are continuous.
    """
    tm = prob.time_model
    total = float(prob.total_samples)

    # Feasibility at tau* = 0: the most data the system can absorb.
    if _d_of_tau_clipped(prob, 0.0).sum() < total - 1e-9:
        raise ValueError(
            "infeasible: even with tau=0 the deadline T cannot absorb d samples"
        )

    lo, hi = 0.0, 1.0
    # grow hi until sum d(hi) <= d
    it = 0
    while _d_of_tau_clipped(prob, hi).sum() > total and it < 200:
        hi *= 2.0
        it += 1
    for _ in range(max_iter):
        mid = 0.5 * (lo + hi)
        s = _d_of_tau_clipped(prob, mid).sum()
        if s > total:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * max(1.0, hi):
            break
        it += 1

    tau_star = 0.5 * (lo + hi)
    d = _d_of_tau_clipped(prob, tau_star)
    # Redistribute the residual of the sum constraint among unclamped learners
    # (bisection leaves a tiny gap; spread it proportionally).
    free = (d > prob.d_lower + 1e-9) & (d < prob.d_upper - 1e-9)
    gap = total - d.sum()
    if np.any(free):
        d[free] += gap * (d[free] / d[free].sum())
    d = np.clip(d, prob.d_lower, prob.d_upper)
    tau = np.maximum(tm.tau_of_d(d, prob.T), 0.0)
    return tau, d, tau_star, it


def _integerize_d(prob: AllocationProblem, d_real: np.ndarray) -> np.ndarray:
    """Largest-remainder rounding of d_real to integers with exact sum and
    bounds respected."""
    base = np.floor(d_real).astype(np.int64)
    base = np.clip(base, prob.d_lower, prob.d_upper)
    deficit = prob.total_samples - int(base.sum())
    if deficit > 0:
        # hand out one sample at a time to the learners with largest remainder
        # that still have headroom
        # stable sorts keep tie-breaks deterministic and index-ordered so the
        # batched engine (solver_batched) reproduces this exactly
        rema = d_real - np.floor(d_real)
        order = np.argsort(-rema, kind="stable")
        i = 0
        while deficit > 0:
            k = order[i % len(order)]
            if base[k] < prob.d_upper:
                base[k] += 1
                deficit -= 1
            i += 1
            if i > 10 * len(order) + prob.total_samples:
                raise RuntimeError("integerize: could not place all samples")
    elif deficit < 0:
        order = np.argsort(d_real - np.floor(d_real), kind="stable")
        i = 0
        while deficit < 0:
            k = order[i % len(order)]
            if base[k] > prob.d_lower:
                base[k] -= 1
                deficit += 1
            i += 1
            if i > 10 * len(order) + prob.total_samples:
                raise RuntimeError("integerize: could not remove surplus")
    return base


def suggest_and_improve(
    prob: AllocationProblem,
    d_suggest: np.ndarray,
    *,
    max_rounds: int = 10_000,
) -> tuple[np.ndarray, np.ndarray, int]:
    """SAI (paper Sec. IV): start from the suggested (rounded) d, set each
    tau_k to its maximum feasible integer, then greedily move samples from
    low-tau learners to high-tau learners while the staleness objective
    improves. Every iterate is feasible."""
    tm = prob.time_model
    d = _integerize_d(prob, np.asarray(d_suggest, dtype=float))
    tau = tm.max_tau(d, prob.T)
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        s = max_staleness(tau)
        if s == 0:
            break
        hi = int(np.argmax(tau))   # too many updates -> give it MORE data
        lo_candidates = np.where(tau == tau.min())[0]
        # pick the min-tau learner that frees the most tau per sample removed
        lo = int(lo_candidates[np.argmax(tm.c2[lo_candidates])])
        # move m samples lo -> hi
        room = min(prob.d_upper - int(d[hi]), int(d[lo]) - prob.d_lower)
        if room <= 0:
            # try the next-highest tau learner with room
            order = np.argsort(-tau, kind="stable")
            moved = False
            for cand in order:
                if tau[cand] == tau.min():
                    break
                room = min(prob.d_upper - int(d[cand]), int(d[lo]) - prob.d_lower)
                if room > 0:
                    hi = int(cand)
                    moved = True
                    break
            if not moved:
                break
        m = max(1, room // 8)
        d2 = d.copy()
        d2[hi] += m
        d2[lo] -= m
        tau2 = tm.max_tau(d2, prob.T)
        if max_staleness(tau2) < s or (
            max_staleness(tau2) == s and tau2.sum() > tau.sum()
        ):
            d, tau = d2, tau2
            continue
        if m > 1:
            # retry with the minimal step before giving up on this pair
            d2 = d.copy()
            d2[hi] += 1
            d2[lo] -= 1
            tau2 = tm.max_tau(d2, prob.T)
            if max_staleness(tau2) < s or (
                max_staleness(tau2) == s and tau2.sum() > tau.sum()
            ):
                d, tau = d2, tau2
                continue
        break
    return tau, d, rounds


def solve(prob: AllocationProblem) -> Allocation:
    """Full paper pipeline: relaxed KKT water-filling -> floor -> SAI."""
    tau_r, d_r, _tau_star, it_relax = solve_relaxed(prob)
    tau, d, it_sai = suggest_and_improve(prob, d_r)
    alloc = Allocation(
        tau=tau,
        d=d,
        method="kkt_sai",
        relaxed_tau=tau_r,
        relaxed_d=d_r,
        solver_iters=it_relax + it_sai,
    )
    alloc.validate(prob)
    return alloc


# ---------------------------------------------------------------------------
# Energy-budgeted pipeline (arXiv 2012.00143) — the NumPy reference that
# ``solver_batched``'s kkt_energy policy mirrors decision for decision
# ---------------------------------------------------------------------------

_TAU_BIG = 2**30   # finite "unbounded tau" sentinel (see solver_batched)


def _max_tau_energy_np(d, e2, e1, e0, eb):
    """Largest integer tau with E_k <= eb at integer d; ``_TAU_BIG`` where
    the budget never binds (e2 = 0 or eb = inf)."""
    df = np.asarray(d, dtype=float)
    num = eb - e0 - e1 * df
    den = e2 * df
    with np.errstate(divide="ignore", invalid="ignore"):
        raw = np.where(
            den > 0, num / np.where(den > 0, den, 1.0),
            np.where(num >= 0, np.inf, -1.0),
        )
    t = np.floor(raw)
    t = np.where(np.isfinite(t), t, float(_TAU_BIG))
    t = np.where(df > 0, t, 0.0)
    return np.maximum(t, 0.0).astype(np.int64)


def _energy_rows_or_free(prob: AllocationProblem):
    """The problem's (e2, e1, e0, eb) rows; zero-cost/infinite-budget rows
    when no energy model is attached (kkt_sai-equivalent regime)."""
    rows = prob.energy_rows()
    if rows is not None:
        return rows
    k = prob.num_learners
    z = np.zeros(k)
    return z, z.copy(), z.copy(), np.full(k, np.inf)


def _integerize_d_vec(d_real, total, lo_i, hi_i):
    """``_integerize_d`` with per-learner integer bounds (the energy mask
    tightens d_hi per learner, so scalar problem bounds no longer apply)."""
    base = np.floor(d_real).astype(np.int64)
    base = np.clip(base, lo_i, hi_i)
    deficit = int(total) - int(base.sum())
    rema = d_real - np.floor(d_real)
    if deficit > 0:
        order = np.argsort(-rema, kind="stable")
        i = 0
        while deficit > 0:
            k = order[i % len(order)]
            if base[k] < hi_i[k]:
                base[k] += 1
                deficit -= 1
            i += 1
            if i > 10 * len(order) + int(total):
                raise RuntimeError("integerize: could not place all samples")
    elif deficit < 0:
        order = np.argsort(rema, kind="stable")
        i = 0
        while deficit < 0:
            k = order[i % len(order)]
            if base[k] > lo_i[k]:
                base[k] -= 1
                deficit += 1
            i += 1
            if i > 10 * len(order) + int(total):
                raise RuntimeError("integerize: could not remove surplus")
    return base


def _sai_energy_np(d, c2, c1, c0, T, lo_i, hi_i, valid, energy, max_rounds):
    """Greedy SAI with energy-capped taus over the affordable sub-fleet —
    the NumPy twin of ``solver_batched._sai_one`` with energy rows (same
    move selection, same tie-breaks, same exit conditions)."""
    sentinel = 2**31 - 1

    def tau_of(dd):
        df = dd.astype(float)
        with np.errstate(divide="ignore", invalid="ignore"):
            t = np.floor((T - c0 - c1 * df) / (c2 * df))
        t = np.where(dd > 0, t, 0.0)
        t = np.maximum(t, 0.0).astype(np.int64)
        return np.minimum(t, _max_tau_energy_np(dd, *energy))

    def stats(tau):
        return (int(np.max(np.where(valid, tau, -1))),
                int(np.min(np.where(valid, tau, sentinel))))

    tau = tau_of(d)
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        tmax, tmin = stats(tau)
        s = tmax - tmin
        if s <= 0:
            break
        hi0 = int(np.argmax(np.where(valid, tau, -1)))
        lo = int(np.argmax(np.where(valid & (tau == tmin), c2, -np.inf)))
        give = d[lo] - lo_i[lo]
        room_k = np.minimum(hi_i - d, give)
        room0 = room_k[hi0]
        if room0 <= 0:
            elig = valid & (tau > tmin) & (room_k > 0)
            if not elig.any():
                break
            hi_idx = int(np.argmax(np.where(elig, tau, -1)))
            room = int(room_k[hi_idx])
        else:
            hi_idx, room = hi0, int(room0)
        tau_sum = int(np.where(valid, tau, 0).sum())

        def try_move(m):
            d2 = d.copy()
            d2[hi_idx] += m
            d2[lo] -= m
            tau2 = tau_of(d2)
            tmax2, tmin2 = stats(tau2)
            s2 = tmax2 - tmin2
            better = s2 < s or (
                s2 == s and int(np.where(valid, tau2, 0).sum()) > tau_sum
            )
            return d2, tau2, better

        m_big = max(1, room // 8)
        d2, tau2, better = try_move(m_big)
        if better:
            d, tau = d2, tau2
            continue
        if m_big > 1:
            d2, tau2, better = try_move(1)
            if better:
                d, tau = d2, tau2
                continue
        break
    return tau, d, rounds


def solve_energy(
    prob: AllocationProblem,
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
    max_rounds: int = 10_000,
) -> Allocation:
    """Energy-budgeted KKT water-filling + SAI (arXiv 2012.00143).

    The pipeline of ``solve`` with the budget folded in at every stage:

      1. **affordability mask** — the tau = 0 budget cap
         ``(eb_k - e0_k) / e1_k`` tightens each d_hi; a learner whose cap
         cannot cover d_lower is removed (padded-slot semantics) and the
         sample budget clips into the surviving fleet's box
         (feasible-or-degraded, exactly like churn masking);
      2. **relaxed water-filling** on
         ``d_k(tau*) = clip(min(d_time, d_energy), d_lo, d_hi)`` where
         ``d_energy = (eb - e0)/(e2 tau* + e1)`` is the budget hyperbola
         — at any water level each learner absorbs what BOTH constraints
         allow;
      3. **integerize + SAI** with per-learner bounds and taus capped by
         ``_max_tau_energy_np``, so every iterate spends within budget.

    Without an energy model (or with eb = inf) every energy term is
    inert and the decisions coincide with ``solve``. The result is only
    validated against the problem when nothing was degraded (a degraded
    fleet intentionally breaks the d_lower/sum contract, like an offline
    fleet under churn).
    """
    tm = prob.time_model
    k = prob.num_learners
    e2, e1, e0, eb = _energy_rows_or_free(prob)
    energy = (e2, e1, e0, eb)

    lo = np.full(k, float(prob.d_lower))
    hi = np.full(k, float(prob.d_upper))
    room = eb - e0
    with np.errstate(divide="ignore", invalid="ignore"):
        capf = np.where(
            e1 > 0, room / np.where(e1 > 0, e1, 1.0),
            np.where(room >= 0, np.inf, -1.0),
        )
    hi_e = np.clip(np.minimum(np.floor(capf), hi), 0.0, hi)
    affordable = hi_e >= lo
    lo = np.where(affordable, lo, 0.0)
    hi = np.where(affordable, hi_e, 0.0)
    total = int(np.clip(prob.total_samples, lo.sum(), hi.sum()))
    degraded = (not affordable.all()) or total != prob.total_samples

    def d_of(tau_star):
        with np.errstate(over="ignore", invalid="ignore", divide="ignore"):
            dt = (prob.T - tm.c0) / (tm.c2 * tau_star + tm.c1)
            de = (eb - e0) / (e2 * tau_star + e1)
        return np.clip(np.minimum(dt, de), lo, hi)

    if d_of(0.0).sum() < total - 1e-9:
        raise ValueError(
            "infeasible: even with tau=0 the deadline T cannot absorb d samples"
        )

    lo_b, hi_b = 0.0, 1.0
    it = 0
    while d_of(hi_b).sum() > total and it < 200:
        hi_b *= 2.0
        it += 1
    for _ in range(max_iter):
        mid = 0.5 * (lo_b + hi_b)
        if d_of(mid).sum() > total:
            lo_b = mid
        else:
            hi_b = mid
        if hi_b - lo_b < tol * max(1.0, hi_b):
            break
        it += 1
    tau_star = 0.5 * (lo_b + hi_b)

    d_r = d_of(tau_star)
    free = (d_r > lo + 1e-9) & (d_r < hi - 1e-9)
    gap = total - d_r.sum()
    if np.any(free):
        d_r[free] += gap * (d_r[free] / d_r[free].sum())
    d_r = np.clip(d_r, lo, hi)
    with np.errstate(over="ignore", invalid="ignore", divide="ignore"):
        tau_t = (prob.T - tm.c0 - tm.c1 * d_r) / (tm.c2 * d_r)
        tau_e = (eb - e0 - e1 * d_r) / (e2 * d_r)
    tau_r = np.where(d_r > 0, np.maximum(np.minimum(tau_t, tau_e), 0.0), 0.0)

    lo_i = np.round(lo).astype(np.int64)
    hi_i = np.round(hi).astype(np.int64)
    d_int = _integerize_d_vec(d_r, total, lo_i, hi_i)
    tau, d, it_sai = _sai_energy_np(
        d_int, tm.c2, tm.c1, tm.c0, prob.T, lo_i, hi_i, affordable, energy,
        max_rounds,
    )
    alloc = Allocation(
        tau=tau,
        d=d,
        method="kkt_energy",
        relaxed_tau=tau_r,
        relaxed_d=d_r,
        solver_iters=it + it_sai,
    )
    if not degraded:
        alloc.validate(prob)
    return alloc


# ---------------------------------------------------------------------------
# KKT diagnostics (used by tests to certify Theorem 1 holds at our optimum)
# ---------------------------------------------------------------------------

def kkt_multipliers(prob: AllocationProblem, d: np.ndarray) -> dict:
    """Recover (lambda_k, omega) for the relaxed solution with interior d_k.

    For interior learners Eq. 15 gives lambda_k (C2 tau* + C1_k) = -omega.
    The objective gradient fixes the mu-scale; we normalize omega = 1 and
    report the stationarity residual of Eq. 15 per learner.
    """
    tm = prob.time_model
    tau = tm.tau_of_d(np.asarray(d, dtype=float), prob.T)
    interior = (d > prob.d_lower + 1e-6) & (d < prob.d_upper - 1e-6)
    omega = 1.0
    lam = np.where(interior, -omega / (tm.c2 * tau + tm.c1), np.nan)
    return {"lambda": lam, "omega": omega, "interior": interior, "tau": tau}


def stationarity_residual(prob: AllocationProblem, d: np.ndarray) -> float:
    """Max |lambda_k C2 tau_k + lambda_k C1_k + omega| over interior
    learners — ~0 certifies the water-filling point satisfies Eq. 15."""
    info = kkt_multipliers(prob, d)
    tm = prob.time_model
    lam, tau, interior = info["lambda"], info["tau"], info["interior"]
    res = lam * (tm.c2 * tau + tm.c1) + info["omega"]
    if not np.any(interior):
        return 0.0
    return float(np.nanmax(np.abs(res[interior])))
