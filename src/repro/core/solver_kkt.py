"""Analytic solver: KKT/Lagrangian structure + suggest-and-improve (SAI).

Paper Sec. IV: the relaxed QCLP (Eq. 8) is non-convex, but its KKT system
(Theorem 1, Eqs. 11-12) pins down the optimal structure. Eliminating tau_k
via the active time constraint t_k = T gives

    tau_k(d_k) = (T - C0_k)/(C2_k d_k) - C1_k/C2_k   (monotone decreasing in d_k)

Stationarity (Eq. 15) for any learner whose d_k is strictly inside
[d_l, d_u] (nu_k = nu'_k = 0) reads

    lambda_k (C2_k tau_k + C1_k) + omega = 0
      =>  tau_k = -(lambda_k C1_k + omega) / (lambda_k C2_k)   [Eq. 11]

with a *shared* multiplier omega for the sum constraint: all interior
learners share one tau*.  Learners clamped at d_l (resp. d_u) sit above
(resp. below) tau*.  Hence the optimum is a water-filling in tau*:

    d_k(tau*) = clip( (T - C0_k) / (C2_k tau* + C1_k), d_l, d_u )

and tau* is the unique root of  sum_k d_k(tau*) = d  (the left side is
continuous and strictly decreasing wherever some learner is unclamped).
``solve_relaxed`` bisects that root — this *is* the KKT solution with the
complementary-slackness cases enumerated, not a heuristic.

``suggest_and_improve`` then floors to integers and greedily repairs /
improves, mirroring the paper's SAI step.
"""

from __future__ import annotations

import numpy as np

from repro.core.allocation import Allocation, AllocationProblem
from repro.core.staleness import max_staleness

__all__ = [
    "solve_relaxed",
    "suggest_and_improve",
    "solve",
    "variable_upper_bounds",
    "kkt_multipliers",
    "stationarity_residual",
]


def variable_upper_bounds(prob: AllocationProblem) -> tuple[np.ndarray, np.ndarray]:
    """Upper bounds on the optimal variables (paper Sec. IV-B): tau_k is
    maximized when d_k is at its lower bound; d_k is bounded by d_u and by
    the time budget at tau = 0."""
    tm = prob.time_model
    tau_ub = np.maximum(tm.tau_of_d(np.full(prob.num_learners, prob.d_lower), prob.T), 0.0)
    d_time_cap = (prob.T - tm.c0) / tm.c1  # d with tau = 0
    d_ub = np.minimum(np.full(prob.num_learners, float(prob.d_upper)), d_time_cap)
    return tau_ub, d_ub


def _d_of_tau_clipped(prob: AllocationProblem, tau_star: float) -> np.ndarray:
    tm = prob.time_model
    with np.errstate(over="ignore", invalid="ignore"):
        d = (prob.T - tm.c0) / (tm.c2 * tau_star + tm.c1)
    return np.clip(d, prob.d_lower, prob.d_upper)


def solve_relaxed(
    prob: AllocationProblem, *, tol: float = 1e-10, max_iter: int = 200
) -> tuple[np.ndarray, np.ndarray, float, int]:
    """Water-filling/KKT solution of the relaxed problem (Eq. 8).

    Returns (tau, d, tau_star, iters); tau/d are continuous.
    """
    tm = prob.time_model
    total = float(prob.total_samples)

    # Feasibility at tau* = 0: the most data the system can absorb.
    if _d_of_tau_clipped(prob, 0.0).sum() < total - 1e-9:
        raise ValueError(
            "infeasible: even with tau=0 the deadline T cannot absorb d samples"
        )

    lo, hi = 0.0, 1.0
    # grow hi until sum d(hi) <= d
    it = 0
    while _d_of_tau_clipped(prob, hi).sum() > total and it < 200:
        hi *= 2.0
        it += 1
    for _ in range(max_iter):
        mid = 0.5 * (lo + hi)
        s = _d_of_tau_clipped(prob, mid).sum()
        if s > total:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * max(1.0, hi):
            break
        it += 1

    tau_star = 0.5 * (lo + hi)
    d = _d_of_tau_clipped(prob, tau_star)
    # Redistribute the residual of the sum constraint among unclamped learners
    # (bisection leaves a tiny gap; spread it proportionally).
    free = (d > prob.d_lower + 1e-9) & (d < prob.d_upper - 1e-9)
    gap = total - d.sum()
    if np.any(free):
        d[free] += gap * (d[free] / d[free].sum())
    d = np.clip(d, prob.d_lower, prob.d_upper)
    tau = np.maximum(tm.tau_of_d(d, prob.T), 0.0)
    return tau, d, tau_star, it


def _integerize_d(prob: AllocationProblem, d_real: np.ndarray) -> np.ndarray:
    """Largest-remainder rounding of d_real to integers with exact sum and
    bounds respected."""
    base = np.floor(d_real).astype(np.int64)
    base = np.clip(base, prob.d_lower, prob.d_upper)
    deficit = prob.total_samples - int(base.sum())
    if deficit > 0:
        # hand out one sample at a time to the learners with largest remainder
        # that still have headroom
        # stable sorts keep tie-breaks deterministic and index-ordered so the
        # batched engine (solver_batched) reproduces this exactly
        rema = d_real - np.floor(d_real)
        order = np.argsort(-rema, kind="stable")
        i = 0
        while deficit > 0:
            k = order[i % len(order)]
            if base[k] < prob.d_upper:
                base[k] += 1
                deficit -= 1
            i += 1
            if i > 10 * len(order) + prob.total_samples:
                raise RuntimeError("integerize: could not place all samples")
    elif deficit < 0:
        order = np.argsort(d_real - np.floor(d_real), kind="stable")
        i = 0
        while deficit < 0:
            k = order[i % len(order)]
            if base[k] > prob.d_lower:
                base[k] -= 1
                deficit += 1
            i += 1
            if i > 10 * len(order) + prob.total_samples:
                raise RuntimeError("integerize: could not remove surplus")
    return base


def suggest_and_improve(
    prob: AllocationProblem,
    d_suggest: np.ndarray,
    *,
    max_rounds: int = 10_000,
) -> tuple[np.ndarray, np.ndarray, int]:
    """SAI (paper Sec. IV): start from the suggested (rounded) d, set each
    tau_k to its maximum feasible integer, then greedily move samples from
    low-tau learners to high-tau learners while the staleness objective
    improves. Every iterate is feasible."""
    tm = prob.time_model
    d = _integerize_d(prob, np.asarray(d_suggest, dtype=float))
    tau = tm.max_tau(d, prob.T)
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        s = max_staleness(tau)
        if s == 0:
            break
        hi = int(np.argmax(tau))   # too many updates -> give it MORE data
        lo_candidates = np.where(tau == tau.min())[0]
        # pick the min-tau learner that frees the most tau per sample removed
        lo = int(lo_candidates[np.argmax(tm.c2[lo_candidates])])
        # move m samples lo -> hi
        room = min(prob.d_upper - int(d[hi]), int(d[lo]) - prob.d_lower)
        if room <= 0:
            # try the next-highest tau learner with room
            order = np.argsort(-tau, kind="stable")
            moved = False
            for cand in order:
                if tau[cand] == tau.min():
                    break
                room = min(prob.d_upper - int(d[cand]), int(d[lo]) - prob.d_lower)
                if room > 0:
                    hi = int(cand)
                    moved = True
                    break
            if not moved:
                break
        m = max(1, room // 8)
        d2 = d.copy()
        d2[hi] += m
        d2[lo] -= m
        tau2 = tm.max_tau(d2, prob.T)
        if max_staleness(tau2) < s or (
            max_staleness(tau2) == s and tau2.sum() > tau.sum()
        ):
            d, tau = d2, tau2
            continue
        if m > 1:
            # retry with the minimal step before giving up on this pair
            d2 = d.copy()
            d2[hi] += 1
            d2[lo] -= 1
            tau2 = tm.max_tau(d2, prob.T)
            if max_staleness(tau2) < s or (
                max_staleness(tau2) == s and tau2.sum() > tau.sum()
            ):
                d, tau = d2, tau2
                continue
        break
    return tau, d, rounds


def solve(prob: AllocationProblem) -> Allocation:
    """Full paper pipeline: relaxed KKT water-filling -> floor -> SAI."""
    tau_r, d_r, _tau_star, it_relax = solve_relaxed(prob)
    tau, d, it_sai = suggest_and_improve(prob, d_r)
    alloc = Allocation(
        tau=tau,
        d=d,
        method="kkt_sai",
        relaxed_tau=tau_r,
        relaxed_d=d_r,
        solver_iters=it_relax + it_sai,
    )
    alloc.validate(prob)
    return alloc


# ---------------------------------------------------------------------------
# KKT diagnostics (used by tests to certify Theorem 1 holds at our optimum)
# ---------------------------------------------------------------------------

def kkt_multipliers(prob: AllocationProblem, d: np.ndarray) -> dict:
    """Recover (lambda_k, omega) for the relaxed solution with interior d_k.

    For interior learners Eq. 15 gives lambda_k (C2 tau* + C1_k) = -omega.
    The objective gradient fixes the mu-scale; we normalize omega = 1 and
    report the stationarity residual of Eq. 15 per learner.
    """
    tm = prob.time_model
    tau = tm.tau_of_d(np.asarray(d, dtype=float), prob.T)
    interior = (d > prob.d_lower + 1e-6) & (d < prob.d_upper - 1e-6)
    omega = 1.0
    lam = np.where(interior, -omega / (tm.c2 * tau + tm.c1), np.nan)
    return {"lambda": lam, "omega": omega, "interior": interior, "tau": tau}


def stationarity_residual(prob: AllocationProblem, d: np.ndarray) -> float:
    """Max |lambda_k C2 tau_k + lambda_k C1_k + omega| over interior
    learners — ~0 certifies the water-filling point satisfies Eq. 15."""
    info = kkt_multipliers(prob, d)
    tm = prob.time_model
    lam, tau, interior = info["lambda"], info["tau"], info["interior"]
    res = lam * (tm.c2 * tau + tm.c1) + info["omega"]
    if not np.any(interior):
        return 0.0
    return float(np.nanmax(np.abs(res[interior])))
