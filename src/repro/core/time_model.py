"""Per-learner time model of the MEL global cycle (paper Eqs. 1-5).

Each global cycle of wall-clock budget ``T`` covers, for learner ``k``:

  t_k^S  - orchestrator -> learner transfer of the global model w and
           (task-parallelization only) the d_k data samples      (Eq. 1)
  t_k^C  - one local SGD update over d_k samples                  (Eq. 2);
           tau_k updates cost tau_k * t_k^C
  t_k^R  - learner -> orchestrator return of the local model      (Eq. 3)

Total (Eq. 4/5):   t_k = C2_k * tau_k * d_k + C1_k * d_k + C0_k

with
  C2_k = C_m / f_k
  C1_k = (F * P_d + 2 * P_m * S_d) / R_k        (task-parallelization)
       = (        2 * P_m * S_d) / R_k          (distributed-datasets)
  C0_k = 2 * P_m * S_m / R_k
  R_k  = W * log2(1 + P_k h_k / N0)             (achievable rate, bit/s)

Everything is plain float math over numpy arrays so the allocator can run
on hosts without touching jax device state; a jax twin lives in
``solver_numeric`` for the batched jit path.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "CapacityDrift",
    "ChannelParams",
    "LearnerProfile",
    "QueueDrift",
    "TimeModel",
    "indoor_80211_profile",
    "is_state_coupled",
    "pod_slice_profile",
]


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    """Link parameters for one learner<->orchestrator channel."""

    bandwidth_hz: float = 5e6        # W
    tx_power_w: float = 0.1          # P_ko (20 dBm)
    gain: float = 1e-8               # h_ko (path loss, linear; ~80 dB)
    noise_psd: float = 4e-21         # N0 (W/Hz), thermal ~ -174 dBm/Hz

    def rate_bps(self) -> float:
        snr = self.tx_power_w * self.gain / (self.noise_psd * self.bandwidth_hz)
        return self.bandwidth_hz * np.log2(1.0 + snr)


@dataclasses.dataclass(frozen=True)
class LearnerProfile:
    """One edge learner: compute rate + channel."""

    clock_hz: float                  # f_k, effective clocks/sec
    channel: ChannelParams
    name: str = "learner"


@dataclasses.dataclass(frozen=True)
class TimeModel:
    """Vectorized coefficients (C2, C1, C0) for K learners.

    Attributes
    ----------
    c2, c1, c0 : np.ndarray shape (K,)
        Quadratic / linear / constant coefficients of Eq. 5.
    """

    c2: np.ndarray
    c1: np.ndarray
    c0: np.ndarray

    @property
    def num_learners(self) -> int:
        return int(self.c2.shape[0])

    @staticmethod
    def build(
        profiles: Sequence[LearnerProfile],
        *,
        model_complexity_flops: float,     # C_m: clocks (~= FLOPs) per sample per epoch
        model_size_bits: float,            # S_m * P_m ... we take bits directly
        features_per_sample: int = 784,    # F
        data_precision_bits: int = 32,     # P_d
        model_precision_bits: int = 32,    # P_m (folded into sizes below)
        sample_model_scaling_bits: float = 0.0,  # P_m * S_d: model bits that scale w/ d_k
        task_parallelization: bool = True,
    ) -> "TimeModel":
        """Build (C2, C1, C0) from learner profiles (paper Sec. II).

        ``model_size_bits`` is the full serialized model (P_m * S_m).
        ``sample_model_scaling_bits`` is P_m * S_d - the per-sample part of
        the model transfer (zero for the architectures we care about).
        """
        k = len(profiles)
        c2 = np.empty(k)
        c1 = np.empty(k)
        c0 = np.empty(k)
        for i, p in enumerate(profiles):
            rate = p.channel.rate_bps()
            c2[i] = model_complexity_flops / p.clock_hz
            data_bits = features_per_sample * data_precision_bits if task_parallelization else 0.0
            c1[i] = (data_bits + 2.0 * sample_model_scaling_bits) / rate
            c0[i] = 2.0 * model_size_bits / rate
        del model_precision_bits  # already folded into the *_bits arguments
        return TimeModel(c2=c2, c1=c1, c0=c0)

    # --- Eq. 5 -----------------------------------------------------------
    def cycle_time(self, tau: np.ndarray, d: np.ndarray) -> np.ndarray:
        """t_k for each learner."""
        tau = np.asarray(tau, dtype=float)
        d = np.asarray(d, dtype=float)
        return self.c2 * tau * d + self.c1 * d + self.c0

    # --- the reduced form used by the solvers ----------------------------
    def tau_of_d(self, d: np.ndarray, T: float) -> np.ndarray:
        """tau_k(d_k) = (T - C0_k - C1_k d_k) / (C2_k d_k)  — Eq. 5 solved
        for tau with t_k = T. May be negative => learner infeasible."""
        d = np.asarray(d, dtype=float)
        return (T - self.c0 - self.c1 * d) / (self.c2 * d)

    def d_of_tau(self, tau: np.ndarray, T: float) -> np.ndarray:
        """d_k(tau_k) = (T - C0_k) / (C2_k tau_k + C1_k) — inverse map."""
        tau = np.asarray(tau, dtype=float)
        return (T - self.c0) / (self.c2 * tau + self.c1)

    def max_tau(self, d: np.ndarray, T: float) -> np.ndarray:
        """Largest integer tau_k with t_k <= T for given integer d_k."""
        d = np.asarray(d, dtype=float)
        with np.errstate(divide="ignore", invalid="ignore"):
            t = np.floor((T - self.c0 - self.c1 * d) / (self.c2 * d))
        t = np.where(d > 0, t, 0.0)
        return np.maximum(t, 0.0).astype(np.int64)


# ---------------------------------------------------------------------------
# Time-varying capacities (per-cycle drift)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CapacityDrift:
    """Seeded, jit-compatible per-cycle drift of a fleet's capacities.

    Two independent multiplicative processes, re-drawn each global cycle
    (block model: capacities are constant within a cycle):

      * compute drift — effective clock f_k jitters by a uniform factor in
        ``[1 - clock_jitter, 1 + clock_jitter]`` (thermal throttling,
        co-tenant load), scaling C2_k by its inverse;
      * channel fading — the achievable rate R_k is multiplied by a clipped
        lognormal shadowing factor ``10^(X/10)``, X ~ N(0, fading_sigma_db)
        clipped to ±fading_clip_db (log-distance shadowing re-drawn per
        cycle), scaling C1_k and C0_k by its inverse.

    ``factors_at`` uses ``jax.random.fold_in(key(seed), cycle)`` so it is
    traceable on a traced cycle index (usable inside ``lax.scan``) and the
    whole path is reproducible from ``seed`` alone. Draws are generated in
    float32 regardless of the x64 flag, so the random bits are identical in
    every compilation context; the one transcendental (the dB -> linear
    ``10^(x/10)``) is requested in float64 and rounded once to float32,
    which keeps jit-fused and eager/vmapped evaluations within 1 f32 ULP of
    each other (XLA may narrow the widened pow under jit, so exact bitwise
    equality across compilation contexts is NOT guaranteed — only the
    integer allocations derived from the rows are, pinned by the
    fused-vs-eager orchestrator equivalence tests).
    """

    clock_jitter: float = 0.1
    fading_sigma_db: float = 2.0
    fading_clip_db: float = 6.0
    seed: int = 0

    def factors_at(self, cycle, k: int):
        """(clock_factor, rate_factor), each (K,) float32, for one cycle."""
        import jax
        import jax.numpy as jnp

        key = jax.random.fold_in(jax.random.key(self.seed), cycle)
        kc, kf = jax.random.split(key)
        clock = 1.0 + self.clock_jitter * (
            2.0 * jax.random.uniform(kc, (k,), jnp.float32) - 1.0
        )
        db = jnp.clip(
            self.fading_sigma_db * jax.random.normal(kf, (k,), jnp.float32),
            -self.fading_clip_db, self.fading_clip_db,
        )
        # f64 pow + one rounding: bit-stable across jit/eager/vmap contexts
        # (falls back to plain f32 pow when x64 is disabled)
        rate = jnp.power(
            jnp.asarray(10.0, jnp.float64), db.astype(jnp.float64) / 10.0
        ).astype(jnp.float32)
        return clock, rate

    def coefficient_path(
        self, tm: "TimeModel", cycles: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Drifted (c2, c1, c0) float64 numpy arrays of shape (C, K); row c
        is the fleet's true capacity during global cycle c. Runs under
        ``enable_x64`` so the factors match the traced in-scan
        ``factors_at`` consumers as closely as the compiler allows (within
        1 f32 ULP; see class docstring)."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        k = tm.num_learners
        with enable_x64():
            clock, rate = jax.vmap(lambda c: self.factors_at(c, k))(
                jnp.arange(cycles)
            )
        clock = np.asarray(clock, np.float64)
        rate = np.asarray(rate, np.float64)
        return tm.c2[None] / clock, tm.c1[None] / rate, tm.c0[None] / rate


# ---------------------------------------------------------------------------
# State-coupled capacities (queue-driven drift)
# ---------------------------------------------------------------------------

def is_state_coupled(drift) -> bool:
    """True when ``drift`` follows the state-coupled protocol: it carries
    per-fleet state through the run (``state_init`` / ``state_update``) and
    its ``factors_at`` takes that state as a third argument. Consumers use
    this to decide whether the capacity rows can be materialized up front
    (exogenous drift — ``CapacityDrift.coefficient_path``) or must be
    rolled out jointly with the allocations (``QueueDrift.rollout`` on the
    host, the scan carry on the fused path)."""
    return hasattr(drift, "state_update") and hasattr(drift, "state_init")


@dataclasses.dataclass(frozen=True)
class QueueDrift:
    """State-coupled capacity drift: per-learner congestion queues driven by
    the work the allocator itself dispatches.

    ``CapacityDrift`` models exogenous rate/clock processes; real edge
    fleets additionally couple capacity to system state — a learner that
    keeps receiving more than its fair share of samples builds a transfer
    backlog that degrades its achievable rate (queueing at the access
    point, contention on the shared channel). This class closes that loop:

      * **state** — a ``(K,)`` float32 backlog vector ``q``, one queue per
        learner, starting at ``state_init(k)`` (zeros);
      * **dynamics** (``state_update``) — after the cycle's allocation
        ``(tau, d)`` is served, each queue moves by the learner's load
        relative to its fair share,  ``q' = clip(q + gain * (d_k * K /
        sum(d) - service), 0, q_max)`` — a learner at fair share
        (``load = service = 1``) holds its backlog, an over-loaded learner
        accumulates, an under-loaded one drains;
      * **capacity coupling** (``factors_at``) — the achievable rate R_k is
        degraded by the backlog, ``rate_factor = 1 / (1 + congestion *
        q_k)``, scaling C1_k and C0_k by its inverse (the same lever
        ``CapacityDrift`` fades); compute (C2_k) is untouched unless a
        ``base`` exogenous drift is composed on top.

    ``factors_at(cycle, k, state)`` is the state-coupled overload of
    ``CapacityDrift.factors_at(cycle, k)``: same return convention
    ((clock, rate) float32 factor pairs), with the extra ``state``
    argument read from the fused scan's carry. All queue arithmetic is
    elementwise float32 with no transcendentals, so traced (in-scan) and
    host (``rollout``) evaluations are **bitwise identical**; composing a
    ``base`` ``CapacityDrift`` re-introduces that class's documented
    1-f32-ULP pow caveat.

    Because the capacities of cycle c depend on the allocations of cycles
    < c, there is no standalone coefficient path: rows and allocations
    must be produced together, either sequentially on the host
    (``rollout``, used by the eager orchestrator and the async engine's
    scheduler) or inside the fused scan (``Orchestrator.run_fused(
    reallocate=True)``, where ``factors_at`` reads the queue state from
    the scan carry and no host coefficient path enters the program).
    """

    congestion: float = 0.3     # rate degradation per unit backlog
    gain: float = 1.0           # backlog added per unit of excess load
    service: float = 1.0        # fair-share load served per cycle
    q_max: float = 8.0          # backlog clip (bounded buffers)
    base: CapacityDrift | None = None   # exogenous drift composed on top

    def state_init(self, k: int):
        """Initial (K,) float32 backlog: empty queues."""
        import jax.numpy as jnp

        return jnp.zeros((k,), jnp.float32)

    def factors_at(self, cycle, k: int, state):
        """(clock_factor, rate_factor), each (K,) float32, for one cycle
        given the current backlog ``state``. The state-coupled overload of
        ``CapacityDrift.factors_at`` — jit-compatible on a traced cycle
        index AND a traced state (the fused scan's carry)."""
        import jax.numpy as jnp

        if self.base is not None:
            clock, rate = self.base.factors_at(cycle, k)
        else:
            clock = jnp.ones((k,), jnp.float32)
            rate = jnp.ones((k,), jnp.float32)
        q = jnp.asarray(state, jnp.float32)
        rate = rate / (1.0 + jnp.float32(self.congestion) * q)
        return clock, rate

    def state_update(self, cycle, state, tau, d):
        """Next (K,) float32 backlog after serving allocation ``(tau, d)``.

        ``load_k = d_k * K / sum(d)`` is the learner's share of the cycle's
        transfer volume relative to fair share (the sum is exact integer
        arithmetic; everything after is elementwise f32, bit-stable across
        jit/eager contexts). ``tau`` is accepted for protocol generality
        (compute-queue models would read it) but unused here; ``cycle``
        likewise (time-varying service rates would read it)."""
        import jax.numpy as jnp

        del cycle, tau
        k = d.shape[-1]
        tot = jnp.maximum(jnp.sum(d), 1).astype(jnp.float32)
        load = d.astype(jnp.float32) * jnp.float32(k) / tot
        q = jnp.asarray(state, jnp.float32)
        q = q + jnp.float32(self.gain) * (load - jnp.float32(self.service))
        return jnp.clip(q, 0.0, jnp.float32(self.q_max))

    def rollout_iter(self, tm: "TimeModel", cycles: int, solve):
        """Lazy host-side rollout of the coupled system: per cycle,
        evaluate the drifted (c2, c1, c0) row from the current queue
        state, call ``solve(cycle, c2_row, c1_row, c0_row) -> (tau, d)``
        (integer (K,) arrays), advance the state with that allocation, and
        yield ``(c2_row, c1_row, c0_row, tau, d)``. Laziness lets a
        consumer interleave its own per-cycle work (the eager
        orchestrator trains between solves, so an infeasible cycle raises
        only AFTER the feasible prefix ran — the same contract as the
        fused scan's in-scan guard).

        The factor math runs under ``enable_x64`` (entered per cycle so
        the flag never leaks into consumer code between yields) with
        f32-pinned draws, exactly like ``CapacityDrift.coefficient_path``,
        so the rows match the fused scan's in-scan ``factors_at``
        consumers (bitwise for the queue coupling; 1 f32 ULP when a
        ``base`` drift composes its pow). Raises whatever ``solve``
        raises (e.g. infeasibility) at the first offending cycle."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        k = tm.num_learners
        state = None
        for c in range(cycles):
            with enable_x64():
                if state is None:
                    state = self.state_init(k)
                clock, rate = self.factors_at(c, k, state)
                clock = np.asarray(clock, np.float64)
                rate = np.asarray(rate, np.float64)
                c2r = tm.c2 / clock
                c1r = tm.c1 / rate
                c0r = tm.c0 / rate
                tau, d = solve(c, c2r, c1r, c0r)
                state = self.state_update(
                    c, state, jnp.asarray(tau), jnp.asarray(d)
                )
            yield c2r, c1r, c0r, tau, d

    def rollout(self, tm: "TimeModel", cycles: int, solve):
        """Eager collection of ``rollout_iter``: returns
        ``((c2s, c1s, c0s), (taus, ds))`` — (C, K) float64 rows and (C, K)
        int64 allocations (see ``rollout_iter`` for semantics)."""
        k = tm.num_learners
        c2s = np.empty((cycles, k))
        c1s = np.empty((cycles, k))
        c0s = np.empty((cycles, k))
        taus = np.zeros((cycles, k), np.int64)
        ds = np.zeros((cycles, k), np.int64)
        for c, (c2r, c1r, c0r, tau, d) in enumerate(
            self.rollout_iter(tm, cycles, solve)
        ):
            c2s[c], c1s[c], c0s[c] = c2r, c1r, c0r
            taus[c], ds[c] = tau, d
        return (c2s, c1s, c0s), (taus, ds)


# ---------------------------------------------------------------------------
# Reference environments
# ---------------------------------------------------------------------------

def indoor_80211_profile(
    k: int,
    *,
    seed: int = 0,
    radius_m: float = 50.0,
    bandwidth_hz: float = 5e6,
    tx_power_w: float = 0.1,
    noise_psd: float = 4e-21,
    fast_clock_hz: float = 2.4e9,
    slow_clock_hz: float = 0.7e9,
) -> list[LearnerProfile]:
    """The paper's simulation environment (Sec. V-A): K nodes within a 50 m
    radius over 802.11-type links; ~half are desktop/laptop class, half are
    Raspberry-Pi class. Path loss follows a standard indoor log-distance
    model (Table 1 of ref [9]: PL(d) = PL0 + 10 n log10(d), n ~= 3,
    PL0 ~= 40 dB at 1 m, plus lognormal shadowing sigma = 4 dB).
    """
    rng = np.random.default_rng(seed)
    dist = rng.uniform(2.0, radius_m, size=k)
    pl_db = 40.0 + 10.0 * 3.0 * np.log10(dist) + rng.normal(0.0, 4.0, size=k)
    gains = 10.0 ** (-pl_db / 10.0)
    profiles = []
    for i in range(k):
        fast = i % 2 == 0
        clock = fast_clock_hz if fast else slow_clock_hz
        # mild per-node compute jitter (thermal throttling etc.)
        clock *= rng.uniform(0.9, 1.1)
        profiles.append(
            LearnerProfile(
                clock_hz=clock,
                channel=ChannelParams(
                    bandwidth_hz=bandwidth_hz,
                    tx_power_w=tx_power_w,
                    gain=float(gains[i]),
                    noise_psd=noise_psd,
                ),
                name=f"{'edge' if fast else 'mcu'}-{i}",
            )
        )
    return profiles


def pod_slice_profile(
    k: int,
    *,
    seed: int = 0,
    chips_per_slice: int = 256,
    peak_flops: float = 197e12,
    mfu_range: tuple[float, float] = (0.3, 0.55),
    dcn_gbps_range: tuple[float, float] = (25.0, 100.0),
) -> list[LearnerProfile]:
    """TPU-native adaptation: each 'learner' is a pod slice with an effective
    throughput (chips x peak x MFU) and a DCN link to the orchestrator.
    The Shannon-rate channel is replaced by a fixed-rate DCN link encoded as
    an equivalent (W, SNR) pair with rate == dcn_gbps.
    """
    rng = np.random.default_rng(seed)
    profiles = []
    for i in range(k):
        mfu = rng.uniform(*mfu_range)
        flops = chips_per_slice * peak_flops * mfu
        rate_bps = rng.uniform(*dcn_gbps_range) * 1e9
        # encode the fixed rate: W = rate, SNR = 1 -> W*log2(2) = rate
        ch = ChannelParams(
            bandwidth_hz=rate_bps,
            tx_power_w=1.0,
            gain=1.0,
            noise_psd=1.0 / rate_bps,
        )
        profiles.append(LearnerProfile(clock_hz=flops, channel=ch, name=f"slice-{i}"))
    return profiles
