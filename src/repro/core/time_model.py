"""Per-learner time model of the MEL global cycle (paper Eqs. 1-5).

Each global cycle of wall-clock budget ``T`` covers, for learner ``k``:

  t_k^S  - orchestrator -> learner transfer of the global model w and
           (task-parallelization only) the d_k data samples      (Eq. 1)
  t_k^C  - one local SGD update over d_k samples                  (Eq. 2);
           tau_k updates cost tau_k * t_k^C
  t_k^R  - learner -> orchestrator return of the local model      (Eq. 3)

Total (Eq. 4/5):   t_k = C2_k * tau_k * d_k + C1_k * d_k + C0_k

with
  C2_k = C_m / f_k
  C1_k = (F * P_d + 2 * P_m * S_d) / R_k        (task-parallelization)
       = (        2 * P_m * S_d) / R_k          (distributed-datasets)
  C0_k = 2 * P_m * S_m / R_k
  R_k  = W * log2(1 + P_k h_k / N0)             (achievable rate, bit/s)

Everything is plain float math over numpy arrays so the allocator can run
on hosts without touching jax device state; a jax twin lives in
``solver_numeric`` for the batched jit path.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "CapacityDrift",
    "ChannelParams",
    "LearnerProfile",
    "TimeModel",
    "indoor_80211_profile",
    "pod_slice_profile",
]


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    """Link parameters for one learner<->orchestrator channel."""

    bandwidth_hz: float = 5e6        # W
    tx_power_w: float = 0.1          # P_ko (20 dBm)
    gain: float = 1e-8               # h_ko (path loss, linear; ~80 dB)
    noise_psd: float = 4e-21         # N0 (W/Hz), thermal ~ -174 dBm/Hz

    def rate_bps(self) -> float:
        snr = self.tx_power_w * self.gain / (self.noise_psd * self.bandwidth_hz)
        return self.bandwidth_hz * np.log2(1.0 + snr)


@dataclasses.dataclass(frozen=True)
class LearnerProfile:
    """One edge learner: compute rate + channel."""

    clock_hz: float                  # f_k, effective clocks/sec
    channel: ChannelParams
    name: str = "learner"


@dataclasses.dataclass(frozen=True)
class TimeModel:
    """Vectorized coefficients (C2, C1, C0) for K learners.

    Attributes
    ----------
    c2, c1, c0 : np.ndarray shape (K,)
        Quadratic / linear / constant coefficients of Eq. 5.
    """

    c2: np.ndarray
    c1: np.ndarray
    c0: np.ndarray

    @property
    def num_learners(self) -> int:
        return int(self.c2.shape[0])

    @staticmethod
    def build(
        profiles: Sequence[LearnerProfile],
        *,
        model_complexity_flops: float,     # C_m: clocks (~= FLOPs) per sample per epoch
        model_size_bits: float,            # S_m * P_m ... we take bits directly
        features_per_sample: int = 784,    # F
        data_precision_bits: int = 32,     # P_d
        model_precision_bits: int = 32,    # P_m (folded into sizes below)
        sample_model_scaling_bits: float = 0.0,  # P_m * S_d: model bits that scale w/ d_k
        task_parallelization: bool = True,
    ) -> "TimeModel":
        """Build (C2, C1, C0) from learner profiles (paper Sec. II).

        ``model_size_bits`` is the full serialized model (P_m * S_m).
        ``sample_model_scaling_bits`` is P_m * S_d - the per-sample part of
        the model transfer (zero for the architectures we care about).
        """
        k = len(profiles)
        c2 = np.empty(k)
        c1 = np.empty(k)
        c0 = np.empty(k)
        for i, p in enumerate(profiles):
            rate = p.channel.rate_bps()
            c2[i] = model_complexity_flops / p.clock_hz
            data_bits = features_per_sample * data_precision_bits if task_parallelization else 0.0
            c1[i] = (data_bits + 2.0 * sample_model_scaling_bits) / rate
            c0[i] = 2.0 * model_size_bits / rate
        del model_precision_bits  # already folded into the *_bits arguments
        return TimeModel(c2=c2, c1=c1, c0=c0)

    # --- Eq. 5 -----------------------------------------------------------
    def cycle_time(self, tau: np.ndarray, d: np.ndarray) -> np.ndarray:
        """t_k for each learner."""
        tau = np.asarray(tau, dtype=float)
        d = np.asarray(d, dtype=float)
        return self.c2 * tau * d + self.c1 * d + self.c0

    # --- the reduced form used by the solvers ----------------------------
    def tau_of_d(self, d: np.ndarray, T: float) -> np.ndarray:
        """tau_k(d_k) = (T - C0_k - C1_k d_k) / (C2_k d_k)  — Eq. 5 solved
        for tau with t_k = T. May be negative => learner infeasible."""
        d = np.asarray(d, dtype=float)
        return (T - self.c0 - self.c1 * d) / (self.c2 * d)

    def d_of_tau(self, tau: np.ndarray, T: float) -> np.ndarray:
        """d_k(tau_k) = (T - C0_k) / (C2_k tau_k + C1_k) — inverse map."""
        tau = np.asarray(tau, dtype=float)
        return (T - self.c0) / (self.c2 * tau + self.c1)

    def max_tau(self, d: np.ndarray, T: float) -> np.ndarray:
        """Largest integer tau_k with t_k <= T for given integer d_k."""
        d = np.asarray(d, dtype=float)
        with np.errstate(divide="ignore", invalid="ignore"):
            t = np.floor((T - self.c0 - self.c1 * d) / (self.c2 * d))
        t = np.where(d > 0, t, 0.0)
        return np.maximum(t, 0.0).astype(np.int64)


# ---------------------------------------------------------------------------
# Time-varying capacities (per-cycle drift)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CapacityDrift:
    """Seeded, jit-compatible per-cycle drift of a fleet's capacities.

    Two independent multiplicative processes, re-drawn each global cycle
    (block model: capacities are constant within a cycle):

      * compute drift — effective clock f_k jitters by a uniform factor in
        ``[1 - clock_jitter, 1 + clock_jitter]`` (thermal throttling,
        co-tenant load), scaling C2_k by its inverse;
      * channel fading — the achievable rate R_k is multiplied by a clipped
        lognormal shadowing factor ``10^(X/10)``, X ~ N(0, fading_sigma_db)
        clipped to ±fading_clip_db (log-distance shadowing re-drawn per
        cycle), scaling C1_k and C0_k by its inverse.

    ``factors_at`` uses ``jax.random.fold_in(key(seed), cycle)`` so it is
    traceable on a traced cycle index (usable inside ``lax.scan``) and the
    whole path is reproducible from ``seed`` alone. Draws are generated in
    float32 regardless of the x64 flag, so the random bits are identical in
    every compilation context; the one transcendental (the dB -> linear
    ``10^(x/10)``) is requested in float64 and rounded once to float32,
    which keeps jit-fused and eager/vmapped evaluations within 1 f32 ULP of
    each other (XLA may narrow the widened pow under jit, so exact bitwise
    equality across compilation contexts is NOT guaranteed — only the
    integer allocations derived from the rows are, pinned by the
    fused-vs-eager orchestrator equivalence tests).
    """

    clock_jitter: float = 0.1
    fading_sigma_db: float = 2.0
    fading_clip_db: float = 6.0
    seed: int = 0

    def factors_at(self, cycle, k: int):
        """(clock_factor, rate_factor), each (K,) float32, for one cycle."""
        import jax
        import jax.numpy as jnp

        key = jax.random.fold_in(jax.random.key(self.seed), cycle)
        kc, kf = jax.random.split(key)
        clock = 1.0 + self.clock_jitter * (
            2.0 * jax.random.uniform(kc, (k,), jnp.float32) - 1.0
        )
        db = jnp.clip(
            self.fading_sigma_db * jax.random.normal(kf, (k,), jnp.float32),
            -self.fading_clip_db, self.fading_clip_db,
        )
        # f64 pow + one rounding: bit-stable across jit/eager/vmap contexts
        # (falls back to plain f32 pow when x64 is disabled)
        rate = jnp.power(
            jnp.asarray(10.0, jnp.float64), db.astype(jnp.float64) / 10.0
        ).astype(jnp.float32)
        return clock, rate

    def coefficient_path(
        self, tm: "TimeModel", cycles: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Drifted (c2, c1, c0) float64 numpy arrays of shape (C, K); row c
        is the fleet's true capacity during global cycle c. Runs under
        ``enable_x64`` so the factors match the traced in-scan
        ``factors_at`` consumers as closely as the compiler allows (within
        1 f32 ULP; see class docstring)."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        k = tm.num_learners
        with enable_x64():
            clock, rate = jax.vmap(lambda c: self.factors_at(c, k))(
                jnp.arange(cycles)
            )
        clock = np.asarray(clock, np.float64)
        rate = np.asarray(rate, np.float64)
        return tm.c2[None] / clock, tm.c1[None] / rate, tm.c0[None] / rate


# ---------------------------------------------------------------------------
# Reference environments
# ---------------------------------------------------------------------------

def indoor_80211_profile(
    k: int,
    *,
    seed: int = 0,
    radius_m: float = 50.0,
    bandwidth_hz: float = 5e6,
    tx_power_w: float = 0.1,
    noise_psd: float = 4e-21,
    fast_clock_hz: float = 2.4e9,
    slow_clock_hz: float = 0.7e9,
) -> list[LearnerProfile]:
    """The paper's simulation environment (Sec. V-A): K nodes within a 50 m
    radius over 802.11-type links; ~half are desktop/laptop class, half are
    Raspberry-Pi class. Path loss follows a standard indoor log-distance
    model (Table 1 of ref [9]: PL(d) = PL0 + 10 n log10(d), n ~= 3,
    PL0 ~= 40 dB at 1 m, plus lognormal shadowing sigma = 4 dB).
    """
    rng = np.random.default_rng(seed)
    dist = rng.uniform(2.0, radius_m, size=k)
    pl_db = 40.0 + 10.0 * 3.0 * np.log10(dist) + rng.normal(0.0, 4.0, size=k)
    gains = 10.0 ** (-pl_db / 10.0)
    profiles = []
    for i in range(k):
        fast = i % 2 == 0
        clock = fast_clock_hz if fast else slow_clock_hz
        # mild per-node compute jitter (thermal throttling etc.)
        clock *= rng.uniform(0.9, 1.1)
        profiles.append(
            LearnerProfile(
                clock_hz=clock,
                channel=ChannelParams(
                    bandwidth_hz=bandwidth_hz,
                    tx_power_w=tx_power_w,
                    gain=float(gains[i]),
                    noise_psd=noise_psd,
                ),
                name=f"{'edge' if fast else 'mcu'}-{i}",
            )
        )
    return profiles


def pod_slice_profile(
    k: int,
    *,
    seed: int = 0,
    chips_per_slice: int = 256,
    peak_flops: float = 197e12,
    mfu_range: tuple[float, float] = (0.3, 0.55),
    dcn_gbps_range: tuple[float, float] = (25.0, 100.0),
) -> list[LearnerProfile]:
    """TPU-native adaptation: each 'learner' is a pod slice with an effective
    throughput (chips x peak x MFU) and a DCN link to the orchestrator.
    The Shannon-rate channel is replaced by a fixed-rate DCN link encoded as
    an equivalent (W, SNR) pair with rate == dcn_gbps.
    """
    rng = np.random.default_rng(seed)
    profiles = []
    for i in range(k):
        mfu = rng.uniform(*mfu_range)
        flops = chips_per_slice * peak_flops * mfu
        rate_bps = rng.uniform(*dcn_gbps_range) * 1e9
        # encode the fixed rate: W = rate, SNR = 1 -> W*log2(2) = rate
        ch = ChannelParams(
            bandwidth_hz=rate_bps,
            tx_power_w=1.0,
            gain=1.0,
            noise_psd=1.0 / rate_bps,
        )
        profiles.append(LearnerProfile(clock_hz=flops, channel=ch, name=f"slice-{i}"))
    return profiles
