"""Numerical solvers for the relaxed QCLP (paper Sec. IV compares the
analytic SAI solution against off-the-shelf NLP solvers).

Two implementations:

1. ``solve_slsqp`` — scipy SLSQP on the full relaxed program (Eq. 8):
   variables x = [tau_1..tau_K, d_1..d_K, z], objective z, quadratic
   equality constraints t_k = T, linear sum constraint, pairwise staleness
   inequalities. This mirrors the paper's use of OPTI/fmincon/IPOPT.

2. ``solve_pgd_jax`` — a jit-compiled projected-gradient/penalty solver.
   The time equalities are eliminated exactly through tau_k(d_k); d_k is
   parameterized as d_l + (d_u - d_l) * sigmoid(theta_k) so the box
   constraint always holds; the sum constraint and the (smoothed) max-min
   staleness objective go into the loss. ``vmap``-able across problem
   batches: this is the production path when an orchestrator must re-solve
   allocation for thousands of learner fleets per scheduling tick.

Both return continuous solutions which are then integerized with the same
SAI repair as the analytic path.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.allocation import Allocation, AllocationProblem
from repro.core.solver_batched import BatchedProblems
from repro.core.solver_kkt import suggest_and_improve

__all__ = ["solve_slsqp", "solve_pgd_jax", "pgd_relaxed_batch", "solve_pgd_batched"]


# ---------------------------------------------------------------------------
# scipy SLSQP on the full relaxed program
# ---------------------------------------------------------------------------

def solve_slsqp(prob: AllocationProblem, *, max_iter: int = 300) -> Allocation:
    from scipy.optimize import minimize

    tm = prob.time_model
    k = prob.num_learners
    # init from equal allocation
    d0 = np.full(k, prob.total_samples / k)
    d0 = np.clip(d0, prob.d_lower, prob.d_upper)
    tau0 = np.maximum(tm.tau_of_d(d0, prob.T), 0.0)
    z0 = float(tau0.max() - tau0.min())
    x0 = np.concatenate([tau0, d0, [z0]])

    def split(x):
        return x[:k], x[k : 2 * k], x[-1]

    def objective(x):
        return x[-1]

    def obj_grad(x):
        g = np.zeros_like(x)
        g[-1] = 1.0
        return g

    cons = []

    def time_con(x):
        tau, d, _ = split(x)
        return tm.c2 * tau * d + tm.c1 * d + tm.c0 - prob.T

    cons.append({"type": "eq", "fun": time_con})
    cons.append({"type": "eq", "fun": lambda x: x[k : 2 * k].sum() - prob.total_samples})

    def staleness_con(x):
        tau, _, z = split(x)
        diff = tau[:, None] - tau[None, :]
        iu = np.triu_indices(k, 1)
        pair = diff[iu]
        return np.concatenate([z - pair, z + pair])

    cons.append({"type": "ineq", "fun": staleness_con})

    bounds = (
        [(0.0, None)] * k
        + [(float(prob.d_lower), float(prob.d_upper))] * k
        + [(0.0, None)]
    )
    res = minimize(
        objective,
        x0,
        jac=obj_grad,
        bounds=bounds,
        constraints=cons,
        method="SLSQP",
        options={"maxiter": max_iter, "ftol": 1e-10},
    )
    tau_r, d_r, _ = split(res.x)
    tau, d, it_sai = suggest_and_improve(prob, d_r)
    alloc = Allocation(
        tau=tau,
        d=d,
        method="slsqp_sai",
        relaxed_tau=tau_r,
        relaxed_d=d_r,
        solver_iters=int(res.nit) + it_sai,
    )
    alloc.validate(prob)
    return alloc


# ---------------------------------------------------------------------------
# JAX projected-gradient / penalty solver (batched, jit)
# ---------------------------------------------------------------------------

def _project_sum_box(d, d_lo, d_hi, total, iters: int = 16):
    """Alternating projection onto {sum d = total} intersect [d_lo, d_hi]^K
    (Dykstra-free variant; converges because both sets are closed convex).
    ``d_lo``/``d_hi`` may be scalars or per-learner arrays; padded learner
    slots (d_lo == d_hi == 0) are pinned at zero and never receive mass."""

    def body(d, _):
        gap = total - d.sum()
        free = jnp.where(gap > 0, d < d_hi - 1e-9, d > d_lo + 1e-9).astype(d.dtype)
        w = free / jnp.maximum(free.sum(), 1.0)
        return jnp.clip(d + gap * w, d_lo, d_hi), None

    d, _ = jax.lax.scan(body, d, None, length=iters)
    return d


def _tau_of_d_masked(d, c2, c1, c0, T, valid):
    """tau_k(d_k) with padded / zero-d slots pinned at 0 (NaN-safe grads)."""
    d_safe = jnp.where(valid & (d > 0), d, 1.0)
    tau = jnp.maximum((T - c0 - c1 * d) / (c2 * d_safe), 0.0)
    return jnp.where(valid & (d > 0), tau, 0.0)


def _staleness_loss(d, c2, c1, c0, T, smooth, valid):
    tau = _tau_of_d_masked(d, c2, c1, c0, T, valid)
    masked = jnp.where(valid, tau, -jnp.inf)
    smax = smooth * jax.nn.logsumexp(masked / smooth)
    smin = -smooth * jax.nn.logsumexp(jnp.where(valid, -tau, -jnp.inf) / smooth)
    return smax - smin


@functools.partial(jax.jit, static_argnames=("steps",))
def _pgd_run(d0, c2, c1, c0, T, d_lo, d_hi, total, steps: int, valid=None):
    """Projected gradient descent in d-space with annealed smoothing.

    ``d_lo``/``d_hi`` may be scalars or per-learner (K,) arrays; ``valid``
    is an optional (K,) bool mask — padded slots (d_lo == d_hi == 0,
    valid == False) stay at zero, contribute no gradient and are excluded
    from the smoothed max/min staleness objective, so padded mixed-K
    batches solve exactly like their unpadded counterparts."""
    v = jnp.ones(d0.shape, bool) if valid is None else valid

    def step(d, i):
        frac = i / steps
        smooth = 10.0 ** (0.0 - 2.0 * frac)            # 1.0 -> 0.01
        g = jax.grad(_staleness_loss)(d, c2, c1, c0, T, smooth, v)
        gnorm = jnp.linalg.norm(g) + 1e-12
        lr = 0.05 * (d_hi - d_lo) * (1.0 - 0.9 * frac)
        d = d - lr * g / gnorm
        d = _project_sum_box(d, d_lo, d_hi, total)
        return d, None

    d, _ = jax.lax.scan(step, d0, jnp.arange(steps))
    d = _project_sum_box(d, d_lo, d_hi, total, iters=64)
    tau = _tau_of_d_masked(d, c2, c1, c0, T, v)
    return tau, d


# vmap across a batch of allocation problems (fleet-scale scheduling tick);
# one cached vmapped program per static step count, sharing _pgd_run with
# the single-problem path
@functools.lru_cache(maxsize=None)
def _pgd_batch_fn(steps: int):
    return jax.vmap(
        lambda d0, c2, c1, c0, T, d_lo, d_hi, total, valid: _pgd_run(
            d0, c2, c1, c0, T, d_lo, d_hi, total, steps, valid
        ),
        in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0),
    )


def _energy_cap_tau(tau, d, energy):
    """Cap a relaxed tau row by the budget hyperbola at the final d:
    ``tau <= (eb - e0 - e1 d)/(e2 d)`` (arXiv 2012.00143). Inert where the
    budget never binds (e2 = 0 or eb = inf) — ``min(tau, inf)`` is a
    bitwise no-op — and 0 on zero-d slots like the time path."""
    e2, e1, e0, eb = energy
    den = e2 * d
    tau_e = jnp.where(
        den > 0, (eb - e0 - e1 * d) / jnp.where(den > 0, den, 1.0), jnp.inf
    )
    return jnp.where(d > 0, jnp.maximum(jnp.minimum(tau, tau_e), 0.0), 0.0)


def pgd_relaxed_batch(d0, c2, c1, c0, T, d_lo, d_hi, total, *, steps: int = 600,
                      valid=None, energy=None):
    """Batched relaxed PGD: all args have a leading problem axis B; ``steps``
    is a static compile-time argument. ``valid`` is an optional (B, K) bool
    mask for padded mixed-K batches (defaults to all-valid).

    ``energy`` — optional ``(e2, e1, e0, eb)`` rows of shape (B, K) — adds
    the projection onto the energy-budget box: the d box is tightened by
    the tau = 0 affordability cap (``apply_energy_mask``: unaffordable
    learners degrade to padded slots, the sample budget clips into the
    surviving box), the gradient iterations run on the tightened box, and
    the returned tau is capped by the budget hyperbola at the final d.
    With ``eb = +inf`` every step is a bitwise no-op, so the energy-blind
    call sites are unchanged."""
    if valid is None:
        valid = jnp.ones(jnp.shape(d0), bool)
    if energy is not None:
        from repro.core.solver_batched import apply_energy_mask

        total, d_lo, d_hi, valid = apply_energy_mask(
            total, d_lo, d_hi, valid, energy
        )
        d0 = jnp.clip(d0, d_lo, d_hi)
    tau, d = _pgd_batch_fn(steps)(d0, c2, c1, c0, T, d_lo, d_hi, total, valid)
    if energy is not None:
        tau = _energy_cap_tau(tau, d, energy)
    return tau, d


def solve_pgd_batched(bp: BatchedProblems, *, steps: int = 600):
    """Relaxed PGD over a ``BatchedProblems`` struct — the same (B, K)
    layout the batched KKT engine consumes, including padded mixed-K
    batches: per-learner ``d_lo``/``d_hi`` bound boxes are honored and the
    ``valid`` mask keeps padded slots (d_lo == d_hi == 0) at exactly zero
    work, outside the staleness objective. Structs carrying energy rows
    solve on the affordability-tightened box with budget-capped taus
    (see ``pgd_relaxed_batch``). Returns continuous (tau, d) of shape
    (B, K); padded entries are 0."""
    n_valid = np.maximum(bp.valid.sum(axis=1, keepdims=True), 1)
    d0 = np.where(bp.valid, bp.total[:, None] / n_valid, 0.0)
    d0 = np.clip(d0, bp.d_lo, bp.d_hi).astype(np.float32)
    energy = None
    if bp.has_energy:
        energy = tuple(
            jnp.asarray(r, jnp.float32) for r in bp.energy_rows()
        )
    return pgd_relaxed_batch(
        jnp.asarray(d0),
        jnp.asarray(bp.c2, jnp.float32), jnp.asarray(bp.c1, jnp.float32),
        jnp.asarray(bp.c0, jnp.float32), jnp.asarray(bp.T, jnp.float32),
        jnp.asarray(bp.d_lo, jnp.float32), jnp.asarray(bp.d_hi, jnp.float32),
        jnp.asarray(bp.total, jnp.float32),
        steps=steps, valid=jnp.asarray(bp.valid, bool), energy=energy,
    )


def _solve_pgd_energy(prob: AllocationProblem, *, steps: int) -> Allocation:
    """Energy-budgeted PGD: ``solve_energy``'s affordability prelude and
    energy-capped integer tail around the relaxed PGD stage, so
    ``scheme="pgd"`` composes with ``EnergyModel`` budgets — every
    returned (tau, d) satisfies ``E_k <= e_budget_k`` by construction."""
    from repro.core.solver_kkt import (
        _energy_rows_or_free,
        _integerize_d_vec,
        _sai_energy_np,
    )

    tm = prob.time_model
    k = prob.num_learners
    e2, e1, e0, eb = _energy_rows_or_free(prob)
    energy = (e2, e1, e0, eb)

    # projection onto the energy-budget box: the tau = 0 cap tightens d_hi,
    # unaffordable learners degrade to padded slots (solve_energy step 1)
    lo = np.full(k, float(prob.d_lower))
    hi = np.full(k, float(prob.d_upper))
    room = eb - e0
    with np.errstate(divide="ignore", invalid="ignore"):
        capf = np.where(
            e1 > 0, room / np.where(e1 > 0, e1, 1.0),
            np.where(room >= 0, np.inf, -1.0),
        )
    hi_e = np.clip(np.minimum(np.floor(capf), hi), 0.0, hi)
    affordable = hi_e >= lo
    lo = np.where(affordable, lo, 0.0)
    hi = np.where(affordable, hi_e, 0.0)
    total = int(np.clip(prob.total_samples, lo.sum(), hi.sum()))
    degraded = (not affordable.all()) or total != prob.total_samples

    n_afford = max(int(affordable.sum()), 1)
    d0 = np.where(affordable, total / n_afford, 0.0)
    d0 = np.clip(d0, lo, hi).astype(np.float32)
    tau_r, d_r = _pgd_run(
        jnp.asarray(d0),
        jnp.asarray(tm.c2), jnp.asarray(tm.c1), jnp.asarray(tm.c0),
        float(prob.T),
        jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32),
        float(total), steps, jnp.asarray(affordable),
    )
    tau_r = _energy_cap_tau(
        tau_r, d_r, tuple(jnp.asarray(r, jnp.float32) for r in energy)
    )
    tau_r = np.asarray(tau_r, dtype=float)
    d_r = np.asarray(d_r, dtype=float)

    lo_i = np.round(lo).astype(np.int64)
    hi_i = np.round(hi).astype(np.int64)
    d_int = _integerize_d_vec(d_r, total, lo_i, hi_i)
    tau, d, it_sai = _sai_energy_np(
        d_int, tm.c2, tm.c1, tm.c0, prob.T, lo_i, hi_i, affordable, energy,
        10_000,
    )
    alloc = Allocation(
        tau=tau,
        d=d,
        method="pgd_energy_sai",
        relaxed_tau=tau_r,
        relaxed_d=d_r,
        solver_iters=steps + it_sai,
    )
    if not degraded:
        alloc.validate(prob)
    return alloc


def solve_pgd_jax(prob: AllocationProblem, *, steps: int = 600) -> Allocation:
    if prob.energy is not None:
        return _solve_pgd_energy(prob, steps=steps)
    tm = prob.time_model
    k = prob.num_learners
    d0 = jnp.full(k, prob.total_samples / k, dtype=jnp.float32)
    d0 = jnp.clip(d0, prob.d_lower, prob.d_upper)
    tau_r, d_r = _pgd_run(
        d0,
        jnp.asarray(tm.c2),
        jnp.asarray(tm.c1),
        jnp.asarray(tm.c0),
        float(prob.T),
        float(prob.d_lower),
        float(prob.d_upper),
        float(prob.total_samples),
        steps,
    )
    tau_r = np.asarray(tau_r, dtype=float)
    d_r = np.asarray(d_r, dtype=float)
    tau, d, it_sai = suggest_and_improve(prob, d_r)
    alloc = Allocation(
        tau=tau,
        d=d,
        method="pgd_jax_sai",
        relaxed_tau=tau_r,
        relaxed_d=d_r,
        solver_iters=steps + it_sai,
    )
    alloc.validate(prob)
    return alloc
