"""Model aggregation rules for asynchronous MEL (paper Sec. II + ref [10]).

The orchestrator receives K locally-updated models {w_k}, each trained for
tau_k epochs on d_k samples, and produces the next global model.

* ``fedavg_weights``   — classic data-weighted averaging (alpha_k = d_k / d).
* ``staleness_weights``— staleness-aware async-SGD (ref [10]): learners whose
  tau_k lags the fleet maximum contribute *fresher* gradients less stale, so
  each is weighted by d_k / (1 + s_k) where s_k = tau_max - tau_k, then
  renormalized. With zero staleness this reduces to FedAvg exactly.
* ``aggregate``        — jit-compiled weighted pytree sum (the fused Pallas
  kernel in repro.kernels.fed_agg implements the same contraction for the
  TPU hot path; this is the jnp composition).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["fedavg_weights", "staleness_weights", "aggregate", "aggregate_stacked"]


def fedavg_weights(d: np.ndarray) -> np.ndarray:
    d = np.asarray(d, dtype=float)
    return d / d.sum()


def staleness_weights(tau: np.ndarray, d: np.ndarray, *, gamma: float = 1.0) -> np.ndarray:
    """alpha_k ∝ d_k / (1 + gamma * (tau_max - tau_k)); renormalized."""
    tau = np.asarray(tau, dtype=float)
    d = np.asarray(d, dtype=float)
    s = tau.max() - tau
    w = d / (1.0 + gamma * s)
    return w / w.sum()


@jax.jit
def aggregate(models, weights):
    """Weighted sum of a list-of-pytrees along the learner axis.

    ``models`` is a pytree whose leaves have a leading learner axis K
    (stacked local models); ``weights`` is shape (K,)."""
    weights = jnp.asarray(weights)

    def wsum(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return (leaf * w).sum(axis=0)

    return jax.tree_util.tree_map(wsum, models)


# alias that documents the stacked-leading-axis contract
aggregate_stacked = aggregate
