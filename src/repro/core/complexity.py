"""Analytic per-architecture complexity accounting.

The allocator needs exactly two numbers per architecture (paper Sec. II):

* ``C_m``  — clocks (~FLOPs) for one local update over ONE data sample
             (fwd + bwd  ≈ 3x fwd  ≈ 6 * N_active * tokens_per_sample),
* ``S_m``  — serialized model size in bits (ALL parameters: MoE learners
             must ship every expert even though only top-k are active).

For the paper's own MNIST DNN [784, 300, 124, 60, 10] the exact numbers
from the text are reproduced: 8,974,080 bits of parameters and
1,123,736 FLOPs per fwd+bwd pass.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelCost", "mlp_cost", "mnist_dnn_cost", "transformer_cost"]


@dataclasses.dataclass(frozen=True)
class ModelCost:
    params_total: int          # all parameters
    params_active: int         # activated per token (MoE: shared + top-k)
    flops_per_sample: float    # C_m: fwd+bwd FLOPs for one training sample
    model_bits: float          # S_m * P_m

    @staticmethod
    def from_params(
        params_total: int,
        params_active: int,
        *,
        tokens_per_sample: int = 1,
        precision_bits: int = 32,
        train: bool = True,
    ) -> "ModelCost":
        mult = 6.0 if train else 2.0   # fwd+bwd vs fwd-only FLOPs per param
        return ModelCost(
            params_total=params_total,
            params_active=params_active,
            flops_per_sample=mult * params_active * tokens_per_sample,
            model_bits=float(params_total) * precision_bits,
        )


def mlp_cost(layers: list[int], *, precision_bits: int = 32) -> ModelCost:
    """Fully-connected net with the paper's exact accounting (Sec. V-A):

    * S_m counts WEIGHT matrices only — [784,300,124,60,10] gives
      280,440 weights -> 8,974,080 bits at 32-bit precision (paper's number);
    * C_m = 4 FLOPs per parameter (weights + biases) per sample for the
      fwd+bwd pass — 4 * 280,934 = 1,123,736 FLOPs (paper's number).
    """
    weights = 0
    params = 0
    for fan_in, fan_out in zip(layers[:-1], layers[1:]):
        weights += fan_in * fan_out
        params += fan_in * fan_out + fan_out
    flops = 4 * params
    return ModelCost(
        params_total=params,
        params_active=params,
        flops_per_sample=float(flops),
        model_bits=float(weights) * precision_bits,
    )


def mnist_dnn_cost() -> ModelCost:
    """The paper's network: [784, 300, 124, 60, 10] @ 32-bit params.
    Reproduces the paper's exact constants: model_bits == 8,974,080 and
    flops_per_sample == 1,123,736."""
    return mlp_cost([784, 300, 124, 60, 10], precision_bits=32)


def transformer_cost(
    *,
    params_total: int,
    params_active: int,
    seq_len: int,
    precision_bits: int = 16,
) -> ModelCost:
    """A transformer 'sample' for allocation purposes is one sequence."""
    return ModelCost.from_params(
        params_total,
        params_active,
        tokens_per_sample=seq_len,
        precision_bits=precision_bits,
        train=True,
    )
