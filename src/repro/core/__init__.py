"""Core contribution of the paper: staleness-aware task allocation for
asynchronous federated mobile-edge learning."""

from repro.core.allocation import Allocation, AllocationProblem
from repro.core.aggregation import aggregate, fedavg_weights, staleness_weights
from repro.core.baselines import solve_eta, solve_synchronous
from repro.core.complexity import ModelCost, mlp_cost, mnist_dnn_cost, transformer_cost
from repro.core.solver_batched import (
    TRACED_POLICIES,
    BatchedAllocation,
    BatchedProblems,
    batched_avg_staleness,
    batched_max_staleness,
    batched_policy,
    batched_summary,
    solve_eta_batched,
    solve_kkt_batched,
)
from repro.core.solver_kkt import solve as solve_kkt_sai
from repro.core.solver_kkt import solve_relaxed, suggest_and_improve
from repro.core.solver_numeric import solve_pgd_batched, solve_pgd_jax, solve_slsqp
from repro.core.staleness import (
    STALENESS_FNS,
    avg_staleness,
    max_staleness,
    staleness_factor,
    version_staleness,
    version_staleness_profile,
)
from repro.core.time_model import (
    CapacityDrift,
    ChannelParams,
    LearnerProfile,
    TimeModel,
    indoor_80211_profile,
    pod_slice_profile,
)

__all__ = [
    "Allocation",
    "AllocationProblem",
    "TRACED_POLICIES",
    "BatchedAllocation",
    "BatchedProblems",
    "batched_avg_staleness",
    "batched_max_staleness",
    "batched_policy",
    "batched_summary",
    "solve_eta_batched",
    "solve_kkt_batched",
    "CapacityDrift",
    "ChannelParams",
    "LearnerProfile",
    "ModelCost",
    "TimeModel",
    "aggregate",
    "avg_staleness",
    "fedavg_weights",
    "indoor_80211_profile",
    "max_staleness",
    "mlp_cost",
    "mnist_dnn_cost",
    "pod_slice_profile",
    "solve_eta",
    "solve_kkt_sai",
    "solve_pgd_batched",
    "solve_pgd_jax",
    "solve_relaxed",
    "solve_slsqp",
    "solve_synchronous",
    "STALENESS_FNS",
    "staleness_factor",
    "staleness_weights",
    "version_staleness",
    "version_staleness_profile",
    "suggest_and_improve",
    "transformer_cost",
]
