"""Core contribution of the paper: staleness-aware task allocation for
asynchronous federated mobile-edge learning.

Public surface (see ``docs/architecture.md`` for the layer map and
``docs/allocation.md`` for the paper-notation-to-code mapping):

* **Problem & time model** — ``TimeModel`` (Eq. 5 coefficients C2/C1/C0
  per learner), ``AllocationProblem`` (fleet + budget T + sample total and
  box bounds), ``ChannelParams``/``LearnerProfile`` and the
  ``indoor_80211_profile``/``pod_slice_profile`` reference environments,
  ``ModelCost`` constants (``mnist_dnn_cost`` etc.).
* **Per-problem solvers** — ``solve_kkt_sai`` (the paper's KKT
  water-filling + suggest-and-improve), ``solve_relaxed`` /
  ``suggest_and_improve`` (its stages), ``solve_slsqp`` / ``solve_pgd_jax``
  (numeric baselines), ``solve_eta`` / ``solve_synchronous`` (baselines).
* **Batched engine** — ``BatchedProblems`` / ``BatchedAllocation`` (the
  (B, K) fleet-batch layout), ``solve_kkt_batched`` / ``solve_eta_batched``
  / ``solve_pgd_batched`` (one XLA program for B fleets),
  ``batched_policy`` + ``TRACED_POLICIES`` (traced in-scan re-solve hooks),
  ``batched_max_staleness`` / ``batched_avg_staleness`` /
  ``batched_summary`` (vectorized metrics).
* **Staleness** — ``max_staleness`` / ``avg_staleness`` (the paper's
  update staleness, Eqs. 6/13), ``version_staleness`` /
  ``staleness_factor`` / ``version_staleness_profile`` + ``STALENESS_FNS``
  (FedAsync version staleness and its discounts).
* **Aggregation** — ``aggregate`` (weighted model mean),
  ``staleness_weights`` / ``fedavg_weights``.
* **Capacity dynamics** — ``CapacityDrift`` (exogenous per-cycle
  fading/jitter), ``QueueDrift`` (state-coupled backlog dynamics driven by
  the dispatched allocations), ``is_state_coupled`` (protocol probe).
* **Availability** — ``MarkovAvailability`` / ``ActiveRateAvailability`` /
  ``TraceAvailability`` (per-learner churn processes behind the drift
  protocol, composable with a base capacity drift), ``availability_masks``,
  ``has_availability`` / ``capacity_state_coupled`` (protocol probes),
  ``apply_active_mask`` (offline-slot masking for the batched solve).
* **Energy** — ``EnergyModel`` (per-cycle joule coefficients e2/e1/e0,
  arXiv 2012.00143), ``solve_kkt_energy`` / ``solve_energy_batched``
  (the budgeted pipeline, also traced as ``batched_policy("kkt_energy")``),
  ``apply_energy_mask`` (affordability masking), ``BatteryDrift``
  (battery-drain availability: dispatched work drains, recharge refills,
  empty = offline).
"""

from repro.core.allocation import Allocation, AllocationProblem
from repro.core.availability import (
    ActiveRateAvailability,
    MarkovAvailability,
    TraceAvailability,
    availability_masks,
    capacity_state_coupled,
    has_availability,
)
from repro.core.aggregation import aggregate, fedavg_weights, staleness_weights
from repro.core.baselines import solve_eta, solve_synchronous
from repro.core.complexity import ModelCost, mlp_cost, mnist_dnn_cost, transformer_cost
from repro.core.energy import BatteryDrift, EnergyModel
from repro.core.solver_batched import (
    TRACED_POLICIES,
    BatchedAllocation,
    BatchedProblems,
    apply_active_mask,
    apply_energy_mask,
    apply_sampling_mask,
    batched_avg_staleness,
    batched_max_staleness,
    batched_policy,
    batched_summary,
    solve_energy_batched,
    solve_eta_batched,
    solve_kkt_batched,
)
from repro.core.solver_kkt import solve as solve_kkt_sai
from repro.core.solver_kkt import solve_energy as solve_kkt_energy
from repro.core.solver_kkt import solve_relaxed, suggest_and_improve
from repro.core.solver_numeric import solve_pgd_batched, solve_pgd_jax, solve_slsqp
from repro.core.staleness import (
    STALENESS_FNS,
    avg_staleness,
    max_staleness,
    staleness_factor,
    version_staleness,
    version_staleness_profile,
)
from repro.core.time_model import (
    CapacityDrift,
    ChannelParams,
    LearnerProfile,
    QueueDrift,
    TimeModel,
    indoor_80211_profile,
    is_state_coupled,
    pod_slice_profile,
)

__all__ = [
    "ActiveRateAvailability",
    "Allocation",
    "AllocationProblem",
    "MarkovAvailability",
    "TraceAvailability",
    "apply_active_mask",
    "apply_sampling_mask",
    "availability_masks",
    "capacity_state_coupled",
    "has_availability",
    "TRACED_POLICIES",
    "BatchedAllocation",
    "BatteryDrift",
    "EnergyModel",
    "BatchedProblems",
    "batched_avg_staleness",
    "batched_max_staleness",
    "batched_policy",
    "batched_summary",
    "solve_eta_batched",
    "solve_kkt_batched",
    "solve_kkt_energy",
    "solve_energy_batched",
    "apply_energy_mask",
    "CapacityDrift",
    "ChannelParams",
    "LearnerProfile",
    "ModelCost",
    "QueueDrift",
    "TimeModel",
    "is_state_coupled",
    "aggregate",
    "avg_staleness",
    "fedavg_weights",
    "indoor_80211_profile",
    "max_staleness",
    "mlp_cost",
    "mnist_dnn_cost",
    "pod_slice_profile",
    "solve_eta",
    "solve_kkt_sai",
    "solve_pgd_batched",
    "solve_pgd_jax",
    "solve_relaxed",
    "solve_slsqp",
    "solve_synchronous",
    "STALENESS_FNS",
    "staleness_factor",
    "staleness_weights",
    "version_staleness",
    "version_staleness_profile",
    "suggest_and_improve",
    "transformer_cost",
]
