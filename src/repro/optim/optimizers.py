"""Optimizers in pure JAX (pytree-functional, optax-free).

State pytrees mirror the param tree, so whatever sharding the params get,
the optimizer state inherits — with FSDP ('embed' -> data) rules this is
ZeRO-style sharded optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adamw", "get_optimizer", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable          # params -> state
    update: Callable        # (grads, state, params) -> (updates, state)

    def apply(self, grads, state, params):
        updates, state = self.update(grads, state, params)
        new_params = jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        return new_params, state


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def sgd(lr: float) -> Optimizer:
    return Optimizer(
        init=lambda params: (),
        update=lambda g, s, p: (jax.tree_util.tree_map(lambda x: -lr * x, g), s),
    )


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(g, m, p):
        m = jax.tree_util.tree_map(lambda mi, gi: beta * mi + gi.astype(jnp.float32), m, g)
        return jax.tree_util.tree_map(lambda mi: -lr * mi, m), m

    return Optimizer(init=init, update=update)


def _adam_core(lr, b1, b2, eps, wd):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(g, state, params):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda mi, gi: b1 * mi + (1 - b1) * gi.astype(jnp.float32), state["m"], g
        )
        v = jax.tree_util.tree_map(
            lambda vi, gi: b2 * vi + (1 - b2) * jnp.square(gi.astype(jnp.float32)),
            state["v"], g,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(mi, vi, pi):
            step = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if wd:
                step = step + wd * pi.astype(jnp.float32)
            return (-lr * step).astype(pi.dtype)

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init=init, update=update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, 0.0)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, wd: float = 0.01) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, wd)


def get_optimizer(name: str, lr: float) -> Optimizer:
    return {
        "sgd": lambda: sgd(lr),
        "momentum": lambda: momentum(lr),
        "adam": lambda: adam(lr),
        "adamw": lambda: adamw(lr),
    }[name]()
