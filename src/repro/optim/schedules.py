"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "warmup_cosine", "warmup_linear_decay"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(lr: float, *, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    """Linear warmup to ``lr`` then cosine decay to ``final_frac * lr``."""

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return f


def warmup_linear_decay(lr: float, *, warmup_steps: int, total_steps: int):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, lr * (1.0 - t))

    return f
