"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

TPU adaptation of the CUDA wkv6 kernel: grid = (batch, head, time-chunks)
with the time dimension innermost/"arbitrary"; the (hd x hd) state matrix
lives in VMEM scratch across chunk iterations (never spilled to HBM, the
whole point of the fused kernel — the jnp `lax.scan` reference round-trips
the state through HBM every step). Inside a chunk the recurrence is a
`fori_loop` of rank-1 updates: per step
    y_t = r_t (S + diag(u) k_t v_t^T)
    S  <- diag(w_t) S + k_t v_t^T

r/k/v/w chunks are (block_t, hd) VMEM tiles; u is (1, hd); the final state
is written once at the last chunk (grid revisiting guarantees ordering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["wkv6_pallas"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_out_ref, state,
            *, block_t: int, nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0, 0].astype(jnp.float32)                    # (hd,)

    def step(t, _):
        r = r_ref[0, t, 0, :].astype(jnp.float32)          # (hd,)
        k = k_ref[0, t, 0, :].astype(jnp.float32)
        v = v_ref[0, t, 0, :].astype(jnp.float32)
        w = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = k[:, None] * v[None, :]                       # (hd, hd)
        y = ((state[...] + u[:, None] * kv) * r[:, None]).sum(axis=0)
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        state[...] = w[:, None] * state[...] + kv
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(ti == nt - 1)
    def _flush():
        s_out_ref[0, 0] = state[...].astype(s_out_ref.dtype)


def wkv6_pallas(r, k, v, w, u, s0=None, *, block_t: int = 64, interpret: bool = False):
    """r,k,v,w: (B,S,H,hd); u: (H,hd); s0: (B,H,hd,hd) f32 or None.
    Returns (y (B,S,H,hd) f32, s_last (B,H,hd,hd) f32) — matching
    ``repro.models.rwkv6.wkv_scan``."""
    b, s, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    block_t = min(block_t, s)
    while s % block_t:
        block_t -= 1
    nt = s // block_t

    kernel = functools.partial(_kernel, block_t=block_t, nt=nt)
    seq_spec = pl.BlockSpec((1, block_t, 1, hd), lambda bi, hi, ti: (bi, ti, hi, 0))
    y, s_last = pl.pallas_call(
        kernel,
        grid=(b, h, nt),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, 1, hd), lambda bi, hi, ti: (0, hi, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u[None], s0)
    return y, s_last
