"""Pure-jnp oracles for every Pallas kernel (the contract the kernel
tests `assert_allclose` against)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "flash_attention_ref",
    "wkv6_ref",
    "fed_agg_ref",
    "swiglu_ref",
    "mamba_scan_ref",
    "waterfill_residual_ref",
    "waterfill_energy_residual_ref",
    "train_agg_step_ref",
]


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """O(S^2) dense attention with explicit masking (NOT the chunked scan —
    an independent formulation so the two implementations cross-check)."""
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k.astype(jnp.float32)) / jnp.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def wkv6_ref(r, k, v, w, u, s0=None):
    from repro.models.rwkv6 import wkv_scan

    return wkv_scan(r, k, v, w, u, s0=s0)


def fed_agg_ref(stacked, weights):
    w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1)).astype(jnp.float32)
    return (stacked.astype(jnp.float32) * w).sum(axis=0).astype(stacked.dtype)


def swiglu_ref(x, w_gate, w_up, w_down):
    from repro.models.layers import swiglu

    return swiglu(x, w_gate, w_up, w_down)


def waterfill_residual_ref(tau_star, c2, c1, c0, T, d_lo, d_hi, total):
    """Batched KKT water-filling residual (core.solver_batched layout):
    tau_star/T/total: (B,); c2/c1/c0/d_lo/d_hi: (B, K). Returns (B,)."""
    d = (T[:, None] - c0) / (c2 * tau_star[:, None] + c1)
    return jnp.clip(d, d_lo, d_hi).sum(axis=-1) - total


def waterfill_energy_residual_ref(tau_star, c2, c1, c0, T, e2, e1, e0, eb,
                                  d_lo, d_hi, total):
    """Energy-budgeted water-filling residual (arXiv 2012.00143): each
    learner absorbs the tightest of the deadline hyperbola
    ``(T - c0)/(c2 tau* + c1)`` and the budget hyperbola
    ``(eb - e0)/(e2 tau* + e1)``. The time branch repeats
    ``waterfill_residual_ref`` op-for-op, and ``min(d_time, inf)`` selects
    it bitwise under IEEE inf arithmetic, so ``eb = +inf`` rows degenerate
    to the unbudgeted residual exactly. tau_star/T/total: (B,); the six
    coefficient rows and the bounds: (B, K). Returns (B,)."""
    dt = (T[:, None] - c0) / (c2 * tau_star[:, None] + c1)
    de = (eb - e0) / (e2 * tau_star[:, None] + e1)
    return jnp.clip(jnp.minimum(dt, de), d_lo, d_hi).sum(axis=-1) - total


def train_agg_step_ref(disp, x, y, m, tau, weights, lr, *, loss_fn, max_tau,
                       server=None, acc=None, keep=None, flush=None):
    """Unfused train+aggregate composition — literally
    ``local_train_stacked`` followed by the ``fed_agg_ref`` contractions,
    so the megakernel's bitwise contract is pinned against the exact ops
    the scan bodies run today. ``acc=None`` selects the cycle form (plain
    weighted aggregation of the trained locals); otherwise the async
    accumulate/flush form ``server' = keep*server + flush*(acc + sum_k
    w_k local_k)``, ``acc' = (1-flush)*(acc + sum_k w_k local_k)``.
    Returns ``(new_server, new_acc)`` with ``new_acc=None`` in cycle form.
    """
    from repro.fed.orchestrator import local_train_stacked

    locals_ = local_train_stacked(disp, x, y, m, tau, lr,
                                  max_tau=max_tau, loss_fn=loss_fn)
    w = jnp.asarray(weights, jnp.float32)
    if acc is None:
        new = jax.tree_util.tree_map(lambda l: fed_agg_ref(l, w), locals_)
        return new, None
    one = jnp.ones((1,), jnp.float32)
    w_acc = jnp.concatenate([one, w])
    acc1 = jax.tree_util.tree_map(
        lambda a, l: fed_agg_ref(jnp.concatenate([a[None], l], axis=0), w_acc),
        acc, locals_,
    )
    keep = jnp.asarray(keep, jnp.float32)
    flush = jnp.asarray(flush, jnp.float32)
    w_flush = jnp.stack([keep, flush])
    server1 = jax.tree_util.tree_map(
        lambda s, a: fed_agg_ref(jnp.stack([s, a]), w_flush), server, acc1
    )
    acc2 = jax.tree_util.tree_map(lambda a: (1.0 - flush) * a, acc1)
    return server1, acc2


def mamba_scan_ref(dt, x, b, c, a, h0=None):
    """Sequential S6 scan. dt,x: (B,S,D); b,c: (B,S,N); a: (D,N); h0: (B,D,N)."""
    bsz, s, d = dt.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        da = jnp.exp(dt_t[:, :, None] * a[None])
        h = h * da + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = tuple(t.transpose(1, 0, 2) for t in (dt, x, b, c))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), h_last
