"""Pallas TPU megakernel: fused local-GD + weighted accumulate + flush.

Every scan fast path (``Orchestrator.run_fused``, the reallocating scan,
the async engine's jagged ``run_events``, the fleet engine's vmapped
per-fleet round) spends its step on the same composition:

  1. masked local GD — each of K learners runs ``tau_k`` gradient steps
     from its OWN start params on its masked shard
     (``fed.orchestrator.local_train_stacked``);
  2. a weighted accumulate of the trained locals
     (``acc' = acc + sum_k w_k * local_k``);
  3. the masked ``ops.fed_agg`` flush contraction into the server
     (``server' = keep * server + f * acc'``).

Unfused, that launches one XLA op per GD step per leaf plus the
aggregation contractions. This kernel runs the WHOLE composition as one
Pallas program: every model leaf stays VMEM-resident across the in-kernel
``fori_loop`` over the traced fleet-max tau (per-step masked with
``i < tau_k``, the data mask applied inside the loss contraction), and the
accumulate + flush read the trained locals without ever leaving the core.

Numerics contract (pinned by ``tests/test_kernel_parity.py``): in
interpret mode the kernel is **bitwise** equal to the unfused
``local_train_stacked`` + accumulate + ``fed_agg`` composition
(``kernels.ref.train_agg_step_ref``) on f32 operands — the in-kernel
``fori_loop`` + ``where`` masking computes the same per-step select as
``local_train_stacked``'s vmapped ``lax.cond``, and the contractions
repeat ``fed_agg_ref`` op-for-op.

Fusion boundary: the whole per-step working set — K stacked copies of the
model, the (K, d_cap, F) shard block, and the grad workspace — must fit
VMEM (~16 MB/core), which holds for the paper's MLP family at fleet sizes
K <= 10 but NOT for large models or very wide shard blocks; those stay on
the unfused path (the default everywhere). The loss_fn is traced into the
kernel body, so on real TPU it must stick to Mosaic-supported primitives;
interpret mode (the CI path) runs any jax loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["train_agg_step_pallas"]


def _fed_agg_body(stacked, weights):
    """``kernels.ref.fed_agg_ref`` repeated op-for-op inside the kernel
    (inlined to keep this module import-light)."""
    w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1)).astype(jnp.float32)
    return (stacked.astype(jnp.float32) * w).sum(axis=0).astype(stacked.dtype)


def _make_kernel(treedef, n_leaves: int, loss_fn, with_acc: bool):
    """Kernel body over flattened model leaves. Ref layout:
    ``(x, y, m, tau, w, scal, *disp[, *server, *acc], *outs)`` where
    ``outs`` is ``server' + acc'`` leaves (with_acc) or the aggregated
    model leaves (cycle form)."""
    L = n_leaves

    def kernel(x_ref, y_ref, m_ref, tau_ref, w_ref, scal_ref, *refs):
        x = x_ref[...]
        y = y_ref[...]
        m = m_ref[...]
        tau = tau_ref[0, :]
        w = w_ref[0, :]
        lr = scal_ref[0, 0]
        disp = [refs[i][...] for i in range(L)]

        def gd(i, leaves):
            p = jax.tree_util.tree_unflatten(treedef, leaves)
            g = jax.vmap(
                lambda pk, xk, yk, mk: jax.grad(loss_fn)(
                    pk, {"x": xk, "y": yk, "mask": mk}
                )
            )(p, x, y, m)
            # the same per-step select as local_train_stacked's vmapped
            # lax.cond: steps at i >= tau_k leave the params untouched
            new = jax.tree_util.tree_map(
                lambda pk, gk: jnp.where(
                    (i < tau).reshape((-1,) + (1,) * (pk.ndim - 1)),
                    pk - lr * gk, pk,
                ),
                p, g,
            )
            return jax.tree_util.tree_leaves(new)

        locals_ = jax.lax.fori_loop(0, jnp.max(tau), gd, disp)

        if not with_acc:
            outs = refs[L:]
            for i in range(L):
                outs[i][...] = _fed_agg_body(locals_[i], w)
            return

        keep = scal_ref[0, 1]
        flush = scal_ref[0, 2]
        server = [refs[L + i][...] for i in range(L)]
        acc = [refs[2 * L + i][...] for i in range(L)]
        out_server = refs[3 * L: 4 * L]
        out_acc = refs[4 * L: 5 * L]
        one = jnp.ones((1,), jnp.float32)
        w_acc = jnp.concatenate([one, w])
        w_flush = jnp.stack([keep, flush])
        for i in range(L):
            acc1 = _fed_agg_body(
                jnp.concatenate([acc[i][None], locals_[i]], axis=0), w_acc
            )
            out_server[i][...] = _fed_agg_body(
                jnp.stack([server[i], acc1]), w_flush
            )
            out_acc[i][...] = (1.0 - flush) * acc1

    return kernel


def train_agg_step_pallas(disp, x, y, m, tau, weights, lr, *, loss_fn,
                          server=None, acc=None, keep=None, flush=None,
                          interpret: bool = False):
    """One fused train+aggregate step (see module docstring).

    disp : model pytree with a leading K learner axis on every leaf
    x : (K, d_cap, F); y, m : (K, d_cap); tau, weights : (K,)
    server, acc : model pytrees (no K axis) — the async accumulate/flush
        form; ``None`` selects the cycle form (plain weighted aggregation
        of the trained locals, ``keep``/``flush`` unused)
    keep, flush : f32 scalars — the flush contraction coefficients

    Returns ``(new_server, new_acc)``; ``new_acc`` is None in cycle form.
    """
    with_acc = acc is not None
    if with_acc and (server is None or keep is None or flush is None):
        raise ValueError("the accumulate/flush form needs server, keep "
                         "and flush alongside acc")
    if not with_acc and (server is not None or keep is not None
                        or flush is not None):
        raise ValueError("server/keep/flush have no meaning without acc "
                         "(cycle form aggregates the locals directly)")

    d_leaves, treedef = jax.tree_util.tree_flatten(disp)
    L = len(d_leaves)
    k = x.shape[0]
    tau2 = jnp.asarray(tau, jnp.int32).reshape(1, k)
    w2 = jnp.asarray(weights, jnp.float32).reshape(1, k)
    lr_f = jnp.asarray(lr, jnp.float32)
    zero = jnp.zeros((), jnp.float32)
    scal = jnp.stack([
        lr_f,
        jnp.asarray(keep, jnp.float32) if with_acc else zero,
        jnp.asarray(flush, jnp.float32) if with_acc else zero,
    ]).reshape(1, 3)

    vmem = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM)
    smem = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM)
    operands = [x, y, m]
    if with_acc:
        s_leaves = jax.tree_util.tree_leaves(server)
        a_leaves = jax.tree_util.tree_leaves(acc)
        operands += d_leaves + s_leaves + a_leaves
        out_shape = [jax.ShapeDtypeStruct(l.shape, l.dtype)
                     for l in s_leaves] * 2
    else:
        operands += d_leaves
        out_shape = [jax.ShapeDtypeStruct(l.shape[1:], l.dtype)
                     for l in d_leaves]

    kernel = _make_kernel(treedef, L, loss_fn, with_acc)
    outs = pl.pallas_call(
        kernel,
        in_specs=[vmem, vmem, vmem, smem, smem, smem]
        + [vmem] * (len(operands) - 3),
        out_specs=[vmem] * len(out_shape),
        out_shape=out_shape,
        interpret=interpret,
    )(operands[0], operands[1], operands[2], tau2, w2, scal, *operands[3:])

    if with_acc:
        new_server = jax.tree_util.tree_unflatten(treedef, outs[:L])
        new_acc = jax.tree_util.tree_unflatten(treedef, outs[L:])
        return new_server, new_acc
    return jax.tree_util.tree_unflatten(treedef, outs), None
