"""Pallas TPU kernel for the Mamba (S6) selective scan.

Mamba-1's decay is input-dependent PER (channel, state) pair
(da_t = exp(dt_t * A)), which resists the chunked-matmul reformulation
that works for RWKV-6 (see ``repro.models.rwkv6.wkv_chunked`` — there the
intra-chunk exponents contract over the channel axis). The TPU answer is
the same as the CUDA kernel's: keep the (block_d, N) state resident in
fast memory (VMEM here, SRAM there) and stream the time axis.

Grid = (batch, d_inner blocks, time chunks), time innermost/"arbitrary";
the state scratch persists across time chunks, so HBM traffic is exactly
inputs + outputs — the jnp ``lax.scan`` reference round-trips the
(B, d_inner, N) state every step, which is why jamba training is
memory-bound at ~139 s/step (EXPERIMENTS §Roofline).

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t
    y_t = h_t . C_t
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["mamba_scan_pallas"]


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, h_out_ref, state,
            *, block_t: int, nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                     # (bd, N)

    def step(t, _):
        dt = dt_ref[0, t, :].astype(jnp.float32)           # (bd,)
        x = x_ref[0, t, :].astype(jnp.float32)             # (bd,)
        b = b_ref[0, t, :].astype(jnp.float32)             # (N,)
        c = c_ref[0, t, :].astype(jnp.float32)             # (N,)
        da = jnp.exp(dt[:, None] * a)                      # (bd, N)
        state[...] = state[...] * da + (dt * x)[:, None] * b[None, :]
        y_ref[0, t, :] = (state[...] * c[None, :]).sum(axis=1).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(ti == nt - 1)
    def _flush():
        h_out_ref[0] = state[...].astype(h_out_ref.dtype)


def mamba_scan_pallas(dt, x, b, c, a, h0=None, *, block_d: int = 512,
                      block_t: int = 64, interpret: bool = False):
    """dt, x: (B, S, D); b, c: (B, S, N); a: (D, N) (negative);
    h0: (B, D, N) f32 or None. Returns (y (B,S,D) f32, h_last (B,D,N) f32)
    — matching the scan inside ``repro.models.mamba.apply``."""
    bsz, s, d = dt.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)
    block_d = min(block_d, d)
    while d % block_d:
        block_d -= 1
    block_t = min(block_t, s)
    while s % block_t:
        block_t -= 1
    nd, nt = d // block_d, s // block_t

    kernel = functools.partial(_kernel, block_t=block_t, nt=nt)
    chan_spec = pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di))
    state_spec = pl.BlockSpec((1, block_t, n), lambda bi, di, ti: (bi, ti, 0))
    y, h_last = pl.pallas_call(
        kernel,
        grid=(bsz, nd, nt),
        in_specs=[
            chan_spec, chan_spec, state_spec, state_spec,
            pl.BlockSpec((block_d, n), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((1, block_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_specs=[
            chan_spec,
            pl.BlockSpec((1, block_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dt, x, b, c, a, h0)
    return y, h_last
