# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

__all__ = ["tpu_compiler_params"]


def tpu_compiler_params(**kwargs):
    """CompilerParams across jax versions: renamed TPUCompilerParams ->
    CompilerParams upstream; resolve whichever this jax ships. Imported
    lazily so the pure-jnp reference paths never touch Pallas."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
