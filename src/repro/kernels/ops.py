"""Jit'd dispatch wrappers for the compute hot spots.

Every op has two backends:
  * pure-jnp reference (``repro.kernels.ref`` / ``repro.models.layers``) —
    the default on CPU and the oracle the Pallas kernels are tested against;
  * a Pallas TPU kernel (``use_pallas=True``) with explicit BlockSpec VMEM
    tiling — the deployment path on real hardware. On CPU the kernels run
    in ``interpret=True`` mode (tests) only.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "flash_attention",
    "wkv6",
    "fed_agg",
    "swiglu_fused",
    "mamba_scan",
    "waterfill_residual",
    "waterfill_energy_residual",
    "train_agg_step",
]


def flash_attention(q, k, v, *, causal=True, window=None, chunk=512,
                    use_pallas=False, interpret=False, p_bf16=False, q_block=0):
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, interpret=interpret
        )
    from repro.models.layers import flash_attention as ref

    return ref(q, k, v, causal=causal, window=window, chunk=chunk,
               p_bf16=p_bf16, q_block=q_block)


def wkv6(r, k, v, w, u, s0=None, *, use_pallas=False, interpret=False, unroll=1,
         backend="scan", chunk=16):
    if use_pallas:
        from repro.kernels.wkv6 import wkv6_pallas

        return wkv6_pallas(r, k, v, w, u, s0=s0, interpret=interpret)
    if backend == "chunked":
        from repro.models.rwkv6 import wkv_chunked

        return wkv_chunked(r, k, v, w, u, s0=s0, chunk=chunk)
    from repro.models.rwkv6 import wkv_scan

    return wkv_scan(r, k, v, w, u, s0=s0, unroll=unroll)


def fed_agg(stacked, weights, *, use_pallas=False, interpret=False):
    """Weighted sum over the leading learner axis of a stacked tensor."""
    if use_pallas:
        from repro.kernels.fed_agg import fed_agg_pallas

        return fed_agg_pallas(stacked, weights, interpret=interpret)
    w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1)).astype(jnp.float32)
    return (stacked.astype(jnp.float32) * w).sum(axis=0).astype(stacked.dtype)


def waterfill_residual(tau_star, c2, c1, c0, T, d_lo, d_hi, total, *,
                       use_pallas=False, interpret=False):
    """Batched water-filling residual sum_k clip((T-c0)/(c2*tau+c1), lo, hi)
    - total for a (B, K) fleet batch — the inner evaluation of every
    bisection step in ``core.solver_batched``."""
    if use_pallas:
        from repro.kernels.waterfill import waterfill_residual_pallas

        return waterfill_residual_pallas(
            tau_star, c2, c1, c0, T, d_lo, d_hi, total, interpret=interpret
        )
    from repro.kernels.ref import waterfill_residual_ref

    return waterfill_residual_ref(tau_star, c2, c1, c0, T, d_lo, d_hi, total)


def waterfill_energy_residual(tau_star, c2, c1, c0, T, e2, e1, e0, eb,
                              d_lo, d_hi, total, *,
                              use_pallas=False, interpret=False):
    """Energy-budgeted water-filling residual
    sum_k clip(min((T-c0)/(c2*tau+c1), (eb-e0)/(e2*tau+e1)), lo, hi)
    - total for a (B, K) fleet batch — the inner evaluation of every
    ``kkt_energy`` bisection step (arXiv 2012.00143). ``eb = +inf`` rows
    reproduce ``waterfill_residual`` bitwise on both backends."""
    if use_pallas:
        from repro.kernels.waterfill import waterfill_energy_residual_pallas

        return waterfill_energy_residual_pallas(
            tau_star, c2, c1, c0, T, e2, e1, e0, eb, d_lo, d_hi, total,
            interpret=interpret,
        )
    from repro.kernels.ref import waterfill_energy_residual_ref

    return waterfill_energy_residual_ref(
        tau_star, c2, c1, c0, T, e2, e1, e0, eb, d_lo, d_hi, total
    )


def train_agg_step(disp, x, y, m, tau, weights, lr, *, loss_fn, max_tau=None,
                   server=None, acc=None, keep=None, flush=None,
                   use_pallas=False, interpret=False):
    """One fused train+aggregate scan step: masked per-learner GD
    (``local_train_stacked`` numerics — ``tau_k`` steps from each
    learner's own start params, data mask in the loss contraction),
    weighted accumulate, and the masked ``fed_agg`` flush contraction.

    ``acc=None`` is the cycle form (``run_fused``/fleet rounds): returns
    ``(fed_agg(locals, weights), None)``. Passing ``server``/``acc``/
    ``keep``/``flush`` is the async form (``_bucketed_events``): returns
    ``(keep*server + flush*acc1, (1-flush)*acc1)`` with
    ``acc1 = acc + sum_k w_k local_k``.

    The unfused path needs a static ``max_tau`` bound (it runs the
    ``lax.scan`` of ``local_train_stacked``); the Pallas megakernel
    bounds its in-kernel ``fori_loop`` by the traced ``max(tau)`` and
    ignores ``max_tau`` — interpret mode is bitwise equal to the unfused
    path on f32 operands (``tests/test_kernel_parity.py``).
    """
    if use_pallas:
        from repro.kernels.train_step import train_agg_step_pallas

        return train_agg_step_pallas(
            disp, x, y, m, tau, weights, lr, loss_fn=loss_fn,
            server=server, acc=acc, keep=keep, flush=flush,
            interpret=interpret,
        )
    from repro.kernels.ref import train_agg_step_ref

    if max_tau is None:
        raise ValueError("the unfused path needs a static max_tau bound")
    return train_agg_step_ref(
        disp, x, y, m, tau, weights, lr, loss_fn=loss_fn, max_tau=max_tau,
        server=server, acc=acc, keep=keep, flush=flush,
    )


def swiglu_fused(x, w_gate, w_up, w_down, *, use_pallas=False, interpret=False):
    if use_pallas:
        from repro.kernels.swiglu import swiglu_pallas

        return swiglu_pallas(x, w_gate, w_up, w_down, interpret=interpret)
    from repro.models.layers import swiglu as ref

    return ref(x, w_gate, w_up, w_down)


def mamba_scan(dt, x, b, c, a, h0=None, *, use_pallas=False, interpret=False):
    """Selective scan: state-resident Pallas kernel on TPU, lax.scan ref."""
    if use_pallas:
        from repro.kernels.mamba_scan import mamba_scan_pallas

        return mamba_scan_pallas(dt, x, b, c, a, h0=h0, interpret=interpret)
    from repro.kernels.ref import mamba_scan_ref

    return mamba_scan_ref(dt, x, b, c, a, h0=h0)
