"""Pallas TPU kernel: staleness-weighted federated aggregation.

The orchestrator's hot loop is `w_global = sum_k alpha_k * w_k` over K
stacked learner models — a memory-bound contraction over a small leading
axis. The fused kernel streams one (K, block_n) VMEM tile per grid step
and writes the (block_n,) weighted sum, touching every byte exactly once;
alpha lives in SMEM-friendly (1, K) form.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["fed_agg_pallas"]


def _kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (K, bn)
    w = w_ref[0, :].astype(jnp.float32)         # (K,)
    o_ref[...] = (w[:, None] * x).sum(axis=0, keepdims=True).astype(o_ref.dtype)


def fed_agg_pallas(stacked, weights, *, block_n: int = 2048, interpret: bool = False):
    """stacked: (K, ...) learner-stacked tensor; weights: (K,).
    Returns the weighted sum over axis 0 with the input dtype."""
    k = stacked.shape[0]
    orig_shape = stacked.shape[1:]
    flat = stacked.reshape(k, -1)
    n = flat.shape[1]
    pad = (-n) % block_n
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    nb = flat.shape[1] // block_n

    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, flat.shape[1]), stacked.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(flat, weights.reshape(1, k))
    out = out.reshape(-1)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
