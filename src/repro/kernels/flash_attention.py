"""Pallas TPU flash attention (GQA, causal, optional sliding window).

Online-softmax blockwise attention: grid = (batch, q_head, q_blocks,
kv_blocks) with the kv dimension innermost/"arbitrary" so the running
(m, l, acc) statistics live in VMEM scratch across kv iterations. Fully
masked kv blocks (beyond the causal frontier or outside the sliding
window) are skipped with ``pl.when`` — on TPU this prunes ~half the
compute for causal attention, which the pure-jnp reference (scan over all
chunks + where-mask) cannot do.

Block shapes are (block_q, head_dim) / (block_k, head_dim) VMEM tiles;
head_dim is kept whole (128 for every assigned arch — MXU-aligned).

Validated against ``repro.kernels.ref.flash_attention_ref`` in
interpret mode; on real TPU drop ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int | None, block_q: int, block_k: int,
            nk: int, sq: int, skv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip tests (static under the grid, dynamic in program ids)
    live = jnp.asarray(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_k - 1 >= q_start - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / (q.shape[-1] ** 0.5)                            # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v, *, causal: bool = True, window: int | None = None,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    while sq % block_q:
        block_q -= 1
    while skv % block_k:
        block_k -= 1
    nq, nk = sq // block_q, skv // block_k

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, nk=nk, sq=sq, skv=skv,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
