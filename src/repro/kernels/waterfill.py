"""Pallas TPU kernel: batched KKT water-filling residual.

One bisection step of the batched allocator (``core.solver_batched``)
evaluates, for every fleet b in a (B, K) problem batch,

    r_b = sum_k clip((T_b - C0_bk) / (C2_bk * tau_b + C1_bk), dl_bk, du_bk)
          - d_b

i.e. how much data the fleet absorbs at the trial water level tau_b minus
the sum constraint. The kernel streams one (block_b, K) coefficient tile
per grid step with the per-fleet scalars broadcast from a (block_b, 1)
column, computes the clipped divide and the K-reduction in VMEM, and
writes the (block_b, 1) residual — every coefficient byte is touched
exactly once per bisection step.

Layout conventions (shared with ``core.solver_batched``):
  * coefficients / bounds: (B, K), fleets on the sublane axis so K sits on
    the 128-lane axis (padded here to a lane multiple);
  * per-fleet scalars (tau*, T, d): (B,) reshaped to (B, 1) columns;
  * padded learner slots carry d_lo = d_hi = 0 so they clip to zero and
    never contribute to the residual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tpu_compiler_params

__all__ = ["waterfill_residual_pallas", "waterfill_energy_residual_pallas"]


def _kernel(tau_ref, c2_ref, c1_ref, c0_ref, t_ref, lo_ref, hi_ref, tot_ref, o_ref):
    tau = tau_ref[...].astype(jnp.float32)      # (bb, 1)
    t = t_ref[...].astype(jnp.float32)          # (bb, 1)
    c2 = c2_ref[...].astype(jnp.float32)        # (bb, K)
    c1 = c1_ref[...].astype(jnp.float32)
    c0 = c0_ref[...].astype(jnp.float32)
    d = (t - c0) / (c2 * tau + c1)
    d = jnp.clip(d, lo_ref[...].astype(jnp.float32), hi_ref[...].astype(jnp.float32))
    r = d.sum(axis=1, keepdims=True) - tot_ref[...].astype(jnp.float32)
    o_ref[...] = r.astype(o_ref.dtype)


def waterfill_residual_pallas(
    tau_star, c2, c1, c0, T, d_lo, d_hi, total,
    *, block_b: int = 8, lane: int = 128, interpret: bool = False,
):
    """tau_star/T/total: (B,); c2/c1/c0/d_lo/d_hi: (B, K). Returns (B,)."""
    b, k = c2.shape
    dtype = c2.dtype

    pad_b = (-b) % block_b
    pad_k = (-k) % lane
    # Padded learners: c2 = c1 = 1, c0 = 0, lo = hi = 0  ->  clip(...) == 0.
    # Padded fleets: T = 0, total = 0                    ->  residual == 0.
    if pad_k:
        kw = dict(mode="constant")
        c2 = jnp.pad(c2, ((0, 0), (0, pad_k)), constant_values=1.0, **kw)
        c1 = jnp.pad(c1, ((0, 0), (0, pad_k)), constant_values=1.0, **kw)
        c0 = jnp.pad(c0, ((0, 0), (0, pad_k)), **kw)
        d_lo = jnp.pad(d_lo, ((0, 0), (0, pad_k)), **kw)
        d_hi = jnp.pad(d_hi, ((0, 0), (0, pad_k)), **kw)
    if pad_b:
        c2 = jnp.pad(c2, ((0, pad_b), (0, 0)), constant_values=1.0)
        c1 = jnp.pad(c1, ((0, pad_b), (0, 0)), constant_values=1.0)
        c0 = jnp.pad(c0, ((0, pad_b), (0, 0)))
        d_lo = jnp.pad(d_lo, ((0, pad_b), (0, 0)))
        d_hi = jnp.pad(d_hi, ((0, pad_b), (0, 0)))
        tau_star = jnp.pad(tau_star, (0, pad_b))
        T = jnp.pad(T, (0, pad_b))
        total = jnp.pad(total, (0, pad_b))

    bp, kp = c2.shape
    col = lambda v: v.reshape(bp, 1).astype(dtype)
    nb = bp // block_b
    mat_spec = pl.BlockSpec((block_b, kp), lambda i: (i, 0))
    col_spec = pl.BlockSpec((block_b, 1), lambda i: (i, 0))

    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[col_spec, mat_spec, mat_spec, mat_spec, col_spec,
                  mat_spec, mat_spec, col_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((bp, 1), dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(col(tau_star), c2, c1, c0, col(T), d_lo, d_hi, col(total))
    return out.reshape(-1)[:b]


def _energy_kernel(tau_ref, c2_ref, c1_ref, c0_ref, t_ref, e2_ref, e1_ref,
                   e0_ref, eb_ref, lo_ref, hi_ref, tot_ref, o_ref):
    tau = tau_ref[...].astype(jnp.float32)      # (bb, 1)
    t = t_ref[...].astype(jnp.float32)          # (bb, 1)
    c2 = c2_ref[...].astype(jnp.float32)        # (bb, K)
    c1 = c1_ref[...].astype(jnp.float32)
    c0 = c0_ref[...].astype(jnp.float32)
    e2 = e2_ref[...].astype(jnp.float32)
    e1 = e1_ref[...].astype(jnp.float32)
    e0 = e0_ref[...].astype(jnp.float32)
    eb = eb_ref[...].astype(jnp.float32)
    dt = (t - c0) / (c2 * tau + c1)
    de = (eb - e0) / (e2 * tau + e1)
    d = jnp.clip(jnp.minimum(dt, de),
                 lo_ref[...].astype(jnp.float32),
                 hi_ref[...].astype(jnp.float32))
    r = d.sum(axis=1, keepdims=True) - tot_ref[...].astype(jnp.float32)
    o_ref[...] = r.astype(o_ref.dtype)


def waterfill_energy_residual_pallas(
    tau_star, c2, c1, c0, T, e2, e1, e0, eb, d_lo, d_hi, total,
    *, block_b: int = 8, lane: int = 128, interpret: bool = False,
):
    """Budgeted twin of ``waterfill_residual_pallas``: each learner's
    absorbable data is ``min(d_time, d_energy)`` before the box clip, with
    the ``(e2, e1, e0, eb)`` rows streamed alongside the time rows (four
    more (block_b, K) tiles per grid step — still one pass over every
    coefficient byte per bisection step). ``eb = +inf`` rows reproduce the
    time-only residual via IEEE ``min(d_time, inf)``. Shapes as in the
    time kernel; the energy rows are (B, K)."""
    b, k = c2.shape
    dtype = c2.dtype

    pad_b = (-b) % block_b
    pad_k = (-k) % lane
    # Padded learners: unit coefficient rows with a zero box — both
    # hyperbolae stay finite and clip(..., 0, 0) == 0 regardless.
    # Padded fleets: T = 0, eb = 0, total = 0 -> residual == 0.
    if pad_k:
        kw = dict(mode="constant")
        c2 = jnp.pad(c2, ((0, 0), (0, pad_k)), constant_values=1.0, **kw)
        c1 = jnp.pad(c1, ((0, 0), (0, pad_k)), constant_values=1.0, **kw)
        c0 = jnp.pad(c0, ((0, 0), (0, pad_k)), **kw)
        e2 = jnp.pad(e2, ((0, 0), (0, pad_k)), constant_values=1.0, **kw)
        e1 = jnp.pad(e1, ((0, 0), (0, pad_k)), constant_values=1.0, **kw)
        e0 = jnp.pad(e0, ((0, 0), (0, pad_k)), **kw)
        eb = jnp.pad(eb, ((0, 0), (0, pad_k)), **kw)
        d_lo = jnp.pad(d_lo, ((0, 0), (0, pad_k)), **kw)
        d_hi = jnp.pad(d_hi, ((0, 0), (0, pad_k)), **kw)
    if pad_b:
        c2 = jnp.pad(c2, ((0, pad_b), (0, 0)), constant_values=1.0)
        c1 = jnp.pad(c1, ((0, pad_b), (0, 0)), constant_values=1.0)
        c0 = jnp.pad(c0, ((0, pad_b), (0, 0)))
        e2 = jnp.pad(e2, ((0, pad_b), (0, 0)), constant_values=1.0)
        e1 = jnp.pad(e1, ((0, pad_b), (0, 0)), constant_values=1.0)
        e0 = jnp.pad(e0, ((0, pad_b), (0, 0)))
        eb = jnp.pad(eb, ((0, pad_b), (0, 0)))
        d_lo = jnp.pad(d_lo, ((0, pad_b), (0, 0)))
        d_hi = jnp.pad(d_hi, ((0, pad_b), (0, 0)))
        tau_star = jnp.pad(tau_star, (0, pad_b))
        T = jnp.pad(T, (0, pad_b))
        total = jnp.pad(total, (0, pad_b))

    bp, kp = c2.shape
    col = lambda v: v.reshape(bp, 1).astype(dtype)
    nb = bp // block_b
    mat_spec = pl.BlockSpec((block_b, kp), lambda i: (i, 0))
    col_spec = pl.BlockSpec((block_b, 1), lambda i: (i, 0))

    out = pl.pallas_call(
        _energy_kernel,
        grid=(nb,),
        in_specs=[col_spec, mat_spec, mat_spec, mat_spec, col_spec,
                  mat_spec, mat_spec, mat_spec, mat_spec,
                  mat_spec, mat_spec, col_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((bp, 1), dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(col(tau_star), c2, c1, c0, col(T), e2, e1, e0, eb,
      d_lo, d_hi, col(total))
    return out.reshape(-1)[:b]
