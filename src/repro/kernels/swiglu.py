"""Pallas TPU kernel: fused SwiGLU FFN  down( silu(x Wg) * (x Wu) ).

Grid = (m_blocks, f_blocks) with the hidden/f dimension innermost: the
(block_m, d) output accumulator stays in VMEM scratch while gate/up/down
weight tiles stream through, so the (m, f) silu(g)*u intermediate is never
materialized to HBM — that is the fusion win over the 3-matmul jnp
reference (which writes g, u, h to HBM at (tokens x d_ff) each).

Tiles: x (block_m, d), Wg/Wu (d, block_f), Wd (block_f, d) — all
MXU-aligned multiples of 128 for the assigned architectures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["swiglu_pallas"]


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc, *, nf: int):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)
    g = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    u = jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g)) * u
    acc[...] += jax.lax.dot(h, wd_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def swiglu_pallas(x, w_gate, w_up, w_down, *, block_m: int = 256,
                  block_f: int = 512, interpret: bool = False):
    """x: (..., d); w_gate/w_up: (d, f); w_down: (f, d)."""
    orig_shape = x.shape
    d = x.shape[-1]
    f = w_gate.shape[1]
    xm = x.reshape(-1, d)
    m = xm.shape[0]
    block_m = min(block_m, m)
    while m % block_m:
        block_m -= 1
    block_f = min(block_f, f)
    while f % block_f:
        block_f -= 1
    nm, nf = m // block_m, f // block_f

    out = pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid=(nm, nf),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((block_f, d), lambda mi, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xm, w_gate, w_up, w_down)
    return out.reshape(orig_shape)
