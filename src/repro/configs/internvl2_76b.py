"""internvl2-76b — VLM backbone: InternViT (stub) + InternLM2-like decoder
[arXiv:2404.16821]. 80L d_model=8192 64H (kv=8) d_ff=28672 vocab=128256.
The vision encoder + projector are the allowed stub: input_specs feeds
projected patch embeddings (B, num_image_tokens, d_model)."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2 76B)",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    num_image_tokens=256,
    rope_theta=1000000.0,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        num_image_tokens=8,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
