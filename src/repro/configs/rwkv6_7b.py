"""rwkv6-7b — Finch: attention-free RNN with data-dependent decay
[arXiv:2404.05892]. 32L d_model=4096 d_ff=14336 vocab=65536."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892 (RWKV-6 Finch 7B)",
    ssm_kind="rwkv6",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # 4096 / head 64
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=896,
        vocab_size=512,
        rwkv_head_dim=64,
        rwkv_lora_decay=16,
        rwkv_lora_mix=8,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
