"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]. 24L d_model=2048 16H d_ff(expert)=1408
vocab=151936."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,              # shared-expert aggregate width (4 x 1408)
    vocab_size=151936,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    moe_every=1,
    rope_theta=1000000.0,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        num_experts=4,
        num_shared_experts=1,
        top_k=2,
        moe_d_ff=128,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
