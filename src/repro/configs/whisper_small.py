"""whisper-small — encoder-decoder speech backbone [arXiv:2212.04356].
12L(enc)+12L(dec) d_model=768 12H d_ff=3072 vocab=51865. The conv/mel
frontend is the allowed stub: input_specs feeds 1500 frame embeddings."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356 (Whisper small)",
    num_layers=12,
    num_encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    tie_embeddings=True,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        num_encoder_layers=2,
        encoder_seq=64,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
