"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts,
top-6 routing, first layer dense [arXiv:2401.06066].
28L d_model=2048 16H d_ff(expert)=1408 vocab=102400."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066 (DeepSeekMoE 16B)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,             # the single dense (first) layer, per model card
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,          # assigned expert hidden size
    moe_every=1,
    moe_first_dense=1,
    rope_theta=10000.0,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        num_experts=4,
        num_shared_experts=1,
        top_k=2,
        moe_d_ff=128,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
