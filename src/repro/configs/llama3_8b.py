"""llama3-8b — dense GQA decoder with 128k vocab [arXiv:2407.21783].
32L d_model=4096 32H (kv=8) d_ff=14336 vocab=128256."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    source="arXiv:2407.21783 (Llama 3 8B)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
