"""jamba-v0.1-52b — hybrid Mamba + attention (1:7 interleave) with MoE
every other layer, 16 experts top-2 [arXiv:2403.19887].
32L d_model=4096 32H (kv=8) d_ff=14336 vocab=65536."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba v0.1)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    attn_every=8,          # 1 attention : 7 mamba per 8-layer period
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,        # Jamba experts are full-width
    moe_every=2,
    d_state=16,
    d_conv=4,
    expand=2,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    # 4 layers with attn_every=2 keeps the hybrid pattern (mamba+moe,
    # attn+dense, mamba+moe, attn+dense) at smoke scale.
    return dataclasses.replace(
        CONFIG,
        num_layers=4,
        attn_every=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        num_experts=4,
        top_k=2,
        moe_d_ff=256,
        d_state=8,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
