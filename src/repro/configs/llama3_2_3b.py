"""llama3.2-3b — small Llama-3 family dense model
[hf:meta-llama/Llama-3.2-1B family card]. 28L d_model=3072 24H (kv=8)
d_ff=8192 vocab=128256."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    source="hf:meta-llama/Llama-3.2-3B",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
