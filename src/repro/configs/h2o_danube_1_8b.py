"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818]. 24L d_model=2560 32H (kv=8) d_ff=6912 vocab=32000.
The 4096-token sliding window is what qualifies this dense arch for the
long_500k decode shape (rolling KV cache, O(window) state)."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    source="arXiv:2401.16818 (H2O-Danube 1.8B)",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10000.0,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        sliding_window=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
