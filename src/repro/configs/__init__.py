"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape, shape_for

_MODULES = {
    "rwkv6-7b": "rwkv6_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama3-8b": "llama3_8b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "whisper-small": "whisper_small",
    "llama3.2-3b": "llama3_2_3b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "internvl2-76b": "internvl2_76b",
}

ARCH_NAMES = list(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _mod(name).CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _mod(name).reduced()


__all__ = [
    "ARCH_NAMES",
    "ArchConfig",
    "INPUT_SHAPES",
    "InputShape",
    "get_config",
    "get_reduced",
    "shape_for",
]
