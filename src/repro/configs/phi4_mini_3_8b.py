"""phi4-mini-3.8b — dense RoPE + SwiGLU + GQA, 200k vocab [arXiv:2412.08905].
32L d_model=3072 24H (kv=8) d_ff=8192 vocab=200064."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    source="arXiv:2412.08905 (Phi-4-mini)",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    rope_theta=10000.0,
    param_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
