"""Architecture + run configuration.

``ArchConfig`` is the single config object every layer of the stack consumes
(model builder, sharding rules, launcher, allocator complexity accounting).
One file per assigned architecture lives next to this module; each exposes
``CONFIG`` (the exact published shape) and ``reduced()`` (a <=512-dim,
2-layer smoke variant of the same family).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "shape_for"]

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: Family
    source: str = ""                  # citation for the shape

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"                 # silu (SwiGLU) | gelu (plain MLP)

    # attention variants
    sliding_window: int | None = None     # SWA width (h2o-danube)
    attn_chunk: int = 512                 # flash-attention KV chunk

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                     # routed-expert hidden dim
    moe_every: int = 1                    # MoE every n-th layer (jamba: 2)
    moe_first_dense: int = 0              # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25

    # SSM (mamba / rwkv6)
    ssm_kind: str = ""                    # "mamba" | "rwkv6"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                      # 0 -> d_model // 16
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32

    # hybrid (jamba): period layout, e.g. attention every 8th layer
    attn_every: int = 0                   # 0 -> pure; n -> layer i is attn iff i % n == n//2

    # enc-dec (whisper)
    num_encoder_layers: int = 0
    encoder_seq: int = 1500               # stubbed mel-frame count

    # vlm (internvl2)
    num_image_tokens: int = 256           # stubbed projected patch embeddings

    # perf knobs (§Perf hillclimbing; defaults = paper-faithful baseline)
    wkv_unroll: int = 1        # WKV recurrence steps per scan iteration
    mamba_unroll: int = 1      # selective-scan steps per scan iteration
    loss_chunk: int = 512      # vocab-logit chunk length in lm_loss
    moe_shard_map: bool = True # batch-manual shard_map around MoE dispatch
    attn_p_bf16: bool = False  # bf16 probabilities for the PV contraction
    attn_q_block: int = 0      # causal q-block kv-truncation (0 = off)
    wkv_backend: str = "scan"  # "scan" (step recurrence) | "chunked" (matmul form)
    wkv_chunk: int = 16        # chunk length for the chunked WKV backend

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # training
    learning_rate: float = 3e-4
    optimizer: str = "adamw"
    remat: bool = True                    # activation checkpoint per layer
    zero1: bool = True                    # shard optimizer state over fsdp axis

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(self.d_model // 16, 1)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> list[str]:
        """Per-layer mixer kind for the decoder trunk."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append(self.ssm_kind)
            elif self.family == "hybrid" and self.attn_every:
                kinds.append("attn" if i % self.attn_every == self.attn_every // 2 else "mamba")
            else:
                kinds.append("attn")
        return kinds

    def layer_is_moe(self) -> list[bool]:
        out = []
        for i in range(self.num_layers):
            if self.num_experts == 0:
                out.append(False)
            elif i < self.moe_first_dense:
                out.append(False)
            else:
                out.append((i - self.moe_first_dense) % self.moe_every == 0)
        return out

    def supports_long_context(self) -> bool:
        """True iff decode with a 500k context is sub-quadratic / bounded."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    # -- allocator accounting ----------------------------------------------
    def param_counts(self) -> tuple[int, int]:
        """(total, active-per-token) parameter counts, analytic."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qo = d * self.num_heads * hd + self.num_heads * hd * d
        kv = 2 * d * self.num_kv_heads * hd
        dense_ffn = 3 * d * ff if self.act == "silu" else 2 * d * ff
        moe_ffn_total = moe_ffn_active = 0
        if self.num_experts:
            unit = 3 * d * self.moe_d_ff
            moe_ffn_total = (self.num_experts + self.num_shared_experts) * unit + d * self.num_experts
            moe_ffn_active = (self.top_k + self.num_shared_experts) * unit + d * self.num_experts
        mamba = (
            2 * d * self.d_inner                      # in_proj (x, z)
            + self.d_inner * self.d_conv              # conv
            + self.d_inner * (self.resolved_dt_rank + 2 * self.d_state)
            + self.resolved_dt_rank * self.d_inner    # dt proj
            + self.d_inner * self.d_state             # A
            + self.d_inner * d                        # out proj
        )
        rwkv = (
            5 * d * d                                  # r,k,v,g,o projections
            + 2 * d * self.rwkv_lora_decay + 6 * d * self.rwkv_lora_mix * 2
            + 2 * d                                    # decay base, bonus u
            + 3 * d * ff // 2                          # channel-mix (approx)
        )
        total = active = 0
        for kind, is_moe in zip(self.layer_kinds(), self.layer_is_moe()):
            mixer = {"attn": qo + kv, "mamba": mamba, "rwkv6": rwkv}[kind]
            ffn_t = moe_ffn_total if is_moe else dense_ffn
            ffn_a = moe_ffn_active if is_moe else dense_ffn
            total += mixer + ffn_t
            active += mixer + ffn_a
        emb = v * d * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb
        if self.num_encoder_layers:
            enc = self.num_encoder_layers * (qo + kv + dense_ffn)
            cross = self.num_layers * (qo + kv)
            total += enc + cross
            active += enc + cross
        return int(total), int(active)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_for(name: str) -> InputShape:
    return INPUT_SHAPES[name]
